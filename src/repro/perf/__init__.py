"""Performance layer: measured autotuning of the kernel dispatch
schedule (:mod:`repro.perf.tune`) and profiler trace / per-op cost
capture (:mod:`repro.perf.profile`).  See DESIGN.md §8.

The package is deliberately one-way: :mod:`repro.kernels.ops` never
imports it — the tuner measures through the public kernel wrappers and
hands the surviving parameters to :func:`repro.kernels.ops.set_tuning`,
so an untuned process (and every traced call) behaves exactly as if
this package did not exist.
"""
__all__ = ["tune", "profile"]  # import the submodules explicitly
