"""Profiler trace capture + per-op compiled-cost harvesting.

Two thin, dependency-free views into what the kernels actually cost:

* :func:`trace` — a context manager around ``jax.profiler`` trace
  capture.  Everything executed inside lands in a TensorBoard/Perfetto
  trace directory (``benchmarks/run.py --profile`` wraps one benchmark
  section in it and uploads the directory from CI).
* :func:`op_costs` — lower + compile a callable and harvest the
  compiler's own cost model: flops, bytes accessed, and (where the
  backend reports it) optimal seconds.  This is the *static* cost view
  that pairs with a measured wall time to give achieved-vs-attainable
  (:mod:`benchmarks.roofline` uses its own analytic model instead, so
  the roofline gate cannot drift when XLA's cost tables change; the two
  are cross-checkable in the profile report).

Both normalize across jax versions via
:func:`repro.launch.hlocost.cost_dict` (older jax returns
``cost_analysis()`` as a one-element list).
"""
from __future__ import annotations

import contextlib
import json
import os

import jax

from repro.launch import hlocost

__all__ = ["trace", "op_costs", "profile_ops", "write_report"]


@contextlib.contextmanager
def trace(logdir: str):
    """Capture a ``jax.profiler`` trace of the enclosed block into
    ``logdir`` (created if missing).  Yields the directory; view with
    TensorBoard's profile plugin or Perfetto.

    Keep the enclosed block BOUNDED — a handful of dispatches, not a
    bench run: the profiler buffers every event in host memory until
    ``stop_trace``, so minutes of hot-loop dispatches (e.g. the tuner's
    grid race) exhaust RAM instead of producing a trace.
    :func:`profile_ops` with ``logdir`` is the safe packaged form."""
    os.makedirs(logdir, exist_ok=True)
    jax.profiler.start_trace(logdir)
    try:
        yield logdir
    finally:
        jax.profiler.stop_trace()


def op_costs(fn, *args, static_argnames=()) -> dict:
    """Compile ``fn(*args)`` and return the compiler's cost view:
    ``{"flops", "bytes", "peak_memory", "optimal_seconds"}`` (0.0 where
    the backend does not report a term).  ``fn`` is jitted here — pass
    the un-jitted body; already-jitted callables lower fine too."""
    jitted = fn if hasattr(fn, "lower") else jax.jit(
        fn, static_argnames=static_argnames)
    compiled = jitted.lower(*args).compile()
    cost = hlocost.cost_dict(compiled)
    out = {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "optimal_seconds": float(cost.get("optimal_seconds", 0.0)),
    }
    try:
        mem = compiled.memory_analysis()
        out["peak_memory"] = float(
            getattr(mem, "temp_size_in_bytes", 0.0) or 0.0)
    except Exception:       # backends without memory analysis
        out["peak_memory"] = 0.0
    return out


def profile_ops(named: dict, *, logdir: str | None = None) -> dict:
    """Harvest :func:`op_costs` for ``{name: (fn, args)}``; when
    ``logdir`` is given, also execute each op once under a profiler
    trace (one trace for the whole set — per-op spans are visible inside
    it).  Returns ``{name: costs}``."""
    report = {name: op_costs(fn, *args) for name, (fn, args) in named.items()}
    if logdir is not None:
        with trace(logdir):
            for fn, args in named.values():
                jax.block_until_ready(jax.jit(fn)(*args)
                                      if not hasattr(fn, "lower")
                                      else fn(*args))
    return report


def write_report(report: dict, path: str) -> str:
    """Serialize a :func:`profile_ops` report to JSON (the CI artifact)."""
    with open(path, "w") as f:
        json.dump(report, f, indent=1, sort_keys=True)
    return path
