"""Measured autotuner for the kernel dispatch schedule (DESIGN.md §8).

Every tile/grid constant in :mod:`repro.kernels.ops` is a *schedule*
knob — bit-identical under any legal value — whose default was eyeballed
on one container.  This module replaces the guess with a measurement:

    PYTHONPATH=src python -m repro.perf.tune            # tune + cache
    PYTHONPATH=src python -m repro.perf.tune --smoke    # tiny-grid CI check

For each (family, backend, shape class) it races every candidate in
:data:`SEARCH_SPACE` through the PUBLIC dispatch wrapper — so a
candidate pays exactly what real dispatch will pay, including padding
and cache-key formation — in an interleaved best-of-``reps`` loop (the
same-run convention from docs/benchmarks.md: load moves all candidates
together, so the argmin is load-stable even when the absolute times are
not).  Before any candidate is timed its output is asserted *bitwise*
identical to the all-defaults output: a candidate that changes a single
bit is a semantics bug in the kernels, not a schedule choice, and the
tuner refuses to continue (:class:`TuningError`).

One knob class is excluded by construction rather than gated: the
batch-STREAMING tiles (``forest_update.tile_b``, ``qo_update.tile``)
set the granularity at which a batch flows through the kernels'
sequential Chan merge, so on the kernel path ("pallas"/"interpret")
changing them reorders f32 accumulation — same math, different bits.
:func:`candidates` drops them from kernel-path grids
(:data:`KERNEL_STREAM_KNOBS`); on the jnp backend the fused lowering
ignores them entirely, so there they remain searchable (and trivially
bit-identical) dispatch-key shapers.

Winners persist to a JSON cache keyed by **device kind** as well — a
cache tuned on a TPU v5e never steers a CPU host — and
:func:`install` filters the cache to the current device before handing
the entries to :func:`repro.kernels.ops.set_tuning`.  The search space
always contains the hard-coded defaults, so the installed winner is
never measurably worse than an untuned machine on the machine that
tuned it; a machine with no cache entry simply keeps the defaults.
"""
from __future__ import annotations

import argparse
import contextlib
import itertools
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops as kops

__all__ = [
    "SEARCH_SPACE", "KERNEL_STREAM_KNOBS", "SMOKE_SPACE", "SMOKE_SHAPES",
    "TUNE_FAMILIES",
    "TuningError", "candidates", "make_workloads", "tune_family", "tune",
    "cache_path", "load_cache", "save_cache", "install", "ensure",
    "device_kind",
]

#: Candidate values per tunable parameter, per dispatch family.  Every
#: family's space CONTAINS :data:`repro.kernels.ops.DEFAULT_PARAMS` (the
#: tuner asserts it), so "best measured" can never lose to "untuned".
#: Kernel-path tile knobs (tile_b/tile_m/tile_r/tile) only reshape the
#: Pallas grid; the jnp backend's real knobs are the dispatch-shaping
#: ones — ``batch_ladder`` (how much pad work a ragged batch buys),
#: ``ply_round`` (wasted route plies vs compiled-program count) and the
#: query ``min_bucket`` (gather bucket granularity).
SEARCH_SPACE = {
    "qo_update": {"tile": (128, 256, 512, 1024)},
    "forest_update": {"tile_b": (128, 256, 512), "tile_m": (64, 128),
                      "batch_ladder": ("pow2", "pow2_half")},
    "forest_query": {"tile_m": (64, 128, 256), "min_bucket": (4, 8, 16)},
    "forest_route": {"tile_b": (128, 256, 512),
                     "batch_ladder": ("pow2", "pow2_half"),
                     "ply_round": (1, 2, 4)},
    "forest_merge": {"tile_r": (64, 128, 256, 512)},
    "sketch_update": {"tile_r": (64, 128, 256, 512),
                      "batch_ladder": ("pow2", "pow2_half")},
    "sketch_merge": {"tile_r": (64, 128, 256, 512)},
}

#: Knobs that are NOT searchable on the kernel path ("pallas" /
#: "interpret"): they set the width at which the batch streams through a
#: sequential per-tile Chan merge, so a different value reorders f32
#: accumulation — bit-different output, i.e. a semantics knob there, not
#: a schedule knob.  The jnp lowering fuses the whole batch in one
#: segment-sum (these knobs never reach the program), so on "jnp" they
#: stay in the grid purely as dispatch-key shapers.
KERNEL_STREAM_KNOBS = {
    "forest_update": ("tile_b",),
    "qo_update": ("tile",),
    # the sketch families deliberately have NO entry: a batch is absorbed
    # as ONE compaction (batch pre-sketch + rank-bucket merge), so no
    # knob sets a sequential per-tile Chan-merge width — ``tile_r`` only
    # tiles independent table rows and every value is bit-identical on
    # every backend (asserted by the tuner's identity gate).
}

#: The families :func:`tune` covers by default: the forest-scale hot
#: paths.  ``qo_update`` is tunable but opt-in — its kernel always runs
#: the Pallas path (interpreter off-TPU), so racing it on a CPU host
#: measures the interpreter, not a schedule.
TUNE_FAMILIES = ("forest_update", "forest_query", "forest_route",
                 "forest_merge", "sketch_update", "sketch_merge")

#: Two-candidates-per-knob truncation for the CI smoke: exercises the
#: full tune -> assert-bit-identity -> save -> load -> install loop in
#: seconds, not minutes.
SMOKE_SPACE = {
    fam: {k: (v[0], v[-1]) if len(v) > 1 else v for k, v in knobs.items()}
    for fam, knobs in SEARCH_SPACE.items()
}

#: Workload shapes for the smoke run (full-run defaults are in
#: :func:`make_workloads`).
SMOKE_SHAPES = dict(M=64, F=4, C=8, T=4, B=260)


class TuningError(AssertionError):
    """A candidate schedule changed the op's output bits — a kernel
    semantics bug, never a legal tuning outcome."""


def device_kind() -> str:
    """Tuning-cache namespace for this host's accelerator (e.g. ``cpu``,
    ``TPU v5e``) — entries never cross device kinds."""
    return jax.devices()[0].device_kind


def candidates(family: str, space: dict | None = None,
               backend: str = "jnp") -> list[dict]:
    """The family's candidate grid as a list of full param dicts (cross
    product of ``space[family]``, defaults filled for unmentioned knobs).
    On kernel-path backends the :data:`KERNEL_STREAM_KNOBS` are pinned
    at their defaults (never searched — see the module docstring).  The
    all-defaults point is always present (prepended if the space was
    truncated past it)."""
    knobs = dict((space or SEARCH_SPACE)[family])
    if backend != "jnp":
        for k in KERNEL_STREAM_KNOBS.get(family, ()):
            knobs.pop(k, None)
    defaults = dict(kops.DEFAULT_PARAMS[family])
    keys = sorted(knobs)
    grid = [dict(defaults, **dict(zip(keys, combo)))
            for combo in itertools.product(*(knobs[k] for k in keys))]
    if defaults not in grid:
        grid.insert(0, defaults)
    return grid


def _complete_trees(T: int, M: int, F: int, rng):
    """T perfect binary trees in the (T, M) routing layout: internal
    node i has children (2i+1, 2i+2) — the pairs-allocation contract —
    random features/thresholds, and every row past the realized node
    count is a self-contained pad leaf.  Returns the arrays + depth."""
    d = 1
    while 2 ** (d + 2) - 1 <= M:
        d += 1
    n_int = 2 ** d - 1
    feature = rng.integers(0, F, (T, M)).astype(np.int32)
    threshold = rng.normal(0, 1, (T, M)).astype(np.float32)
    child = np.full((T, M, 2), -1, np.int32)
    is_leaf = np.ones((T, M), bool)
    ii = np.arange(n_int)
    child[:, :n_int, 0] = 2 * ii + 1
    child[:, :n_int, 1] = 2 * ii + 2
    is_leaf[:, :n_int] = False
    return (jnp.asarray(feature), jnp.asarray(threshold),
            jnp.asarray(child), jnp.asarray(is_leaf), d)


def make_workloads(M: int = 256, F: int = 8, C: int = 16, T: int = 8,
                   B: int = 1300, seed: int = 0) -> dict:
    """Fixed-seed representative inputs for every tunable family.

    B = 1300 deliberately sits just past a pow-2 bucket boundary (1024)
    — the regime where the ladder choice matters most; tables carry a
    realistic occupancy mix (empty, singleton and populated bins).
    Returns the input arrays plus each family's shape-class string.
    """
    rng = np.random.default_rng(seed)
    n = rng.poisson(4.0, (M, F, C)).astype(np.float32)
    mean = np.where(n > 0, rng.normal(0, 1, (M, F, C)), 0).astype(np.float32)
    m2 = np.where(n > 1, rng.gamma(2.0, 1.0, (M, F, C)), 0).astype(np.float32)
    ao_y = {"n": jnp.asarray(n), "mean": jnp.asarray(mean),
            "m2": jnp.asarray(m2)}
    ao_sum_x = jnp.asarray(
        np.where(n > 0, rng.normal(0, 1, (M, F, C)), 0).astype(np.float32))
    ao_radius = jnp.asarray(rng.uniform(0.5, 1.5, (M, F)).astype(np.float32))
    ao_origin = jnp.asarray(rng.normal(0, 0.1, (M, F)).astype(np.float32))
    X = jnp.asarray(rng.normal(0, 1, (B, F)).astype(np.float32))
    y = jnp.asarray(rng.normal(0, 1, (B,)).astype(np.float32))
    leaf = jnp.asarray(rng.integers(0, M, (B,)).astype(np.int32))
    attempt = jnp.asarray(np.arange(M) < max(1, M // 8))
    feature, threshold, child, is_leaf, depth = _complete_trees(T, M, F, rng)
    xs = jnp.asarray(rng.normal(0, 1, (B,)).astype(np.float32))
    table = {"n": ao_y["n"][0, 0], "mean": ao_y["mean"][0, 0],
             "m2": ao_y["m2"][0, 0], "sum_x": ao_sum_x[0, 0],
             "radius": jnp.float32(1.0), "origin": jnp.float32(0.0)}
    tabs = kops._shape_class_tables(M, F, C)
    return {
        "update": (ao_y, ao_sum_x, ao_radius, ao_origin, leaf, X, y),
        "query": (ao_y, ao_sum_x, ao_radius, ao_origin, attempt),
        "route": (feature, threshold, child, is_leaf, X),
        "merge": (ao_y, ao_sum_x, ao_y, ao_sum_x),
        # the sketch families reuse the same occupancy-mixed planes with
        # the C axis read as K slots (the ops sort them into rank order
        # themselves, so arbitrary plane contents are a legal workload)
        "sketch_update": (ao_y, ao_sum_x, leaf, X, y),
        "sketch_merge": (ao_y, ao_sum_x, ao_y, ao_sum_x),
        "qo": (table, xs, y),
        "depth": depth,
        "shape_class": {
            "forest_update": tabs, "forest_query": tabs,
            "forest_merge": tabs,
            "sketch_update": tabs, "sketch_merge": tabs,
            "forest_route": kops._shape_class_route(T, M, F),
            "qo_update": f"C{C}",
        },
    }


def _runner(family: str, w: dict, backend: str):
    """Zero-arg closure running one dispatch of ``family`` through its
    public wrapper (no explicit schedule args, so the installed tuning
    entry — and nothing else — steers the dispatch)."""
    if family == "forest_update":
        return lambda: kops.forest_update(*w["update"], backend=backend)
    if family == "forest_query":
        return lambda: kops.forest_best_splits(*w["query"], backend=backend)
    if family == "forest_route":
        return lambda: kops.forest_route(*w["route"], depth=w["depth"],
                                         backend=backend)
    if family == "forest_merge":
        return lambda: kops.forest_merge(*w["merge"], backend=backend)
    if family == "sketch_update":
        return lambda: kops.sketch_update(*w["sketch_update"],
                                          backend=backend)
    if family == "sketch_merge":
        return lambda: kops.sketch_merge(*w["sketch_merge"],
                                         backend=backend)
    if family == "qo_update":
        return lambda: kops.qo_update(*w["qo"])
    raise KeyError(family)


@contextlib.contextmanager
def _only_tuning(entry: dict):
    """Temporarily replace the process tuning table (restored on exit)."""
    saved = kops.get_tuning()
    try:
        kops.set_tuning(entry)
        yield
    finally:
        kops.set_tuning(saved)


def _bitwise_equal(a, b) -> bool:
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    return len(la) == len(lb) and all(
        np.array_equal(np.asarray(x), np.asarray(y), equal_nan=True)
        for x, y in zip(la, lb))


def tune_family(family: str, backend: str | None = None, *,
                shapes: dict | None = None, space: dict | None = None,
                reps: int = 3, inner: int = 2) -> tuple[str, dict]:
    """Race the family's candidate grid on one workload; return
    ``(cache key, entry)``.

    Every candidate is first run once under :func:`_only_tuning` and
    asserted bitwise-identical to the all-defaults output (compiling it
    as a side effect), then raced interleaved: ``reps`` rounds visiting
    every candidate per round (``inner`` calls each), keeping each
    candidate's per-round minimum — host load perturbs a whole round,
    not one candidate.  The entry records the winner's params plus the
    measured (winner, default) microseconds and their ratio.
    """
    backend = kops.resolve_backend(backend)
    if family == "qo_update":
        backend = "pallas"          # the family is kernel-path-only
    defaults = dict(kops.DEFAULT_PARAMS[family])
    w = make_workloads(**(shapes or {}))
    sc = w["shape_class"][family]
    tkey = (family, backend, sc)
    run = _runner(family, w, backend)
    with _only_tuning({}):
        ref = jax.tree.map(np.asarray, jax.block_until_ready(run()))
    grid = candidates(family, space, backend=backend)
    assert defaults in grid, (family, "search space must contain defaults")
    best_us = [float("inf")] * len(grid)
    for i, cand in enumerate(grid):      # identity gate + warm compile
        with _only_tuning({tkey: cand}):
            out = jax.block_until_ready(run())
        if not _bitwise_equal(ref, out):
            raise TuningError(
                f"{family}/{backend}/{sc}: candidate {cand} is not "
                f"bit-identical to defaults — schedule changed semantics")
    for _ in range(reps):
        for i, cand in enumerate(grid):
            with _only_tuning({tkey: cand}):
                t0 = time.perf_counter()
                for _ in range(inner):
                    jax.block_until_ready(run())
                best_us[i] = min(best_us[i],
                                 (time.perf_counter() - t0) / inner * 1e6)
    win = int(np.argmin(best_us))
    default_us = best_us[grid.index(defaults)]
    entry = {
        "params": grid[win],
        "us": round(best_us[win], 3),
        "default_us": round(default_us, 3),
        "speedup_vs_default": round(default_us / best_us[win], 4),
        "n_candidates": len(grid),
    }
    return "|".join((device_kind(), family, backend, sc)), entry


def tune(families=TUNE_FAMILIES, backend: str | None = None, *,
         shapes: dict | None = None, space: dict | None = None,
         reps: int = 3) -> dict:
    """Tune each family on the (shared) workload; returns ``{cache key:
    entry}``.  Drops every cached jit afterwards so the candidate
    programs compiled during the race don't linger."""
    entries = {}
    for fam in families:
        key, entry = tune_family(fam, backend, shapes=shapes, space=space,
                                 reps=reps)
        entries[key] = entry
    kops.clear_jit_caches()
    return entries


# --------------------------------------------------------------------------
# persistence + installation
# --------------------------------------------------------------------------

_CACHE_VERSION = 1


def cache_path() -> str:
    """The tuning-cache location: ``$REPRO_TUNING_CACHE`` if set, else
    ``.tuning_cache.json`` at the repo root (gitignored — a measured
    artifact of one machine, never a committed baseline)."""
    env = os.environ.get("REPRO_TUNING_CACHE")
    if env:
        return env
    root = os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__)))))
    return os.path.join(root, ".tuning_cache.json")


def load_cache(path: str | None = None) -> dict:
    """``{cache key: entry}`` from disk ({} on missing/old-version file)."""
    path = path or cache_path()
    if not os.path.exists(path):
        return {}
    with open(path) as f:
        blob = json.load(f)
    if blob.get("version") != _CACHE_VERSION:
        return {}
    return blob.get("entries", {})


def save_cache(entries: dict, path: str | None = None) -> str:
    """Merge ``entries`` over the on-disk cache and write it back."""
    path = path or cache_path()
    merged = dict(load_cache(path))
    merged.update(entries)
    with open(path, "w") as f:
        json.dump({"version": _CACHE_VERSION, "entries": merged}, f,
                  indent=1, sort_keys=True)
    return path


def install(entries: dict) -> dict:
    """Hand the current device kind's entries to
    :func:`repro.kernels.ops.set_tuning` (replacing the installed
    table); returns the installed ``{(family, backend, shape_class):
    params}``.  Entries measured on other device kinds are skipped —
    the whole point of keying the cache on the device."""
    dk = device_kind()
    table = {}
    for key, entry in entries.items():
        kind, family, backend, sc = key.split("|")
        if kind == dk:
            table[(family, backend, sc)] = dict(entry["params"])
    kops.set_tuning(table)
    return table


def ensure(path: str | None = None, families=TUNE_FAMILIES,
           backend: str | None = None, *, shapes: dict | None = None,
           space: dict | None = None, reps: int = 3,
           force: bool = False) -> dict:
    """Load-or-tune: install cached entries for this device kind,
    tuning (and persisting) any family that has no entry yet.  The
    serving/bench entry point — one call makes dispatch tuned without
    ever re-measuring on a machine that already has a cache."""
    entries = {} if force else load_cache(path)
    rb = kops.resolve_backend(backend)
    have = {k.split("|")[1] for k in entries if k.split("|")[0] == device_kind()
            and k.split("|")[2] == rb}
    missing = [f for f in families if f not in have]
    if missing:
        entries = dict(entries,
                       **tune(missing, backend, shapes=shapes, space=space,
                              reps=reps))
        save_cache(entries, path)
    install(entries)
    return entries


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="tiny grid + tiny shapes; assert cache round-trip")
    ap.add_argument("--families", nargs="*", default=list(TUNE_FAMILIES))
    ap.add_argument("--backend", default=None)
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--cache", default=None,
                    help="cache file (default: $REPRO_TUNING_CACHE or "
                         "repo-root .tuning_cache.json)")
    ap.add_argument("--force", action="store_true",
                    help="re-measure even when the cache has entries")
    args = ap.parse_args(argv)

    shapes = SMOKE_SHAPES if args.smoke else None
    space = SMOKE_SPACE if args.smoke else None
    reps = 2 if args.smoke else args.reps
    entries = tune(args.families, args.backend, shapes=shapes, space=space,
                   reps=reps)
    path = save_cache(entries, args.cache)
    reloaded = load_cache(path)
    for key, entry in entries.items():
        assert reloaded[key] == json.loads(json.dumps(entry)), \
            f"cache round-trip mismatch for {key}"
    installed = install(reloaded)
    print(f"tuned {len(entries)} entr{'y' if len(entries) == 1 else 'ies'} "
          f"-> {path} (installed {len(installed)} for '{device_kind()}')")
    for key, entry in sorted(entries.items()):
        print(f"  {key:<52} {entry['us']:>9.1f}us "
              f"({entry['speedup_vs_default']:.2f}x vs default "
              f"{entry['default_us']:.1f}us) {entry['params']}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
