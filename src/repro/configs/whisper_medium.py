"""whisper-medium: enc-dec, conv frontend stubbed (precomputed frame
embeddings). [arXiv:2212.04356; unverified]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-medium", family="encdec",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=4096, vocab=51865,
    n_enc_layers=24, enc_seq=1500, frontend_stub=True,
)
