"""Architecture registry: --arch <id> resolves here."""
from repro.configs.base import ArchConfig, ShapeConfig, SHAPES, reduced

from repro.configs.moonshot_v1_16b_a3b import CONFIG as _moonshot
from repro.configs.grok_1_314b import CONFIG as _grok
from repro.configs.whisper_medium import CONFIG as _whisper
from repro.configs.h2o_danube_3_4b import CONFIG as _danube
from repro.configs.mistral_nemo_12b import CONFIG as _nemo
from repro.configs.qwen3_8b import CONFIG as _qwen3
from repro.configs.phi3_mini_3_8b import CONFIG as _phi3
from repro.configs.falcon_mamba_7b import CONFIG as _falcon
from repro.configs.zamba2_2_7b import CONFIG as _zamba2
from repro.configs.chameleon_34b import CONFIG as _chameleon

ARCHS = {c.name: c for c in [
    _moonshot, _grok, _whisper, _danube, _nemo,
    _qwen3, _phi3, _falcon, _zamba2, _chameleon,
]}


def get_arch(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


def get_shape(name: str) -> ShapeConfig:
    for s in SHAPES:
        if s.name == name:
            return s
    raise KeyError(name)
