"""chameleon-34b: early-fusion VLM, VQ image tokens share the vocab; the
patch/VQ frontend is stubbed (token ids arrive precomputed).
[arXiv:2405.09818; unverified]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="chameleon-34b", family="vlm",
    n_layers=48, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=22016, vocab=65536,
)
