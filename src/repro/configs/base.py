"""Architecture configuration schema + shape suite shared by all archs."""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace
from typing import Optional, Tuple

__all__ = ["ArchConfig", "ShapeConfig", "SHAPES", "reduced"]


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0            # 0 -> d_model // n_heads
    # attention extras
    qk_norm: bool = False
    swa_window: int = 0          # 0 -> full attention
    rope_theta: float = 1e4
    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_capacity_factor: float = 1.25
    # SSM (mamba)
    ssm_state: int = 0
    ssm_version: int = 1         # 1 = mamba1, 2 = mamba2 (scalar-A heads)
    ssm_expand: int = 2
    ssm_head_dim: int = 64       # mamba2 only
    # hybrid (zamba2-style): a weight-shared attention block applied every
    # `hybrid_period` ssm layers
    hybrid_period: int = 0
    # encoder-decoder (whisper-style)
    n_enc_layers: int = 0        # 0 -> decoder-only
    enc_seq: int = 0             # fixed encoder length (audio frames)
    # modality frontend stub: inputs are precomputed embeddings, not ids
    frontend_stub: bool = False
    tie_embeddings: bool = False
    norm_eps: float = 1e-5

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch run 500k-token contexts? (DESIGN.md §6)"""
        return self.family in ("ssm", "hybrid") or self.swa_window > 0

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    def n_params(self) -> int:
        """Approximate parameter count (embedding + blocks + head)."""
        d, f, L, V = self.d_model, self.d_ff, self.n_layers, self.vocab
        hd, H, Hkv = self.hd, self.n_heads, self.n_kv_heads
        att = d * H * hd + 2 * d * Hkv * hd + H * hd * d
        if self.family == "ssm":
            di, N = self.d_inner, self.ssm_state
            blk = 2 * d * di + di * 4 + di * (2 * N + 2) + di * d  # in/conv/ssm/out
            att = 0
            mlp = 0
        else:
            mlp = 3 * d * f
            blk = att + mlp
        if self.is_moe:
            blk = att + self.n_experts * 3 * d * f + d * self.n_experts
        if self.family == "hybrid":
            di, N = self.d_inner, self.ssm_state
            blk = 2 * d * di + di * (2 * N + 2) + di * d
        emb = V * d * (1 if self.tie_embeddings else 2)
        enc = self.n_enc_layers * (att + mlp) if self.n_enc_layers else 0
        return L * blk + emb + enc

    def n_active_params(self) -> int:
        """Params touched per token (MoE: top_k of n_experts)."""
        if not self.is_moe:
            return self.n_params()
        d, f, L = self.d_model, self.d_ff, self.n_layers
        hd, H, Hkv = self.hd, self.n_heads, self.n_kv_heads
        att = d * H * hd + 2 * d * Hkv * hd + H * hd * d
        blk = att + self.top_k * 3 * d * f + d * self.n_experts
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        return L * blk + emb


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                    # train | prefill | decode


SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", 4096, 256, "train"),
    ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    ShapeConfig("decode_32k", 32768, 128, "decode"),
    ShapeConfig("long_500k", 524288, 1, "decode"),
)


def reduced(cfg: ArchConfig, **over) -> ArchConfig:
    """Tiny same-family config for CPU smoke tests (per assignment)."""
    kw = dict(
        n_layers=min(cfg.n_layers, 2),
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 4) if cfg.n_kv_heads else 0,
        d_ff=128,
        vocab=256,
        head_dim=16,
        n_experts=min(cfg.n_experts, 4) if cfg.n_experts else 0,
        top_k=min(cfg.top_k, 2) if cfg.top_k else 0,
        ssm_state=min(cfg.ssm_state, 8) if cfg.ssm_state else 0,
        ssm_head_dim=16 if cfg.ssm_version == 2 else cfg.ssm_head_dim,
        swa_window=64 if cfg.swa_window else 0,
        hybrid_period=2 if cfg.hybrid_period else 0,
        n_enc_layers=2 if cfg.n_enc_layers else 0,
        enc_seq=32 if cfg.enc_seq else 0,
    )
    kw.update(over)
    return replace(cfg, **kw)
