"""zamba2-2.7b: mamba2 backbone + weight-shared attention block.
[arXiv:2411.15242; hf]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-2.7b", family="hybrid",
    n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32,
    d_ff=10240, vocab=32000, ssm_state=64, ssm_version=2,
    ssm_head_dim=64, hybrid_period=6,
)
