"""Robust incremental (weighted) mean/variance algebra — paper §3.

Implements Welford's update (Eqs. 2-3), the Chan et al. parallel *merge*
(Eqs. 4-5) and the paper's new *subtraction* of partial estimates
(Eqs. 6-7), all as pure, vectorized JAX functions over a (n, mean, M2)
triple.  The triple is carried as a plain dict pytree so it shards, vmaps
and scans transparently.

The merge operator is associative and commutative, which makes it a legal
XLA/collective reduction operator: it powers the cross-device sketch
merges in ``repro.core.sketch`` and the prefix scans used by the QO split
query.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

Stats = Dict[str, jax.Array]  # {"n": f, "mean": f, "m2": f}

__all__ = [
    "init",
    "from_single",
    "observe",
    "merge",
    "subtract",
    "variance",
    "stddev",
    "zeros_like",
    "from_batch",
]


def init(shape=(), dtype=jnp.float32) -> Stats:
    """Empty statistics (n=0). Identity element of :func:`merge`."""
    z = jnp.zeros(shape, dtype)
    return {"n": z, "mean": z, "m2": z}


def zeros_like(s: Stats) -> Stats:
    return jax.tree.map(jnp.zeros_like, s)


def from_single(y, w=1.0) -> Stats:
    """Statistics of a single (optionally weighted) observation."""
    y = jnp.asarray(y, jnp.float32)
    w = jnp.broadcast_to(jnp.asarray(w, jnp.float32), y.shape)
    return {"n": w, "mean": y, "m2": jnp.zeros_like(y)}


def observe(s: Stats, y, w=1.0) -> Stats:
    """Welford single-observation update (paper Eqs. 2-3), weighted.

    mean_n = mean_{n-1} + w*(y - mean_{n-1})/n
    M2_n   = M2_{n-1} + w*(y - mean_{n-1})*(y - mean_n)
    """
    y = jnp.asarray(y, jnp.float32)
    w = jnp.asarray(w, jnp.float32)
    n = s["n"] + w
    safe_n = jnp.where(n > 0, n, 1.0)
    d_pre = y - s["mean"]
    mean = s["mean"] + w * d_pre / safe_n
    m2 = s["m2"] + w * d_pre * (y - mean)
    return {"n": n, "mean": mean, "m2": m2}


def merge(a: Stats, b: Stats) -> Stats:
    """Chan et al. parallel merge (paper Eqs. 4-5); handles empty operands.

    n_AB    = n_A + n_B
    mean_AB = (n_A mean_A + n_B mean_B) / n_AB
    M2_AB   = M2_A + M2_B + delta^2 * n_A n_B / n_AB
    """
    n = a["n"] + b["n"]
    safe_n = jnp.where(n > 0, n, 1.0)
    delta = b["mean"] - a["mean"]
    mean = (a["n"] * a["mean"] + b["n"] * b["mean"]) / safe_n
    m2 = a["m2"] + b["m2"] + delta * delta * (a["n"] * b["n"]) / safe_n
    # keep the identity exact when both sides are empty
    mean = jnp.where(n > 0, mean, 0.0)
    m2 = jnp.where(n > 0, m2, 0.0)
    return {"n": n, "mean": mean, "m2": m2}


def subtract(ab: Stats, b: Stats) -> Stats:
    """Paper Eqs. 6-7: recover A = AB - B from whole and partial stats.

    n_A    = n_AB - n_B
    mean_A = (n_AB mean_AB - n_B mean_B) / n_A
    M2_A   = M2_AB - M2_B - delta^2 * n_A n_B / n_AB
    with delta = mean_B - mean_A.
    """
    n_a = ab["n"] - b["n"]
    safe_na = jnp.where(n_a > 0, n_a, 1.0)
    mean_a = (ab["n"] * ab["mean"] - b["n"] * b["mean"]) / safe_na
    delta = b["mean"] - mean_a
    safe_nab = jnp.where(ab["n"] > 0, ab["n"], 1.0)
    m2_a = ab["m2"] - b["m2"] - delta * delta * (n_a * b["n"]) / safe_nab
    mean_a = jnp.where(n_a > 0, mean_a, 0.0)
    # numerical floor: M2 is a sum of squares, clamp tiny negatives
    m2_a = jnp.where(n_a > 0, jnp.maximum(m2_a, 0.0), 0.0)
    return {"n": n_a, "mean": mean_a, "m2": m2_a}


def variance(s: Stats, ddof: int = 1) -> jax.Array:
    """Sample variance s^2 = M2/(n-ddof); 0 where undefined (n<=ddof)."""
    denom = s["n"] - ddof
    return jnp.where(denom > 0, s["m2"] / jnp.where(denom > 0, denom, 1.0), 0.0)


def stddev(s: Stats, ddof: int = 1) -> jax.Array:
    return jnp.sqrt(jnp.maximum(variance(s, ddof), 0.0))


def from_batch(y: jax.Array, w=None, axis=0) -> Stats:
    """Exact batch statistics along ``axis`` (two-pass; used for oracles and
    for folding a whole tile into one Stats before a merge)."""
    y = jnp.asarray(y, jnp.float32)
    if w is None:
        n = jnp.asarray(y.shape[axis], jnp.float32)
        n = jnp.broadcast_to(n, y.sum(axis=axis).shape)
        mean = y.mean(axis=axis)
        m2 = ((y - jnp.expand_dims(mean, axis)) ** 2).sum(axis=axis)
        return {"n": n, "mean": mean, "m2": m2}
    w = jnp.asarray(w, jnp.float32)
    n = w.sum(axis=axis)
    safe_n = jnp.where(n > 0, n, 1.0)
    mean = (w * y).sum(axis=axis) / safe_n
    m2 = (w * (y - jnp.expand_dims(mean, axis)) ** 2).sum(axis=axis)
    mean = jnp.where(n > 0, mean, 0.0)
    return {"n": n, "mean": mean, "m2": m2}


def stack(stats_list) -> Stats:
    return jax.tree.map(lambda *xs: jnp.stack(xs), *stats_list)


def tree_reduce_merge(s: Stats, axis=0) -> Stats:
    """Reduce a stacked Stats along ``axis`` with the Chan merge.

    Uses a log-depth pairwise tree (matches how a real all-reduce combines
    partial estimates and is the numerically preferred order).
    """
    def move(s_):
        return jax.tree.map(lambda x: jnp.moveaxis(x, axis, 0), s_)

    s = move(s)

    def body(s_):
        k = s_["n"].shape[0]
        half = k // 2
        a = jax.tree.map(lambda x: x[:half], s_)
        b = jax.tree.map(lambda x: x[half : 2 * half], s_)
        m = merge(a, b)
        if k % 2:
            tail = jax.tree.map(lambda x: x[-1:], s_)
            m = jax.tree.map(lambda x, t: jnp.concatenate([x, t], 0), m, tail)
        return m

    while s["n"].shape[0] > 1:
        s = body(s)
    return jax.tree.map(lambda x: x[0], s)
