"""Split-decision backends: fixed-n Hoeffding bound vs anytime-valid
e-process (DESIGN.md §2.7).

The third stage of the tree hot path (route -> absorb -> attempt) ends in
a *decision*: given the (M, F) merit table the compacted query produced,
which attempting leaves actually split, and on which feature?  This
module is that decision stage, factored out of
:mod:`repro.core.hoeffding` so the tree and the folded forest share ONE
vmappable implementation, selected by ``HTRConfig.decision_backend``:

* ``"hoeffding"`` (default) — the FIMT ratio test the repo has always
  shipped, bit-identical to the pre-factoring trees: split when
  ``vr2/vr1 < 1 - eps`` with ``eps = sqrt(ln(1/delta) / (2 n))`` or when
  ``eps < tau`` (tie break).  The bound is a FIXED-n guarantee: it
  controls the error of ONE look at the statistics.  Under the §2.5
  ``eager`` schedule (and under any re-attempt cadence) the same leaf is
  tested again and again as mass accumulates, so the realized false-split
  rate is a union over looks and silently exceeds ``delta`` — the
  continuous-peeking defect this module exists to fix (Amoukou et al.,
  PAPERS.md).

* ``"anytime"`` — an e-value / confidence-sequence test that stays valid
  at EVERY look.  Each (leaf, feature) pair carries a running e-process
  over the *variance-explained fraction* ``eta_f = VR_f / sigma^2_leaf``
  (the scale-free signal strength of a candidate split; ~``c·log(F·C)/n``
  on pure noise from the max-over-candidates selection effect, a
  constant on real structure).  At every look the process bets the fresh
  mass ``dn`` absorbed since the previous look against a
  selection-corrected null mean:

      log E_f  +=  dn * ( lam * (eta_f - mu0(n))  -  lam^2 / 8 )

  the Hoeffding-supermartingale increment for ``dn`` bounded
  observations (Ville's inequality then bounds the crossing probability
  of ``E >= 1/alpha`` under the null by ``alpha``, *uniformly over
  looks* — peeking every batch costs nothing).  A leaf splits on its
  merit champion ``f* = argmax_f VR_f`` once ``log E_{f*}`` crosses
  ``log(1/alpha)``.  There is NO tie-break clause: near-equal genuinely
  good features both accumulate evidence and the champion crosses —
  the ratio test's stall (and its noise-splitting ``eps < tau`` escape
  hatch, a guaranteed false split on any long noise stream) does not
  exist in this geometry.

The e-process state rides the TreeState pytree as two ordinary leaves —
``dec_logE`` (M, F) and ``dec_n_last`` (M,) — so it vmaps over the
forest's tree axis, shards under ``forest_state_specs``, round-trips
through the checkpointer, and stays replicated under the §4.1
data-parallel protocol for free (attempts — and therefore every decision
-state update — only execute on merged statistics at sync boundaries,
identically on every shard).  Both backends carry the same leaves
(inert zeros under ``"hoeffding"``), so the backend knob never changes
the state treedef and cannot fragment any shape-keyed jit cache.

Shared by both backends: merit sanitization (NaN -> -inf, random-subspace
feature masking) and the degenerate-leaf guard — a leaf whose merit row
has fewer than two finite entries must not pass the *ratio* test (with a
single candidate the runner-up merit is -inf, the ratio collapses to 0
and any positive merit "wins" unopposed); the per-feature e-process
needs no such guard, since it never compares features.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import stats

__all__ = ["DECISION_BACKENDS", "decision_state", "DECISION_KEYS",
           "sanitize_merit", "decide", "E_LAMBDA", "E_SEL", "E_MARGIN"]

DECISION_BACKENDS = ("hoeffding", "anytime")

#: names of the decision-state leaves every TreeState carries
DECISION_KEYS = ("dec_logE", "dec_n_last")

# e-process constants (module-level, not config: they parameterize the
# supermartingale construction, not the user-facing risk contract)
E_LAMBDA = 0.3   # betting fraction lam in (0, 1]: larger = faster
#                  accumulation on strong signal but a larger -lam^2/8
#                  drag that starves weak-signal leaves (the
#                  benchmarks/false_splits.py sweep picked this point)
E_SEL = 2.0      # selection-correction multiplier: the null mean of
#                  eta = max-over-(F*C)-candidates VR / sigma^2 scales
#                  like log(F*C)/n on noise; E_SEL covers its tail
E_MARGIN = 0.01  # practical-null floor on eta: variance fractions below
#                  this are never worth a split, whatever n says


def decision_state(M: int, F: int) -> dict:
    """Fresh decision-stage leaves for an (M-node, F-feature) tree.

    ``dec_logE``   (M, F) f32 — running log e-value per (leaf, feature)
                   (0 = no evidence; floored at 0, see :func:`decide`);
    ``dec_n_last`` (M,) f32  — leaf weight mass at the leaf's previous
                   look (so the next look bets only the FRESH mass).
    Both stay identically zero under the Hoeffding backend.
    """
    return {"dec_logE": jnp.zeros((M, F), jnp.float32),
            "dec_n_last": jnp.zeros((M,), jnp.float32)}


def sanitize_merit(merit, feat_mask=None):
    """NaN merits -> -inf; features outside the subspace mask -> -inf.

    The query reports -inf for masked/non-attempting tables already, but
    a NaN can escape degenerate table arithmetic — and a NaN poisons
    ``top_k``/``argmax`` ordering, so the decision stage normalizes
    before ANY backend looks at the table.
    """
    merit = jnp.where(jnp.isnan(merit), -jnp.inf, merit)
    if feat_mask is not None:
        merit = jnp.where(feat_mask[None, :], merit, -jnp.inf)
    return merit


def _hoeffding_want(cfg, state, merit, attempt):
    """The pre-factoring FIMT ratio test, op-for-op (bit-identity pin),
    plus the degenerate-leaf guard.  Returns (want, {}) — the Hoeffding
    backend carries no decision state."""
    top2 = jax.lax.top_k(merit, 2)[0]                       # (M, 2)
    vr1, vr2 = top2[:, 0], top2[:, 1]
    n_leaf = jnp.maximum(state["ystats"]["n"], 1.0)
    eps = jnp.sqrt(jnp.log(1.0 / cfg.delta) / (2.0 * n_leaf))
    ratio = jnp.where(vr1 > 0, jnp.maximum(vr2, 0.0) / vr1, 1.0)
    decide_ = (ratio < 1.0 - eps) | (eps < cfg.tau)
    # degenerate-leaf guard: the ratio test compares champion vs
    # runner-up, so it is only meaningful when at least two features
    # offer a real (finite-merit) candidate — with one, ratio == 0 and
    # any positive merit splits unopposed (tests/test_decide.py pins the
    # failure this prevents)
    n_finite = jnp.sum(jnp.isfinite(merit), axis=1)
    want = attempt & decide_ & jnp.isfinite(vr1) & (vr1 > 0) \
        & (n_finite >= 2)
    return want, {}


def _anytime_want(cfg, state, merit, attempt):
    """Per-(leaf, feature) e-process update + threshold crossing.

    One look = one call with ``attempt`` marking the looking leaves; the
    e-process leaves of every non-attempting leaf are untouched (their
    fresh mass keeps accruing and is bet at their next look).  Returns
    (want, updated decision leaves).
    """
    M, F = merit.shape
    finite = jnp.isfinite(merit)
    n_leaf = state["ystats"]["n"]                            # (M,)
    sigma2 = jnp.maximum(stats.variance(state["ystats"]), 1e-12)
    eta = jnp.clip(jnp.where(finite, merit, 0.0) / sigma2[:, None],
                   0.0, 1.0)                                 # (M, F)
    # selection-corrected null mean: on pure noise the best of ~F*C
    # candidate boundaries explains ~log(F*C)/n of the variance by
    # overfitting alone; real structure keeps eta bounded away from 0.
    # C is the observer's slot count (n_bins dense, sketch_k sketched) —
    # a K-slot sketch offers fewer candidate boundaries, and the
    # correction must track the layout actually in play
    safe_n = jnp.maximum(n_leaf, 1.0)
    mu0 = E_MARGIN + E_SEL * jnp.log(float(max(cfg.n_features, 2)
                                           * cfg.observer_bins())) / safe_n
    dn = jnp.maximum(n_leaf - state["dec_n_last"], 0.0)      # fresh mass
    inc = dn[:, None] * (E_LAMBDA * (eta - mu0[:, None])
                         - E_LAMBDA * E_LAMBDA / 8.0)
    # floor at 0: a feature whose evidence collapses restarts its bet
    # instead of digging an unbounded hole (the standard restart trick;
    # the harness pins the realized alpha empirically)
    logE = jnp.maximum(state["dec_logE"] + jnp.where(finite, inc, 0.0),
                       0.0)
    look = attempt[:, None]
    logE = jnp.where(look, logE, state["dec_logE"])
    n_last = jnp.where(attempt, n_leaf, state["dec_n_last"])

    best_f = jnp.argmax(merit, axis=1)                       # (M,)
    vr1 = jnp.take_along_axis(merit, best_f[:, None], 1)[:, 0]
    crossed = jnp.take_along_axis(logE, best_f[:, None], 1)[:, 0] \
        >= jnp.log(1.0 / cfg.alpha)
    want = attempt & crossed & jnp.isfinite(vr1) & (vr1 > 0)
    return want, {"dec_logE": logE, "dec_n_last": n_last}


def decide(cfg, state, merit, attempt, feat_mask=None):
    """Which attempting leaves split, on which feature — one batched call.

    cfg: :class:`repro.core.hoeffding.HTRConfig` (``decision_backend``
    selects the test); state: the TreeState (reads ``ystats`` and the
    ``dec_*`` leaves); merit: (M, F) from
    :func:`repro.kernels.ops.forest_best_splits` (-inf = no candidate);
    attempt: (M,) bool look mask; feat_mask: optional (F,) bool
    random-subspace mask.

    Returns ``(want, best_f, dec_new)``: (M,) bool split decisions, the
    (M,) i32 merit champion per leaf, and the dict of updated decision
    -state leaves (empty under ``"hoeffding"``) for the caller to fold
    into the new state.  Decisions depend only on attempting rows'
    merits, so the compacted and full-scan query paths produce bitwise
    identical outcomes (tests/test_decide.py).
    """
    merit = sanitize_merit(merit, feat_mask)
    best_f = jnp.argmax(merit, axis=1)
    if cfg.decision_backend == "hoeffding":
        want, dec_new = _hoeffding_want(cfg, state, merit, attempt)
    else:
        assert cfg.decision_backend == "anytime", cfg.decision_backend
        want, dec_new = _anytime_want(cfg, state, merit, attempt)
    return want, best_f, dec_new
