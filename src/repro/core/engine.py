"""Continuous-serving engine: zero-downtime snapshot hot-swap under load
(DESIGN.md §5.6).

After PR 4 the repo could *freeze* and *serve*; after PR 5 it could
*train at scale* — but nothing owned the lifecycle between the two.
:class:`ServingEngine` is that owner: one object that runs
train-and-serve concurrently and stays up through the faults a real
deployment throws at it.

**Admission queue.**  Requests arrive open-loop (ragged row counts,
bursty rates) through :meth:`ServingEngine.submit`, which hands back a
:class:`Ticket` immediately.  Admission is bounded by
``cfg.max_queue_rows``: a request that would overflow is SHED at the
door — its ticket resolves ``shed`` and the ``shed_requests`` /
``shed_rows`` counters advance — never silently dropped and never
allowed to grow the queue without bound (backpressure by load
shedding, the only graceful answer an open-loop process permits).
Admitted tickets are packed FIFO into serving batches of up to
``cfg.max_batch_rows`` rows; the batch then rides
:func:`repro.core.serve.predict_snapshot`, whose pow-2 padding lands it
exactly on the cached-jit batch buckets PR 4's dispatch keys on — many
small requests cost one dispatch, and a steady mix of request sizes
never recompiles.

**Atomic publish.**  The trainer periodically
:func:`repro.core.serve.freeze`\\ s its live state into a versioned
:class:`~repro.core.serve.Snapshot` and offers it to
:meth:`ServingEngine.publish`.  The publish path is the robustness
choke point: the candidate passes the fault-injection hook (where tests
corrupt/drop/delay it), then :func:`repro.core.serve.validate_snapshot`
(the rollback gate — an invalid snapshot is counted and DISCARDED, the
last good version keeps serving), then a monotone-version check, and
only then is it swapped in — a single reference assignment of an
immutable record, so a concurrent server thread sees either the old
snapshot or the new one, never a torn mix.  In-flight batches pinned
the old record before the swap and drain on it unharmed.

**Fault tolerance.**  A :class:`repro.core.faults.FaultInjector` hooks
``trainer.step`` / ``publish`` / ``ckpt.save``.  A trainer killed
mid-sync-window is caught, counted, and recovered: state restores from
the newest *valid* checkpoint (:meth:`Checkpointer.restore_latest`
skips corrupt ones), the stream rewinds to that step, and the restored
model is re-published immediately — so serving continues from a
validated snapshot throughout and fresh publishes resume within one
sync window of the restart.  A staleness watchdog tracks the age of the
published snapshot against the ``sync_every`` cadence and raises the
``stale`` flag (plus a ``stale_events`` counter) when freshness falls
``cfg.staleness_factor`` windows behind — surfacing silent publish
loss (dropped publishes, a wedged trainer) that no exception ever
reports.

The engine is a deterministic state machine first and threads second:
:meth:`train_once` / :meth:`serve_once` single-step the two loops (what
tests/test_engine.py drives), and :meth:`start` / :meth:`stop` run the
same methods on daemon threads for the open-loop deployment shape
(examples/engine_stream.py, benchmarks/engine.py).
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from repro.core import faults as fl
from repro.core import serve as sv

__all__ = ["EngineConfig", "Ticket", "ServingEngine"]


@dataclass(frozen=True)
class EngineConfig:
    """Static engine knobs.

    sync_every:       trainer batches between freeze+publish boundaries
                      (the freshness cadence; ROADMAP's staleness knob).
    ckpt_every:       publishes between checkpoint saves (0 = never).
    max_queue_rows:   admission bound — rows queued beyond this are shed.
    max_batch_rows:   serving pack cap — queued tickets are concatenated
                      up to this many rows per dispatch (pow-2 bucketed
                      downstream by ``predict_snapshot``).
    keep_versions:    published snapshots retained for drain/rollback
                      audits (``snapshot_for_version``).
    staleness_factor: ``stale`` when the published snapshot's age exceeds
                      ``staleness_factor * sync_every`` trainer steps.
    backend:          kernel backend for serving (None = platform auto).
    """
    sync_every: int = 4
    ckpt_every: int = 1
    max_queue_rows: int = 8192
    max_batch_rows: int = 2048
    keep_versions: int = 4
    staleness_factor: float = 3.0
    backend: Optional[str] = None


class Ticket:
    """One admitted (or shed) request: a thread-safe future.

    ``status``: ``"queued" | "done" | "shed"``.  ``wait(timeout)``
    blocks until resolution; ``result`` is the (B,) f32 predictions,
    ``version`` the snapshot version that served them (the bit-identity
    pin: ``predict_snapshot(engine.snapshot_for_version(t.version), X)``
    must equal ``t.result`` exactly), ``latency_s`` the submit→resolve
    wall time.
    """

    __slots__ = ("X", "status", "result", "version", "t_submit", "t_done",
                 "_event")

    def __init__(self, X: np.ndarray):
        self.X = X
        self.status = "queued"
        self.result: Optional[np.ndarray] = None
        self.version: Optional[int] = None
        self.t_submit = time.perf_counter()
        self.t_done: Optional[float] = None
        self._event = threading.Event()

    @property
    def rows(self) -> int:
        return self.X.shape[0]

    @property
    def latency_s(self) -> Optional[float]:
        return None if self.t_done is None else self.t_done - self.t_submit

    def wait(self, timeout: Optional[float] = None) -> bool:
        return self._event.wait(timeout)

    def _resolve(self, status: str, result=None, version=None):
        self.status = status
        self.result = result
        self.version = version
        self.t_done = time.perf_counter()
        self._event.set()


class _Published:
    """Immutable published record — the single swapped reference.

    Readers grab ``engine._published`` ONCE per serving batch; because
    the record never mutates after construction, that one read pins a
    consistent (snapshot, version, step, wall-clock) tuple no matter
    when the publisher swaps the attribute underneath them.
    """

    __slots__ = ("snap", "version", "step", "wall")

    def __init__(self, snap: sv.Snapshot, version: int, step: int):
        self.snap = snap
        self.version = version
        self.step = step
        self.wall = time.monotonic()


class ServingEngine:
    """Concurrent train-and-serve over one model lineage.

    ``cfg_model``: a :class:`repro.core.forest.ForestConfig` (its
    ``"trees"``-keyed state) or a :class:`repro.core.hoeffding.HTRConfig`
    (single tree) — anything :func:`repro.core.serve.freeze` packs.
    ``state``: the initial trained-or-fresh model pytree.
    ``stream``: ``stream(step) -> (X, y) | None`` — a *deterministic*
    batch source indexed by trainer step (None = exhausted).  Indexing by
    step is what makes crash-recovery exact: after a restore to step s
    the trainer replays the stream from s, identically.
    ``checkpointer``: optional :class:`repro.checkpoint.ckpt.Checkpointer`
    — without one, recovery restarts from the in-memory state instead.
    ``injector``: optional :class:`repro.core.faults.FaultInjector`.

    The constructor publishes version 1 from the initial state, so the
    engine serves from its very first request — publish is a hot-SWAP,
    never a cold start.
    """

    def __init__(self, cfg_model, state, stream: Callable, *,
                 cfg: EngineConfig = EngineConfig(),
                 checkpointer=None, injector: Optional[fl.FaultInjector] = None):
        self.cfg = cfg
        self._model_cfg = cfg_model
        self._state = state
        self._stream = stream
        self._ckpt = checkpointer
        self._injector = injector or fl.FaultInjector()

        self._trainer_step = 0
        self._queue: List[Ticket] = []
        self._queued_rows = 0
        self._q_lock = threading.Lock()
        self._q_event = threading.Event()
        self._pub_lock = threading.Lock()
        self._published: Optional[_Published] = None
        self._versions: Dict[int, sv.Snapshot] = {}
        self._stop = threading.Event()
        self._threads: List[threading.Thread] = []
        self._m_lock = threading.Lock()
        self._metrics = {
            "admitted_requests": 0, "admitted_rows": 0,
            "served_requests": 0, "served_rows": 0, "serve_batches": 0,
            "shed_requests": 0, "shed_rows": 0,
            "publishes": 0, "publish_failures": 0, "rollbacks": 0,
            "publishes_dropped": 0, "trainer_crashes": 0, "recoveries": 0,
            "ckpt_failures": 0, "stale_events": 0, "max_queue_rows_seen": 0,
        }
        self.publish_from_state()            # version 1: never cold-start
        assert self._published is not None

    # -- metrics ----------------------------------------------------------

    def _bump(self, **kv):
        with self._m_lock:
            for k, v in kv.items():
                self._metrics[k] += v

    def metrics(self) -> Dict[str, Any]:
        """Counter snapshot + the staleness watchdog's current verdict."""
        with self._m_lock:
            out = dict(self._metrics)
        out.update(self.staleness())
        return out

    def staleness(self) -> Dict[str, Any]:
        """Snapshot age vs the ``sync_every`` cadence (the watchdog).

        ``age_steps`` = trainer steps since the published snapshot was
        frozen; ``stale`` flips when it exceeds
        ``staleness_factor * sync_every`` — the signature of dropped
        publishes or a wedged trainer, which no exception surfaces.
        """
        rec = self._published
        age_steps = self._trainer_step - rec.step
        limit = self.cfg.staleness_factor * self.cfg.sync_every
        return {
            "published_version": rec.version,
            "published_step": rec.step,
            "age_steps": age_steps,
            "age_s": time.monotonic() - rec.wall,
            "stale": age_steps > limit,
        }

    # -- publish path -----------------------------------------------------

    @property
    def published_version(self) -> int:
        return self._published.version

    def snapshot_for_version(self, version: int) -> sv.Snapshot:
        """A retained published snapshot by version (audit/bit-identity
        hook; the last ``cfg.keep_versions`` publishes are retained)."""
        return self._versions[version]

    def publish_from_state(self) -> bool:
        """Freeze the live trainer state and offer it for publication."""
        with self._pub_lock:
            version = (self._published.version + 1) if self._published else 1
        snap = sv.freeze(self._state, version=version,
                         step=self._trainer_step)
        return self.publish(snap)

    def publish(self, snap: sv.Snapshot) -> bool:
        """Validate → atomically swap; False = rejected (rollback).

        The candidate first passes the ``publish`` fault site (tests
        corrupt/drop/delay it there), then the
        :func:`~repro.core.serve.validate_snapshot` invariants and a
        monotone-version gate.  Any failure leaves the previous snapshot
        serving (that IS the rollback — the reference never moved) and
        advances ``publish_failures`` / ``rollbacks``.  Success swaps
        one immutable record under ``_pub_lock`` and retains the
        version for audits.
        """
        try:
            snap = self._injector.fire("publish", snap)
        except fl.DropSignal:
            self._bump(publishes_dropped=1)
            return False
        try:
            sv.validate_snapshot(snap)
            with self._pub_lock:
                if (self._published is not None
                        and int(np.asarray(snap.version))
                        <= self._published.version):
                    raise sv.SnapshotValidationError(
                        f"version {int(np.asarray(snap.version))} is not "
                        f"past published v{self._published.version}")
                rec = _Published(snap, int(np.asarray(snap.version)),
                                 int(np.asarray(snap.step)))
                self._published = rec          # THE atomic hot-swap
                self._versions[rec.version] = snap
                while len(self._versions) > self.cfg.keep_versions:
                    del self._versions[min(self._versions)]
        except sv.SnapshotValidationError:
            self._bump(publish_failures=1, rollbacks=1)
            return False
        self._bump(publishes=1)
        if self._ckpt is not None and self.cfg.ckpt_every \
                and self._metrics["publishes"] % self.cfg.ckpt_every == 0:
            self._checkpoint()
        return True

    def _checkpoint(self):
        try:
            self._injector.fire("ckpt.save")
            self._ckpt.save(self._trainer_step, self._state, blocking=True)
        except Exception:
            # a failed save must never take the trainer down: the last
            # good checkpoint is still on disk and restore skips torn ones
            self._bump(ckpt_failures=1)

    # -- trainer ----------------------------------------------------------

    def train_once(self) -> bool:
        """One trainer batch (False = stream exhausted).

        Absorbs ``stream(step)``, advances the step, and at every
        ``sync_every`` boundary freezes + publishes.  Any exception out
        of the step — injected kill or organic — is caught, counted in
        ``trainer_crashes``, and answered with :meth:`recover`; the
        engine keeps serving the published snapshot throughout.
        """
        batch = self._stream(self._trainer_step)
        if batch is None:
            return False
        try:
            self._injector.fire("trainer.step")
            self._state = self._train_step(batch)
            self._trainer_step += 1
            if self._trainer_step % self.cfg.sync_every == 0:
                self.publish_from_state()
            elif self.staleness()["stale"]:
                self._bump(stale_events=1)
        except Exception:
            self._bump(trainer_crashes=1)
            self.recover()
        return True

    def _train_step(self, batch):
        X, y = batch
        if "trees" in self._state:
            from repro.core import forest as fr
            state, _aux = fr.update(self._model_cfg, self._state, X, y)
        else:
            from repro.core import hoeffding as ht
            state = ht.update(self._model_cfg, self._state, X, y)
        return state

    def recover(self):
        """Crash recovery: restore the newest valid checkpoint (or fall
        back to the in-memory state), rewind the stream to its step, and
        RE-PUBLISH immediately — a validated snapshot of the restored
        model goes live within one publish, and the normal cadence
        resumes from there (fresh publishes within one sync window)."""
        if self._ckpt is not None:
            try:
                template = jax.eval_shape(lambda: self._state)
                state, step = self._ckpt.restore_latest(
                    template, return_step=True)
                self._state, self._trainer_step = state, step
            except FileNotFoundError:
                pass                      # no valid checkpoint: keep memory
        self._bump(recoveries=1)
        self.publish_from_state()

    # -- admission + serving ----------------------------------------------

    def submit(self, X) -> Ticket:
        """Admit a request (or shed it) — never blocks on service.

        Admission is all-or-nothing per request: if the queue cannot
        hold the WHOLE request under ``max_queue_rows``, the ticket
        resolves ``shed`` immediately and the shed counters advance by
        exactly this request — the excess is counted, not dropped.
        """
        X = np.asarray(X, np.float32)
        assert X.ndim == 2, X.shape
        t = Ticket(X)
        with self._q_lock:
            if self._queued_rows + t.rows > self.cfg.max_queue_rows:
                admitted = False
            else:
                admitted = True
                self._queue.append(t)
                self._queued_rows += t.rows
                depth = self._queued_rows
        if admitted:
            self._bump(admitted_requests=1, admitted_rows=t.rows)
            with self._m_lock:
                if depth > self._metrics["max_queue_rows_seen"]:
                    self._metrics["max_queue_rows_seen"] = depth
            self._q_event.set()
        else:
            self._bump(shed_requests=1, shed_rows=t.rows)
            t._resolve("shed")
        return t

    @property
    def queued_rows(self) -> int:
        return self._queued_rows

    def serve_once(self) -> int:
        """Drain one packed batch; returns rows served (0 = queue empty).

        Pops FIFO tickets until the pack would exceed ``max_batch_rows``
        (always at least one), pins the published record with ONE read,
        serves the concatenated rows through ``predict_snapshot`` (pow-2
        bucketed, cached jit), and splits the predictions back per
        ticket.  Per-row predictions are independent of batch packing,
        so every ticket's rows are bit-identical to a standalone
        ``predict_snapshot`` on its pinned version.
        """
        with self._q_lock:
            if not self._queue:
                self._q_event.clear()
                return 0
            batch, rows = [], 0
            while self._queue and (not batch or
                    rows + self._queue[0].rows <= self.cfg.max_batch_rows):
                t = self._queue.pop(0)
                batch.append(t)
                rows += t.rows
            self._queued_rows -= rows
        rec = self._published                   # the one pinned read
        X = batch[0].X if len(batch) == 1 else \
            np.concatenate([t.X for t in batch], axis=0)
        y = np.asarray(sv.predict_snapshot(rec.snap, X,
                                           backend=self.cfg.backend))
        off = 0
        for t in batch:
            t._resolve("done", y[off:off + t.rows], rec.version)
            off += t.rows
        self._bump(served_requests=len(batch), served_rows=rows,
                   serve_batches=1)
        return rows

    # -- threaded mode -----------------------------------------------------

    def start(self):
        """Run the trainer and server loops on daemon threads — the
        deployment shape.  Both loops are the single-step methods above
        in a while-loop, so threaded and stepped execution share every
        code path."""
        assert not self._threads, "engine already started"
        self._stop.clear()

        def _server():
            while not self._stop.is_set():
                if self.serve_once() == 0:
                    self._q_event.wait(timeout=0.005)

        def _trainer():
            while not self._stop.is_set():
                if not self.train_once():
                    break
                time.sleep(0)                  # yield to the server

        self._threads = [
            threading.Thread(target=_server, name="engine-server",
                             daemon=True),
            threading.Thread(target=_trainer, name="engine-trainer",
                             daemon=True),
        ]
        for t in self._threads:
            t.start()

    def stop(self, drain: bool = True, timeout: float = 30.0):
        """Stop the loops; ``drain=True`` first serves every queued
        ticket (in-flight requests complete on the published snapshot —
        zero-downtime includes shutdown)."""
        if drain:
            deadline = time.monotonic() + timeout
            while self._queued_rows and time.monotonic() < deadline:
                time.sleep(0.002)
        self._stop.set()
        self._q_event.set()
        for t in self._threads:
            t.join(timeout=timeout)
        self._threads = []
        while drain and self.serve_once():
            pass                                # whatever the race left
