"""E-BST and Truncated E-BST baselines (paper §1/§5) as array BSTs.

Faithful to Ikonomovska et al.'s Extended Binary Search Tree:

* each node stores a key ``x_v`` and target statistics for every
  observation with ``x <= x_v`` that passed through the node;
* insertion walks the BST (O(depth)), updating the ``<=`` statistics along
  the path (here with the robust (n, mean, M2) algebra of §3 instead of the
  unstable naive sums — the paper upgrades *all* compared AOs this way);
* the split-candidate query is a faithful in-order traversal with an
  explicit stack, accumulating left-context statistics exactly like the
  recursive FIMT algorithm.

TE-BST truncates inputs to ``decimals`` places before insertion (paper §5.2
uses 3), which bounds the number of distinct keys.

Pointer structures do not exist under ``jit``: nodes live in fixed-capacity
arrays, children are int32 indices, and both insert and query are
``lax.while_loop``s.  When capacity is exhausted, further values only update
statistics along their search path (graceful degradation, noted in
EXPERIMENTS.md).
"""
from __future__ import annotations

import functools
from typing import Dict

import jax
import jax.numpy as jnp

from repro.core import stats
from repro.core.qo import SplitResult

EBST = Dict[str, jax.Array]

__all__ = ["init", "update", "best_split", "n_elements"]

_NIL = jnp.int32(-1)


def init(capacity: int, decimals: int = -1) -> EBST:
    """Empty E-BST. ``decimals >= 0`` makes it a TE-BST (truncation)."""
    cap = capacity
    return {
        "key": jnp.zeros((cap,), jnp.float32),
        "left": jnp.full((cap,), _NIL),
        "right": jnp.full((cap,), _NIL),
        "le": stats.init((cap,)),  # stats of values <= key through this node
        "size": jnp.int32(0),
        "total": stats.init(()),
        "decimals": jnp.int32(decimals),
    }


def _quantize_key(t: EBST, x):
    scale = jnp.power(10.0, t["decimals"].astype(jnp.float32))
    return jnp.where(t["decimals"] >= 0, jnp.round(x * scale) / scale, x)


def _insert_one(t: EBST, x, y):
    x = _quantize_key(t, jnp.asarray(x, jnp.float32))
    y = jnp.asarray(y, jnp.float32)
    cap = t["key"].shape[0]

    t = dict(t, total=stats.observe(t["total"], y))

    def empty_case(t):
        t = dict(t)
        t["key"] = t["key"].at[0].set(x)
        t["le"] = jax.tree.map(lambda a, b: a.at[0].set(b), t["le"],
                               stats.observe(stats.init(()), y))
        t["size"] = jnp.int32(1)
        return t

    def walk_case(t):
        # state: (cur, done, tree-arrays...)
        def cond(st):
            return ~st[1]

        def body(st):
            cur, _, key, left, right, le, size = st
            k = key[cur]
            goes_left = x <= k
            # update <= statistics when x lands on the left side
            le = jax.tree.map(
                lambda a, upd: a.at[cur].set(jnp.where(goes_left, upd, a[cur])),
                le, stats.observe(jax.tree.map(lambda a: a[cur], le), y))
            is_eq = x == k
            child = jnp.where(goes_left, left[cur], right[cur])
            need_new = (child == _NIL) & ~is_eq
            can_new = size < cap
            new_idx = size
            # create node
            key = jnp.where(need_new & can_new, key.at[new_idx].set(x), key)
            # a fresh node's <= statistics hold its own observation (x <= x)
            le = jax.tree.map(
                lambda a, b: jnp.where(need_new & can_new, a.at[new_idx].set(b), a),
                le, stats.observe(stats.init(()), y))
            # wire parent -> child (only for the branch that was NIL)
            left = jnp.where(need_new & can_new & goes_left,
                             left.at[cur].set(new_idx), left)
            right = jnp.where(need_new & can_new & ~goes_left,
                              right.at[cur].set(new_idx), right)
            size = jnp.where(need_new & can_new, size + 1, size)
            done = is_eq | need_new  # stop on duplicate, new node, or full walk
            nxt = jnp.where(done, cur, child)
            return (nxt, done, key, left, right, le, size)

        st = (jnp.int32(0), jnp.bool_(False), t["key"], t["left"], t["right"],
              t["le"], t["size"])
        st = jax.lax.while_loop(cond, body, st)
        out = dict(t)
        out["key"], out["left"], out["right"], out["le"], out["size"] = st[2:]
        return out

    return jax.lax.cond(t["size"] == 0, empty_case, walk_case, t)


def update(t: EBST, xs, ys) -> EBST:
    """Sequentially insert a batch (streams are sequential by definition)."""
    xs = jnp.asarray(xs, jnp.float32).reshape(-1)
    ys = jnp.asarray(ys, jnp.float32).reshape(-1)

    def body(t, xy):
        return _insert_one(t, xy[0], xy[1]), None

    t, _ = jax.lax.scan(body, t, jnp.stack([xs, ys], axis=1))
    return t


def n_elements(t: EBST) -> jax.Array:
    return t["size"]


def best_split(t: EBST) -> SplitResult:
    """Faithful in-order traversal split query (O(n), explicit stack).

    At node v with accumulated ancestor-left context S:
      left(v)  = merge(S, v.le)           (everything <= key_v)
      right(v) = total - left(v)          (paper Eqs. 6-7 subtraction)
    then recurse right with context left(v).
    """
    cap = t["key"].shape[0]
    total = t["total"]
    s2_d = stats.variance(total)
    n_tot = jnp.maximum(total["n"], 1.0)

    # stack entries: node idx, phase (0=descend left, 1=emit+descend right),
    # and the ancestor context stats S
    stk_node = jnp.zeros((cap + 1,), jnp.int32)
    stk_phase = jnp.zeros((cap + 1,), jnp.int32)
    stk_S = stats.init((cap + 1,))

    def push(stk, sp, node, phase, S):
        stk_node, stk_phase, stk_S = stk
        stk_node = stk_node.at[sp].set(node)
        stk_phase = stk_phase.at[sp].set(phase)
        stk_S = jax.tree.map(lambda a, b: a.at[sp].set(b), stk_S, S)
        return (stk_node, stk_phase, stk_S), sp + 1

    stk = (stk_node, stk_phase, stk_S)
    stk, sp = push(stk, 0, jnp.int32(0), jnp.int32(0), stats.init(()))
    sp = jnp.where(t["size"] > 0, sp, 0)

    init_best = (jnp.float32(-jnp.inf), jnp.float32(0.0))

    def cond(st):
        return st[1] > 0

    def body(st):
        stk, sp, best = st
        sp = sp - 1
        v = stk[0][sp]
        phase = stk[1][sp]
        S = jax.tree.map(lambda a: a[sp], stk[2])

        def descend(args):
            stk, sp, best = args
            stk, sp = push(stk, sp, v, jnp.int32(1), S)
            lc = t["left"][v]
            stk2, sp2 = push(stk, sp, lc, jnp.int32(0), S)
            has_left = lc != _NIL
            stk = jax.tree.map(lambda a, b: jnp.where(has_left, b, a), stk, stk2)
            sp = jnp.where(has_left, sp2, sp)
            return stk, sp, best

        def emit(args):
            stk, sp, best = args
            left_s = stats.merge(S, jax.tree.map(lambda a: a[v], t["le"]))
            right_s = stats.subtract(total, left_s)
            ok = (left_s["n"] > 0) & (right_s["n"] > 0)
            vr = s2_d - (left_s["n"] / n_tot) * stats.variance(left_s) \
                      - (right_s["n"] / n_tot) * stats.variance(right_s)
            score = jnp.where(ok, vr, -jnp.inf)
            better = score > best[0]
            best = (jnp.where(better, score, best[0]),
                    jnp.where(better, t["key"][v], best[1]))
            rc = t["right"][v]
            stk2, sp2 = push(stk, sp, rc, jnp.int32(0), left_s)
            has_right = rc != _NIL
            stk = jax.tree.map(lambda a, b: jnp.where(has_right, b, a), stk, stk2)
            sp = jnp.where(has_right, sp2, sp)
            return stk, sp, best

        return jax.lax.cond(phase == 0, descend, emit, (stk, sp, best))

    stk, sp, best = jax.lax.while_loop(cond, body, (stk, sp, init_best))
    merit, thr = best
    valid = jnp.isfinite(merit)
    return SplitResult(threshold=thr,
                       merit=jnp.where(valid, merit, 0.0),
                       valid=valid)
