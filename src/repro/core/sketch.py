"""Distributed QO sketches — the paper's variance algebra as a collective.

The Chan merge (paper Eqs. 4-5) is associative and commutative, so a set of
per-device QO tables reduces across any mesh axis exactly like a psum —
but over (n, mean, M2) triples, keeping Welford-grade accuracy.  This
module provides:

* :func:`all_merge` — merge same-shape QO tables across named mesh axes
  (all_gather + log-depth pairwise tree merge, the numerically preferred
  reduction order);
* :func:`quantile` — approximate quantiles of the *observed x values* from
  the bin occupancy (used by gradient compression to pick top-k thresholds
  without sorting, DESIGN.md §4);
* :func:`Sketch` helpers used by ``repro.train.monitor`` for per-step
  telemetry of losses / grad norms / activation RMS.

Payload per step is O(capacity), independent of cluster size — the reason
this scales to 1000+ nodes.
"""
from __future__ import annotations

from typing import Dict, Sequence

import jax
import jax.numpy as jnp

from repro.core import stats
from repro.core import qo as qo_lib

__all__ = ["all_merge", "quantile", "summary"]


def all_merge(table: qo_lib.QOTable, axis_names) -> qo_lib.QOTable:
    """Merge per-device tables across mesh axes (inside shard_map/pjit).

    Gathers the (n, mean, M2, sum_x) planes along ``axis_names`` and folds
    them with a log-depth pairwise Chan-merge tree.  ``sum_x`` is a plain
    sum (it is already a linear statistic).
    """
    if isinstance(axis_names, str):
        axis_names = (axis_names,)
    merged = table
    for ax in axis_names:
        gathered_y = jax.tree.map(
            lambda x: jax.lax.all_gather(x, ax, axis=0), merged["y"])
        merged = {
            "radius": merged["radius"],
            "origin": merged["origin"],
            "sum_x": jax.lax.psum(merged["sum_x"], ax),
            "y": stats.tree_reduce_merge(gathered_y, axis=0),
        }
    return merged


def quantile(table: qo_lib.QOTable, q) -> jax.Array:
    """Approximate q-quantile(s) of the monitored x values.

    Walks the (pre-sorted, dense-binned) occupancy CDF and returns the
    prototype of the bin where the CDF crosses q — the paper's sorted-hash
    sweep reused as an O(|H|) quantile query.
    """
    q = jnp.atleast_1d(jnp.asarray(q, jnp.float32))
    n = table["y"]["n"]
    cum = jnp.cumsum(n)
    total = jnp.maximum(cum[-1], 1.0)
    proto = jnp.where(n > 0, table["sum_x"] / jnp.where(n > 0, n, 1.0), 0.0)
    # fill empty bins with the previous occupied prototype
    idx = jnp.arange(n.shape[0])
    last_occ = jax.lax.associative_scan(jnp.maximum, jnp.where(n > 0, idx, -1))
    proto_f = proto[jnp.maximum(last_occ, 0)]

    def one(qi):
        pos = jnp.searchsorted(cum, qi * total)
        return proto_f[jnp.clip(pos, 0, n.shape[0] - 1)]

    out = jax.vmap(one)(q)
    return out[0] if out.shape == (1,) else out


def summary(table: qo_lib.QOTable) -> Dict[str, jax.Array]:
    """Scalar digest for logging: count / mean / std / occupancy / quantiles."""
    tot = qo_lib.total_stats(table)
    qs = quantile(table, jnp.array([0.5, 0.9, 0.99]))
    return {
        "count": tot["n"],
        "mean": tot["mean"],
        "std": stats.stddev(tot),
        "slots": qo_lib.n_slots(table),
        "p50": qs[0],
        "p90": qs[1],
        "p99": qs[2],
    }
