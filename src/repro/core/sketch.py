"""Mergeable quantile sketches over the paper's (n, mean, M2) algebra.

Two roles share this module, both built on the Chan merge (paper
Eqs. 4-5) being associative and commutative:

* **QO-table collectives + telemetry** (the original role, consumed by
  ``repro.train.monitor`` and ``repro.optim.compress``):
  :func:`all_merge` reduces same-shape QO tables across named mesh axes
  (all_gather + log-depth pairwise tree merge) and :func:`quantile` /
  :func:`summary` read approximate x-quantiles off the dense bin
  occupancy — O(capacity) payload per step, independent of cluster size.

* **The sketch attribute observer** (DESIGN.md §2.8, ROADMAP item 1):
  a fixed-capacity rank-bucket centroid sketch that replaces the dense
  (M, F, C) QO bin planes with O(K·F) per-leaf state when
  ``HTRConfig(observer_backend="sketch")``.  Each (leaf, feature) slot
  holds K weighted centroids — the SAME four planes as a QO bin
  (target (n, mean, M2) + ``sum_x``) — kept in ascending-prototype
  order, so the §2.4 prefix-merge VR query consumes them *unchanged*:
  a sorted centroid list IS a sorted bin table with empties interleaved
  (zero-weight slots are exact identities of the prefix scan).  The
  jit-compatible primitives here (:func:`compact_planes`,
  :func:`from_batch_planes`, :func:`merge_planes`) are the single
  source of truth the :mod:`repro.kernels.ops` ``sketch_update`` /
  ``sketch_merge`` dispatch families and their :mod:`repro.kernels.ref`
  oracles lower.

Sketch algebra (deterministic, trace-safe — no data-dependent shapes):

* a **compaction** of J weighted centroids to K buckets sorts by
  prototype (stable; empties carry +inf and sink to the tail), assigns
  each centroid the bucket of its cumulative-weight *midpoint*
  ``floor((cumw_i - n_i/2) · K / tot)``, and reduces each bucket with
  the exact grouped two-pass form (Eqs. 6-7 algebra) — so bucket stats
  are exact for the grouping, and only *which* centroids share a bucket
  is approximate (rank error O(1/K) per merge level);
* **merge(A, B)** concatenates the 2K centroids and compacts back to K
  — same mergeability contract as the Chan table merge (any reduction
  order, empty-operand safe), which is what lets the §4.1 DP sync and
  checkpointing ride unchanged;
* **update** pre-sketches the batch (per-leaf rank buckets over the
  sorted rows) and merges — weight-0 rows vanish and the batch pad
  ladder is bit-identical, exactly the QO weighted-absorption contract.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core import stats
from repro.core import qo as qo_lib

__all__ = [
    "all_merge", "quantile", "summary",
    "SKTable", "init", "update", "merge", "best_split", "from_batch",
    "quantile_sk", "total_stats", "n_slots",
    "prototypes", "compact_planes", "from_batch_planes", "merge_planes",
    "sort_planes",
]

SKTable = Dict[str, jax.Array]


# --------------------------------------------------------------------------
# QO-table collectives + telemetry (the original module surface)
# --------------------------------------------------------------------------

def all_merge(table: qo_lib.QOTable, axis_names) -> qo_lib.QOTable:
    """Merge per-device QO tables across mesh axes (inside shard_map/pjit).

    Gathers the (n, mean, M2, sum_x) planes along ``axis_names`` and folds
    them with a log-depth pairwise Chan-merge tree.  ``sum_x`` is a plain
    sum (it is already a linear statistic).
    """
    if isinstance(axis_names, str):
        axis_names = (axis_names,)
    merged = table
    for ax in axis_names:
        gathered_y = jax.tree.map(
            lambda x: jax.lax.all_gather(x, ax, axis=0), merged["y"])
        merged = {
            "radius": merged["radius"],
            "origin": merged["origin"],
            "sum_x": jax.lax.psum(merged["sum_x"], ax),
            "y": stats.tree_reduce_merge(gathered_y, axis=0),
        }
    return merged


def quantile(table: qo_lib.QOTable, q) -> jax.Array:
    """Approximate q-quantile(s) of the monitored x values.

    Walks the (pre-sorted, dense-binned) occupancy CDF and returns the
    prototype of the bin where the CDF crosses q — the paper's sorted-hash
    sweep reused as an O(|H|) quantile query.
    """
    q = jnp.atleast_1d(jnp.asarray(q, jnp.float32))
    n = table["y"]["n"]
    cum = jnp.cumsum(n)
    total = jnp.maximum(cum[-1], 1.0)
    proto = jnp.where(n > 0, table["sum_x"] / jnp.where(n > 0, n, 1.0), 0.0)
    # fill empty bins with the previous occupied prototype
    idx = jnp.arange(n.shape[0])
    last_occ = jax.lax.associative_scan(jnp.maximum, jnp.where(n > 0, idx, -1))
    proto_f = proto[jnp.maximum(last_occ, 0)]

    def one(qi):
        pos = jnp.searchsorted(cum, qi * total)
        return proto_f[jnp.clip(pos, 0, n.shape[0] - 1)]

    out = jax.vmap(one)(q)
    return out[0] if out.shape == (1,) else out


def summary(table: qo_lib.QOTable) -> Dict[str, jax.Array]:
    """Scalar digest for logging: count / mean / std / occupancy / quantiles."""
    tot = qo_lib.total_stats(table)
    qs = quantile(table, jnp.array([0.5, 0.9, 0.99]))
    return {
        "count": tot["n"],
        "mean": tot["mean"],
        "std": stats.stddev(tot),
        "slots": qo_lib.n_slots(table),
        "p50": qs[0],
        "p90": qs[1],
        "p99": qs[2],
    }


# --------------------------------------------------------------------------
# sketch-observer plane algebra (DESIGN.md §2.8) — the kernel-family core
#
# Planes are (..., J) arrays: ``n``/``mean``/``m2`` the per-centroid
# target Stats, ``sum_x`` the prototype numerator.  Every function below
# is jnp-traceable with static shapes, so the ops layer can jit/vmap it
# and the forest can fold T·M tables into the leading axes.
# --------------------------------------------------------------------------

def prototypes(n: jax.Array, sum_x: jax.Array,
               empty: float = jnp.inf) -> jax.Array:
    """Per-centroid prototype ``sum_x / n`` with ``empty`` at n == 0 slots
    (+inf by default, so a stable sort sinks empties to the tail)."""
    return jnp.where(n > 0, sum_x / jnp.where(n > 0, n, 1.0), empty)


def sort_planes(n, mean, m2, sum_x) -> Tuple[jax.Array, ...]:
    """Stable-sort centroids along the last axis by ascending prototype
    (empties last).  The defensive half of the densify-at-attempt
    adapter: on well-formed sketch state this is the identity (slots are
    kept rank-ordered by construction), but the query's correctness
    contract — occupied slots in ascending-prototype order — is enforced
    here rather than assumed."""
    key = prototypes(n, sum_x)
    _, n, mean, m2, sum_x = jax.lax.sort(
        (key, n, mean, m2, sum_x), dimension=-1, num_keys=1, is_stable=True)
    return n, mean, m2, sum_x


def _bucket_ids(n_sorted: jax.Array, k_out: int) -> jax.Array:
    """Rank buckets for already-sorted centroids: centroid i (inclusive
    cumulative weight ``cumw_i``) lands in bucket
    ``floor((cumw_i - n_i/2) * k_out / tot)`` — its weight-midpoint rank
    scaled to K buckets.  Zero-weight slots get a valid (clipped) id and
    contribute nothing to any bucket sum."""
    cumw = jnp.cumsum(n_sorted, axis=-1)
    tot = jnp.maximum(cumw[..., -1:], 1e-30)
    mid = cumw - 0.5 * n_sorted
    return jnp.clip((mid * (k_out / tot)).astype(jnp.int32), 0, k_out - 1)


def _bucket_reduce(n, mean, m2, sum_x, bucket, k_out: int):
    """Grouped exact two-pass reduction of sorted centroids into their
    rank buckets — the compaction's compute stage (the piece
    ``kernels/sketch_compact.py`` implements as a Pallas kernel).

    Planes: (..., J); bucket: (..., J) i32 in [0, k_out).  Returns
    (..., k_out) planes.  Pass 1 accumulates the linear sums (n, n·mean,
    sum_x); pass 2 folds each centroid's m2 plus its squared distance to
    the bucket mean — Chan's Eqs. 4-5 evaluated as one grouped two-pass
    form, exact for the grouping and order-independent within a bucket.
    """
    lead = n.shape[:-1]
    J = n.shape[-1]
    R = 1
    for d in lead:
        R *= d
    flat = lambda a: a.reshape(R, J)
    nf, meanf, m2f, sxf, bf = map(flat, (n, mean, m2, sum_x, bucket))
    seg = (jnp.arange(R, dtype=jnp.int32)[:, None] * k_out + bf).reshape(-1)
    pay = jnp.stack([nf, nf * meanf, sxf], -1).reshape(-1, 3)
    acc = jax.ops.segment_sum(pay, seg, R * k_out)
    n_b, sy_b, sx_b = acc[:, 0], acc[:, 1], acc[:, 2]
    mean_b = jnp.where(n_b > 0, sy_b / jnp.where(n_b > 0, n_b, 1.0), 0.0)
    resid = m2f.reshape(-1) + nf.reshape(-1) * (
        meanf.reshape(-1) - mean_b[seg]) ** 2
    m2_b = jax.ops.segment_sum(resid, seg, R * k_out)
    m2_b = jnp.where(n_b > 0, m2_b, 0.0)
    out = lambda a: a.reshape(lead + (k_out,))
    return out(n_b), out(mean_b), out(m2_b), out(sx_b)


def compact_planes(n, mean, m2, sum_x, k_out: int):
    """Compact (..., J) centroid planes to (..., k_out): sort by
    prototype, rank-bucket by cumulative-weight midpoints, reduce each
    bucket exactly.  Output slots are ascending-prototype by
    construction (bucket order == rank order), with zero-weight buckets
    wherever no mass landed — a valid sorted bin table for the §2.4
    query."""
    n, mean, m2, sum_x = sort_planes(n, mean, m2, sum_x)
    bucket = _bucket_ids(n, k_out)
    return _bucket_reduce(n, mean, m2, sum_x, bucket, k_out)


def merge_planes(a_n, a_mean, a_m2, a_sum_x, b_n, b_mean, b_m2, b_sum_x):
    """Merge two same-shape (..., K) sketches: concatenate the 2K
    centroids and compact back to K.  Commutative (bitwise for distinct
    prototypes — the stable sort sees the same sequence either way) and
    associative within the sketch's O(1/K) rank error; the empty sketch
    (all zeros) is an exact identity.  The §4.1 collective for
    ``observer_backend="sketch"``."""
    k = a_n.shape[-1]
    cat = lambda a, b: jnp.concatenate([a, b], axis=-1)
    return compact_planes(cat(a_n, b_n), cat(a_mean, b_mean),
                          cat(a_m2, b_m2), cat(a_sum_x, b_sum_x), k)


def from_batch_planes(leaf, X, y, w, n_tables: int, k: int):
    """Pre-sketch one routed batch into per-(leaf, feature) rank buckets.

    leaf: (B,) i32 routed table ids (−1 = dropped pad row); X: (B, F);
    y/w: (B,).  Returns (n_tables, F, k) planes: per feature the rows
    sort by (leaf, x) — one ``lax.sort`` per feature axis, vectorized —
    each row's within-leaf cumulative-weight midpoint picks its bucket,
    and the buckets reduce with the exact two-pass form.  Weight-0 rows
    vanish (their midpoint is degenerate but their payload is zero), so
    the dispatch ladders' pad rows are exact no-ops, bit for bit.
    """
    B, F = X.shape
    # dropped rows must be weightless BEFORE the cumulative sums: they
    # sort to the front of every leaf run, and any mass they carried
    # would inflate each real row's within-leaf rank (the dispatch
    # ladders already pad at w = 0; this makes the contract hold for any
    # caller that marks rows dropped without zeroing their weight)
    w = jnp.where(leaf >= 0, w, 0.0)
    leaf = jnp.broadcast_to(leaf[None, :], (F, B))
    xT = X.T                                       # (F, B)
    yF = jnp.broadcast_to(y[None, :], (F, B))
    wF = jnp.broadcast_to(w[None, :], (F, B))
    leaf_s, x_s, y_s, w_s = jax.lax.sort(
        (leaf, xT, yF, wF), dimension=-1, num_keys=2, is_stable=True)

    # within-leaf inclusive cumulative weight: global cumsum minus the
    # total mass of every smaller leaf id (rows are leaf-major after the
    # sort; pad rows leaf = −1 sort first and carry zero weight)
    tot_l = jax.ops.segment_sum(
        jnp.where(leaf[0] >= 0, w, 0.0), jnp.maximum(leaf[0], 0), n_tables)
    offset = jnp.cumsum(tot_l) - tot_l             # (n_tables,)
    safe_leaf = jnp.clip(leaf_s, 0, n_tables - 1)
    cumw = jnp.cumsum(w_s, axis=-1) - offset[safe_leaf]
    tot = jnp.maximum(tot_l[safe_leaf], 1e-30)
    mid = cumw - 0.5 * w_s
    bucket = jnp.clip((mid * (k / tot)).astype(jnp.int32), 0, k - 1)

    # flat segment reduce over (leaf, feature, bucket); negative leaf
    # rows produce negative segments and are dropped by the scatter
    frow = jnp.broadcast_to(jnp.arange(F, dtype=jnp.int32)[:, None], (F, B))
    seg = ((leaf_s * F + frow) * k + bucket).reshape(-1)
    wf, yf, xf = w_s.reshape(-1), y_s.reshape(-1), x_s.reshape(-1)
    num = n_tables * F * k
    pay = jnp.stack([wf, wf * yf, wf * xf], -1)
    acc = jax.ops.segment_sum(pay, seg, num)
    n_b, sy_b, sx_b = acc[:, 0], acc[:, 1], acc[:, 2]
    mean_b = jnp.where(n_b > 0, sy_b / jnp.where(n_b > 0, n_b, 1.0), 0.0)
    segc = jnp.clip(seg, 0, num - 1)
    m2_b = jax.ops.segment_sum(
        jnp.where(seg >= 0, wf * (yf - mean_b[segc]) ** 2, 0.0), segc, num)
    m2_b = jnp.where(n_b > 0, m2_b, 0.0)
    shp = (n_tables, F, k)
    return (n_b.reshape(shp), mean_b.reshape(shp), m2_b.reshape(shp),
            sx_b.reshape(shp))


# --------------------------------------------------------------------------
# single-table reference surface (the tests' and ref-oracles' vocabulary)
# --------------------------------------------------------------------------

def init(k: int) -> SKTable:
    """Empty K-centroid sketch: ``{"sum_x": (K,), "y": Stats (K,)}`` —
    the same plane names as a QO table (minus the grid scalars), so the
    tree state swaps layouts without changing its treedef key set."""
    return {"sum_x": jnp.zeros((k,), jnp.float32), "y": stats.init((k,))}


def _planes(t: SKTable):
    return t["y"]["n"], t["y"]["mean"], t["y"]["m2"], t["sum_x"]


def _table(n, mean, m2, sum_x) -> SKTable:
    return {"sum_x": sum_x, "y": {"n": n, "mean": mean, "m2": m2}}


def from_batch(x, y, w=None, *, k: int) -> SKTable:
    """Sketch one weighted batch from scratch (single table)."""
    x = jnp.asarray(x, jnp.float32).reshape(-1)
    y = jnp.asarray(y, jnp.float32).reshape(-1)
    w = jnp.ones_like(x) if w is None \
        else jnp.asarray(w, jnp.float32).reshape(-1)
    X = x[:, None]
    leaf = jnp.zeros_like(x, dtype=jnp.int32)
    n, mean, m2, sum_x = from_batch_planes(leaf, X, y, w, 1, k)
    return _table(n[0, 0], mean[0, 0], m2[0, 0], sum_x[0, 0])


def update(table: SKTable, x, y, w=None) -> SKTable:
    """Fold a weighted batch into the sketch: pre-sketch the batch at the
    table's own capacity, then :func:`merge` (one compaction per batch —
    there is no streaming inner Chan merge, so no stream-order knob
    exists for the tuner to pin)."""
    k = table["sum_x"].shape[-1]
    return merge(table, from_batch(x, y, w, k=k))


def merge(a: SKTable, b: SKTable) -> SKTable:
    """Merge two same-capacity sketches (see :func:`merge_planes`)."""
    return _table(*merge_planes(*_planes(a), *_planes(b)))


def best_split(table: SKTable) -> qo_lib.SplitResult:
    """Variance-reduction best split over the sketch's centroid
    boundaries — :func:`repro.core.qo.best_split` verbatim on the sorted
    centroids (a sorted centroid list is a sorted bin table; the grid
    scalars are inert there)."""
    n, mean, m2, sum_x = sort_planes(*_planes(table))
    return qo_lib.best_split({
        "radius": jnp.float32(1.0), "origin": jnp.float32(0.0),
        "sum_x": sum_x, "y": {"n": n, "mean": mean, "m2": m2}})


def quantile_sk(table: SKTable, q) -> jax.Array:
    """Approximate q-quantile(s) of the sketched x values, read off the
    centroid CDF (rank error O(1/K) per compaction level — the bound the
    property harness measures)."""
    q = jnp.atleast_1d(jnp.asarray(q, jnp.float32))
    n, _, _, sum_x = sort_planes(*_planes(table))
    proto = prototypes(n, sum_x, empty=0.0)
    cum = jnp.cumsum(n)
    total = jnp.maximum(cum[-1], 1e-30)

    def one(qi):
        pos = jnp.searchsorted(cum, qi * total)
        return proto[jnp.clip(pos, 0, n.shape[0] - 1)]

    out = jax.vmap(one)(q)
    return out[0] if out.shape == (1,) else out


def total_stats(table: SKTable) -> stats.Stats:
    """Whole-sample target statistics (merge of every centroid) — exact:
    bucket grouping never loses mass, so this matches the dense QO
    table's total bit-for-bit up to f32 reduction order."""
    return stats.tree_reduce_merge(table["y"], axis=0)


def n_slots(table: SKTable) -> jax.Array:
    """Occupied centroids — the sketch's |H| memory metric."""
    return (table["y"]["n"] > 0).sum()
