"""Core paper contribution: robust stats algebra, Quantizer Observer,
E-BST baselines, Hoeffding tree regressor, distributed sketches."""
from repro.core import stats, qo, ebst, hoeffding, sketch, multi  # noqa: F401
