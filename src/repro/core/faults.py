"""Fault injection for the continuous-serving engine (DESIGN.md §5.6).

Robustness you cannot inject, you cannot trust: the
:class:`repro.core.engine.ServingEngine` threads a
:class:`FaultInjector` through every lifecycle boundary it owns and
calls :meth:`FaultInjector.fire` at each named **site**.  An unarmed
site is a no-op passthrough (zero cost on the hot path); an armed site
applies its fault — raise, sleep, drop, or corrupt-in-flight — for a
bounded number of firings and then disarms itself.  Tests and the
fault-injection harness arm exactly the failure they want to prove the
engine degrades gracefully under, and read back :attr:`FaultInjector.log`
to assert the fault actually fired.

Engine sites (the contract tests/test_engine.py pins):

=================  ========================================================
``trainer.step``   before a training batch is absorbed — ``Kill`` here is
                   the trainer dying mid-sync-window
``publish``        the frozen snapshot in flight to the swap — ``Corrupt``
                   forges a torn model (the validation gate must reject
                   it and roll back), ``Drop`` loses the publish (the
                   staleness watchdog must notice), ``Delay`` stalls it
``ckpt.save``      before a checkpoint write — ``Kill`` is a trainer
                   preempted mid-save (the atomic-rename writer plus
                   validated restore must shrug it off)
=================  ========================================================

The module also provides :func:`bursty_arrivals`, the open-loop arrival
process the benchmarks and the admission-control tests drive the queue
with (a Poisson base rate punctuated by multiplied bursts — arrivals do
NOT wait for service, which is what makes overload reachable).
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Tuple

import numpy as np

__all__ = [
    "FaultError", "TrainerKilled", "DropSignal",
    "Kill", "Delay", "Drop", "Corrupt",
    "FaultInjector", "bursty_arrivals",
]


class FaultError(RuntimeError):
    """Base of every injected failure (so handlers can tell injected
    faults from organic bugs when they want to)."""


class TrainerKilled(FaultError):
    """The injected 'trainer process died here' exception."""


class DropSignal(FaultError):
    """Control-flow signal: the payload at this site is silently lost
    (a dropped publish, a lost message).  Sites that support dropping
    catch it and account the loss; it never escapes the engine."""


@dataclass
class Kill:
    """Raise ``exc_type`` at the site (default :class:`TrainerKilled`)."""
    exc_type: type = TrainerKilled
    message: str = "injected kill"

    def apply(self, site: str, payload):
        raise self.exc_type(f"{self.message} @ {site}")


@dataclass
class Delay:
    """Sleep ``seconds`` at the site, then pass the payload through."""
    seconds: float = 0.05

    def apply(self, site: str, payload):
        time.sleep(self.seconds)
        return payload


@dataclass
class Drop:
    """Raise :class:`DropSignal`: the site's payload is lost."""

    def apply(self, site: str, payload):
        raise DropSignal(f"injected drop @ {site}")


@dataclass
class Corrupt:
    """Transform the payload in flight: ``fn(payload) -> payload'``.

    The forged-value fault — e.g. NaN a snapshot threshold so the
    publish-validation gate must catch it.  ``fn`` must not mutate its
    argument (snapshots are frozen dataclasses; use
    ``dataclasses.replace``).
    """
    fn: Callable[[Any], Any]

    def apply(self, site: str, payload):
        return self.fn(payload)


@dataclass
class _Armed:
    fault: Any
    times: int          # remaining firings; disarms at 0
    after: int          # passthrough calls to skip before first firing


class FaultInjector:
    """Named-site fault hooks with bounded, self-disarming firings.

    ``arm(site, fault, times=1, after=0)`` queues ``fault`` at ``site``:
    the first ``after`` calls pass through untouched, the next ``times``
    calls apply the fault, then the site disarms.  Multiple arms on one
    site queue in FIFO order.  ``fire(site, payload=None)`` is what the
    engine calls — it returns the (possibly transformed) payload or
    raises the armed exception.  Thread-safe: the engine fires from its
    trainer and server threads concurrently.

    Every firing is appended to :attr:`log` as ``(site, fault)`` so
    tests can assert the fault actually happened (a fault test that
    passes because the fault never fired proves nothing).
    """

    def __init__(self):
        self._armed: Dict[str, List[_Armed]] = {}
        self._lock = threading.Lock()
        self.log: List[Tuple[str, Any]] = []

    def arm(self, site: str, fault, *, times: int = 1,
            after: int = 0) -> "FaultInjector":
        assert times >= 1 and after >= 0, (times, after)
        with self._lock:
            self._armed.setdefault(site, []).append(
                _Armed(fault, times, after))
        return self

    def armed(self, site: str) -> bool:
        with self._lock:
            return bool(self._armed.get(site))

    def fire(self, site: str, payload=None):
        with self._lock:
            queue = self._armed.get(site)
            if not queue:
                return payload
            head = queue[0]
            if head.after > 0:
                head.after -= 1
                return payload
            head.times -= 1
            if head.times == 0:
                queue.pop(0)
            self.log.append((site, head.fault))
        # apply OUTSIDE the lock: Delay must not serialize other sites
        return head.fault.apply(site, payload)

    def fired(self, site: str) -> int:
        """How many times any fault fired at ``site``."""
        return sum(1 for s, _ in self.log if s == site)


def bursty_arrivals(n_requests: int, *, base_rows: int = 64,
                    burst_factor: int = 10, burst_every: int = 8,
                    burst_len: int = 2, base_gap_s: float = 0.0,
                    jitter: float = 0.5, seed: int = 0):
    """Open-loop bursty arrival schedule: ``[(gap_s, rows), ...]``.

    A Poisson-ish base process (exponential gaps around ``base_gap_s``,
    request sizes around ``base_rows``) where every ``burst_every``-th
    arrival opens a burst of ``burst_len`` requests carrying
    ``burst_factor``× the rows at ~zero gap — the 10× spike the
    admission queue must shed, not absorb.  Deterministic per ``seed``
    (the schedule is data, not wall-clock: the driver sleeps the gaps,
    so the process stays open-loop even when service stalls).
    """
    rng = np.random.default_rng(seed)
    sched = []
    for i in range(n_requests):
        in_burst = burst_every > 0 and (i % burst_every) < burst_len \
            and i >= burst_every  # warm-up: first window stays calm
        rows = max(1, int(rng.normal(base_rows, jitter * base_rows * 0.2)))
        if in_burst:
            rows *= burst_factor
            gap = 0.0
        else:
            gap = float(rng.exponential(base_gap_s)) if base_gap_s else 0.0
        sched.append((gap, rows))
    return sched
