"""Quantizer Observer (QO) — the paper's core contribution (§4), TPU-native.

Differences from the CPython artifact (see DESIGN.md §2):

* the dynamic hash ``H`` becomes a fixed-capacity **dense bin table**.  Bin
  ids are ``floor(x / r) - origin`` clipped into ``[0, capacity)``; dense
  ids arrive pre-sorted so the paper's ``sorted(H)`` sweep becomes a plain
  prefix scan (cheaper than the paper's O(|H| log |H|)).
* insertion is **batched**: a tile of (x, y) observations is folded into the
  table with one segment-reduction (O(1) amortized per element, one stream
  over the tile).  The per-bin target statistics use the robust
  (n, mean, M2) algebra of :mod:`repro.core.stats` instead of the unstable
  naive sums — exactly the paper's §3 upgrade.
* the split-candidate query (Algorithm 2) is an inclusive prefix scan with
  the Chan merge operator followed by a VR argmax, evaluated for all |H|-1
  candidate cut points at once.

A QO table is a dict pytree, so trees/forests vmap over leading axes and
tables merge across devices with ``lax`` collectives (``repro.core.sketch``).
"""
from __future__ import annotations

import functools
from typing import Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core import stats

QOTable = Dict[str, jax.Array]

__all__ = [
    "init",
    "update",
    "best_split",
    "merge_tables",
    "n_slots",
    "total_stats",
    "SplitResult",
]


def init(capacity: int, radius: float, origin: float = 0.0) -> QOTable:
    """Create an empty QO table.

    capacity: number of bins (paper: dynamic |H|; here fixed, |H| <= capacity)
    radius:   quantization radius r (paper §4); bin id = floor(x/r)
    origin:   value mapped to the middle bin (lets one table cover negative x)
    """
    f = jnp.zeros((capacity,), jnp.float32)
    return {
        "radius": jnp.asarray(radius, jnp.float32),
        "origin": jnp.asarray(origin, jnp.float32),
        "sum_x": f,  # Σx per bin -> prototype = sum_x / n
        "y": stats.init((capacity,)),  # robust (n, mean, M2) of targets
    }


def _bin_ids(table: QOTable, x: jax.Array) -> jax.Array:
    cap = table["sum_x"].shape[0]
    # h = floor(x / r), shifted so `origin` lands mid-table, clipped to edges
    h = jnp.floor((x - table["origin"]) / table["radius"]).astype(jnp.int32)
    return jnp.clip(h + cap // 2, 0, cap - 1)


def update(table: QOTable, x: jax.Array, y: jax.Array, w=None) -> QOTable:
    """Fold a batch of observations into the table (paper Algorithm 1).

    Args:
      table: QO dict from :func:`init` (bins of capacity C).
      x: (B,) f32 feature values (any shape; flattened).
      y: (B,) f32 targets.
      w: optional (B,) f32 sample weights (default 1).  All bin statistics
        accumulate ``w`` — weight-0 rows vanish, integer weight k equals
        k repeated unit inserts (the online-bagging contract).

    Returns a new table of the same shapes.  Equivalent to looping
    Algorithm 1 over the tile, but executed as one segment-reduction:
    per bin we build exact tile statistics and merge them into the stored
    statistics with Chan's formulas.
    """
    x = jnp.asarray(x, jnp.float32).reshape(-1)
    y = jnp.asarray(y, jnp.float32).reshape(-1)
    w = jnp.ones_like(x) if w is None else jnp.asarray(w, jnp.float32).reshape(-1)
    cap = table["sum_x"].shape[0]
    ids = _bin_ids(table, x)

    n_b = jax.ops.segment_sum(w, ids, cap)
    sx_b = jax.ops.segment_sum(w * x, ids, cap)
    sy_b = jax.ops.segment_sum(w * y, ids, cap)
    safe_n = jnp.where(n_b > 0, n_b, 1.0)
    mean_b = jnp.where(n_b > 0, sy_b / safe_n, 0.0)
    # two-pass M2 (residuals against the tile bin mean) — exact within the
    # tile, avoiding the sum-of-squares cancellation the paper warns about
    m2_b = jax.ops.segment_sum(w * (y - mean_b[ids]) ** 2, ids, cap)
    tile = {"n": n_b, "mean": mean_b, "m2": m2_b}

    return {
        "radius": table["radius"],
        "origin": table["origin"],
        "sum_x": table["sum_x"] + sx_b,
        "y": stats.merge(table["y"], tile),
    }


class SplitResult(NamedTuple):
    threshold: jax.Array  # best cut point c
    merit: jax.Array      # VR value at c (paper Eq. 1)
    valid: jax.Array      # bool: at least two occupied bins existed


def total_stats(table: QOTable) -> stats.Stats:
    """Whole-sample target statistics (merge of every bin)."""
    return stats.tree_reduce_merge(table["y"], axis=0)


def n_slots(table: QOTable) -> jax.Array:
    """|H| — number of occupied bins (the paper's memory metric)."""
    return (table["y"]["n"] > 0).sum()


def best_split(table: QOTable) -> SplitResult:
    """Paper Algorithm 2 — evaluate every boundary between occupied bins.

    Candidate cut points are midpoints between prototypes of consecutive
    occupied bins; VR is computed from the prefix statistics (left side)
    and their complement obtained with the paper's subtraction (Eqs. 6-7).

    Returns a :class:`SplitResult` of scalars: ``threshold`` (f32 cut
    point), ``merit`` (f32 VR, 0 when invalid) and ``valid`` (bool —
    False when fewer than two occupied bins exist).  vmap over a leading
    table axis for many tables at once (or use
    :func:`repro.kernels.ops.forest_best_splits`).
    """
    ybins = table["y"]
    occ = ybins["n"] > 0
    cap = occ.shape[0]

    # inclusive prefix merge of bin statistics with the Chan operator
    left = jax.lax.associative_scan(stats.merge, ybins)
    tot = jax.tree.map(lambda x: x[-1], left)
    right = stats.subtract(jax.tree.map(lambda x: jnp.broadcast_to(x, (cap,)), tot), left)

    n_tot = jnp.maximum(tot["n"], 1.0)
    s2_d = stats.variance(tot)
    vr = s2_d - (left["n"] / n_tot) * stats.variance(left) \
              - (right["n"] / n_tot) * stats.variance(right)

    # prototype x value per occupied bin
    proto = jnp.where(occ, table["sum_x"] / jnp.where(occ, ybins["n"], 1.0), 0.0)
    idx = jnp.arange(cap)
    # last occupied index at-or-before i (forward max-scan) ...
    last_occ = jax.lax.associative_scan(jnp.maximum, jnp.where(occ, idx, -1))
    # ... and first occupied index at-or-after i (reverse min-scan)
    first_occ_from = jax.lax.associative_scan(
        jnp.minimum, jnp.where(occ, idx, cap)[::-1])[::-1]
    # first occupied index strictly after i
    nxt = jnp.concatenate([first_occ_from[1:], jnp.full((1,), cap)])
    # a boundary after bin i is valid iff an occupied bin exists on each side
    boundary_ok = (last_occ >= 0) & (nxt < cap)

    proto_left = proto[jnp.maximum(last_occ, 0)]
    proto_right = proto[jnp.minimum(nxt, cap - 1)]
    cand = 0.5 * (proto_left + proto_right)

    score = jnp.where(boundary_ok, vr, -jnp.inf)
    best = jnp.argmax(score)
    return SplitResult(
        threshold=cand[best],
        merit=jnp.where(jnp.isfinite(score[best]), score[best], 0.0),
        valid=boundary_ok.any(),
    )


def merge_tables(a: QOTable, b: QOTable) -> QOTable:
    """Merge two same-capacity QO tables (distributed estimation, DESIGN §4).

    Associative + commutative (inherited from the Chan merge), so D
    shard-local tables reduce to exactly the single-stream table in any
    order; radius/origin are taken from ``a`` (shards must quantize
    identically for the merge to be meaningful).
    """
    return {
        "radius": a["radius"],
        "origin": a["origin"],
        "sum_x": a["sum_x"] + b["sum_x"],
        "y": stats.merge(a["y"], b["y"]),
    }


@functools.partial(jax.jit, static_argnames=("capacity",))
def auto_radius(x_sample: jax.Array, capacity: int, k: float = 2.0) -> Tuple[jax.Array, jax.Array]:
    """Paper's dynamic radius policy: r = sigma / k, origin = sample mean.

    In a tree, sigma comes from the leaf's running variance estimator (the
    tree already keeps one per leaf, paper §5.2); here we bootstrap from a
    warmup sample.  Also returns an origin so the table covers the data.
    """
    s = stats.from_batch(x_sample.reshape(-1))
    sigma = jnp.sqrt(jnp.maximum(stats.variance(s), 1e-12))
    return sigma / k, s["mean"]
