"""Batched online Hoeffding tree regressor with QO attribute observers.

The paper's stated destination for QO (§1, §7): FIMT-style Hoeffding tree
regression where every leaf carries one Attribute Observer per numeric
feature.  Here the whole tree is a fixed-capacity array structure so that

* routing a batch of instances is a vectorized gather loop (depth-bounded),
* all (leaf × feature) QO tables update with ONE fused segment-reduction,
* split attempts evaluate every leaf and feature simultaneously and can
  expand several leaves per attempt,

which is the TPU-native re-think of the per-instance pointer algorithm
(DESIGN.md §2).  Growth follows FIRT/FIMT: a leaf splits when the ratio of
the second-best to best Variance Reduction drops below ``1 - eps`` with
``eps = sqrt(ln(1/delta) / (2 n))`` (Hoeffding bound, R = 1 for the ratio),
or when ``eps < tau`` (tie break).

Functional API: ``init_state`` -> ``update`` (learn a batch) -> ``predict``.
Forests: ``jax.vmap`` over a leading axis of states.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core import stats
from repro.core import qo as qo_lib

TreeState = Dict[str, jax.Array]

__all__ = ["HTRConfig", "init_state", "update", "predict", "n_leaves", "depth_histogram"]


@dataclass(frozen=True)
class HTRConfig:
    n_features: int
    max_nodes: int = 127          # total capacity (internal + leaves)
    n_bins: int = 64              # QO table capacity per (leaf, feature)
    grace_period: int = 200       # observations between split attempts
    delta: float = 1e-4           # Hoeffding confidence
    tau: float = 0.05             # tie-break threshold
    max_depth: int = 12
    r0: float = 0.05              # cold-start quantization radius (paper §5.2)
    sigma_k: float = 2.0          # dynamic radius r = sigma / k for children


def init_state(cfg: HTRConfig) -> TreeState:
    M, F, C = cfg.max_nodes, cfg.n_features, cfg.n_bins
    return {
        "feature": jnp.zeros((M,), jnp.int32),
        "threshold": jnp.zeros((M,), jnp.float32),
        "child": jnp.full((M, 2), -1, jnp.int32),
        "is_leaf": jnp.zeros((M,), jnp.bool_).at[0].set(True),
        "depth": jnp.zeros((M,), jnp.int32),
        "ystats": stats.init((M,)),          # leaf predictor / variance source
        "ao_sum_x": jnp.zeros((M, F, C), jnp.float32),
        "ao_y": stats.init((M, F, C)),       # QO bins per (node, feature)
        "ao_radius": jnp.full((M, F), cfg.r0, jnp.float32),
        "ao_origin": jnp.zeros((M, F), jnp.float32),
        "seen": jnp.zeros((M,), jnp.float32),  # since last split attempt
        "n_nodes": jnp.int32(1),
    }


def _route(state: TreeState, X: jax.Array, max_depth: int) -> jax.Array:
    """Leaf index for each row of X.  X: (B, F) -> (B,) int32."""
    def one(x):
        def body(_, node):
            f = state["feature"][node]
            go_left = x[f] <= state["threshold"][node]
            nxt = jnp.where(go_left, state["child"][node, 0],
                            state["child"][node, 1])
            return jnp.where(state["is_leaf"][node], node, nxt)
        return jax.lax.fori_loop(0, max_depth + 1, body, jnp.int32(0))
    return jax.vmap(one)(X)


def predict(cfg: HTRConfig, state: TreeState, X: jax.Array) -> jax.Array:
    """Mean-of-leaf (centroid) prediction, the paper's §2 framing."""
    leaf = _route(state, X, cfg.max_depth)
    return state["ystats"]["mean"][leaf]


def _ao_bin_ids(state: TreeState, leaf, X, C):
    """(B, F) bin ids in each row's leaf tables."""
    r = state["ao_radius"][leaf]        # (B, F)
    o = state["ao_origin"][leaf]        # (B, F)
    h = jnp.floor((X - o) / r).astype(jnp.int32) + C // 2
    return jnp.clip(h, 0, C - 1)


def _segment_stats(vals_y, seg, num):
    """Exact per-segment (n, mean, M2) from a flat batch."""
    w = jnp.ones_like(vals_y)
    n = jax.ops.segment_sum(w, seg, num)
    sy = jax.ops.segment_sum(vals_y, seg, num)
    syy = jax.ops.segment_sum(vals_y * vals_y, seg, num)
    safe = jnp.where(n > 0, n, 1.0)
    mean = sy / safe
    m2 = jnp.maximum(syy - n * mean * mean, 0.0)
    return {"n": n, "mean": jnp.where(n > 0, mean, 0.0), "m2": m2}


def update(cfg: HTRConfig, state: TreeState, X: jax.Array, y: jax.Array) -> TreeState:
    """Learn one batch: route, absorb statistics, attempt splits."""
    M, F, C = cfg.max_nodes, cfg.n_features, cfg.n_bins
    X = jnp.asarray(X, jnp.float32)
    y = jnp.asarray(y, jnp.float32).reshape(-1)
    B = y.shape[0]

    leaf = _route(state, X, cfg.max_depth)                      # (B,)

    # --- leaf target statistics (predictor + split-variance source) ------
    batch_leaf = _segment_stats(y, leaf, M)
    state = dict(state, ystats=stats.merge(state["ystats"], batch_leaf))

    # --- one fused QO update for every (leaf, feature) table -------------
    bins = _ao_bin_ids(state, leaf, X, C)                       # (B, F)
    seg = (leaf[:, None] * F + jnp.arange(F)[None, :]) * C + bins
    seg = seg.reshape(-1)                                       # (B*F,)
    y_rep = jnp.repeat(y, F)
    x_flat = X.reshape(-1)
    tile = _segment_stats(y_rep, seg, M * F * C)
    tile = jax.tree.map(lambda a: a.reshape(M, F, C), tile)
    sum_x = jax.ops.segment_sum(x_flat, seg, M * F * C).reshape(M, F, C)
    state = dict(
        state,
        ao_y=stats.merge(state["ao_y"], tile),
        ao_sum_x=state["ao_sum_x"] + sum_x,
        seen=state["seen"] + batch_leaf["n"],
    )

    # --- split attempts ---------------------------------------------------
    attempt = state["is_leaf"] & (state["seen"] >= cfg.grace_period) \
        & (state["depth"] < cfg.max_depth)

    def do_attempts(state):
        table = {
            "radius": state["ao_radius"],     # (M, F) — broadcast leaves
            "origin": state["ao_origin"],
            "sum_x": state["ao_sum_x"],       # (M, F, C)
            "y": state["ao_y"],
        }
        split = jax.vmap(jax.vmap(
            lambda r, o, sx, yb: qo_lib.best_split(
                {"radius": r, "origin": o, "sum_x": sx, "y": yb})))(
            table["radius"], table["origin"], table["sum_x"], table["y"])
        merit = jnp.where(split.valid, split.merit, -jnp.inf)   # (M, F)

        top2 = jax.lax.top_k(merit, 2)[0]                       # (M, 2)
        best_f = jnp.argmax(merit, axis=1)                      # (M,)
        best_c = split.threshold[jnp.arange(M), best_f]
        vr1, vr2 = top2[:, 0], top2[:, 1]
        n_leaf = jnp.maximum(state["ystats"]["n"], 1.0)
        eps = jnp.sqrt(jnp.log(1.0 / cfg.delta) / (2.0 * n_leaf))
        ratio = jnp.where(vr1 > 0, jnp.maximum(vr2, 0.0) / vr1, 1.0)
        decide = (ratio < 1.0 - eps) | (eps < cfg.tau)
        want = attempt & decide & jnp.isfinite(vr1) & (vr1 > 0)

        # vectorized allocation of 2 children per splitting leaf
        k = jnp.cumsum(want.astype(jnp.int32)) - 1
        base = state["n_nodes"] + 2 * k
        can = want & (base + 1 < M)
        lidx = jnp.where(can, jnp.arange(M), M)        # M = dropped scatter
        c0, c1 = base, base + 1
        c0i = jnp.where(can, c0, M)
        c1i = jnp.where(can, c1, M)

        st = dict(state)
        st["feature"] = st["feature"].at[lidx].set(best_f, mode="drop")
        st["threshold"] = st["threshold"].at[lidx].set(best_c, mode="drop")
        st["child"] = st["child"].at[lidx, 0].set(c0, mode="drop")
        st["child"] = st["child"].at[lidx, 1].set(c1, mode="drop")
        st["is_leaf"] = st["is_leaf"].at[lidx].set(False, mode="drop")
        st["seen"] = st["seen"].at[lidx].set(0.0, mode="drop")

        child_depth = state["depth"] + 1
        for ci in (c0i, c1i):
            st["is_leaf"] = st["is_leaf"].at[ci].set(True, mode="drop")
            st["depth"] = st["depth"].at[ci].set(child_depth, mode="drop")
            st["child"] = st["child"].at[ci].set(-1, mode="drop")
            st["seen"] = st["seen"].at[ci].set(0.0, mode="drop")

        # children INHERIT the split halves' target statistics, recovered
        # from the winning feature's QO bins with the paper's subtraction
        # (Eqs. 6-7) — fresh leaves predict sensibly from step one
        idxM = jnp.arange(M)
        bins_f = jax.tree.map(lambda a: a[idxM, best_f], state["ao_y"])  # (M,C)
        sumx_f = state["ao_sum_x"][idxM, best_f]
        occ_f = bins_f["n"] > 0
        proto_f = jnp.where(occ_f, sumx_f / jnp.where(occ_f, bins_f["n"], 1.0),
                            jnp.inf)
        maskL = occ_f & (proto_f <= best_c[:, None])
        left = stats.tree_reduce_merge(
            jax.tree.map(lambda a: jnp.where(maskL, a, 0.0), bins_f), axis=1)
        total_b = stats.tree_reduce_merge(bins_f, axis=1)
        right = stats.subtract(total_b, left)
        st["ystats"] = jax.tree.map(
            lambda a, v: a.at[c0i].set(v, mode="drop"), st["ystats"], left)
        st["ystats"] = jax.tree.map(
            lambda a, v: a.at[c1i].set(v, mode="drop"), st["ystats"], right)

        # children inherit a dynamic radius r = sigma_x / k from the parent's
        # per-feature x distribution estimated off the QO bins (paper §5.2)
        occ = state["ao_y"]["n"]                                  # (M, F, C)
        nb = jnp.maximum(occ, 1.0)
        proto = jnp.where(occ > 0, state["ao_sum_x"] / nb, 0.0)
        n_f = occ.sum(-1)
        mean_x = (occ * proto).sum(-1) / jnp.maximum(n_f, 1.0)
        var_x = (occ * (proto - mean_x[..., None]) ** 2).sum(-1) / jnp.maximum(n_f - 1.0, 1.0)
        sigma = jnp.sqrt(jnp.maximum(var_x, 1e-12))               # (M, F)
        child_r = jnp.maximum(sigma / cfg.sigma_k, 1e-6)
        for ci in (c0i, c1i):
            st["ao_radius"] = st["ao_radius"].at[ci].set(child_r, mode="drop")
            st["ao_origin"] = st["ao_origin"].at[ci].set(mean_x, mode="drop")
            st["ao_sum_x"] = st["ao_sum_x"].at[ci].set(0.0, mode="drop")
            st["ao_y"] = jax.tree.map(
                lambda a: a.at[ci].set(0.0, mode="drop"), st["ao_y"])

        st["n_nodes"] = state["n_nodes"] + 2 * jnp.sum(can.astype(jnp.int32))
        # failed attempts still reset the grace counter
        st["seen"] = jnp.where(attempt & ~can, 0.0, st["seen"])
        return st

    return jax.lax.cond(attempt.any(), do_attempts, lambda s: dict(s), state)


def n_leaves(state: TreeState) -> jax.Array:
    active = jnp.arange(state["is_leaf"].shape[0]) < state["n_nodes"]
    return (state["is_leaf"] & active).sum()


def depth_histogram(state: TreeState) -> jax.Array:
    active = jnp.arange(state["is_leaf"].shape[0]) < state["n_nodes"]
    return jax.ops.segment_sum(
        (state["is_leaf"] & active).astype(jnp.int32),
        state["depth"], 32)
