"""Batched online Hoeffding tree regressor with QO attribute observers.

The paper's stated destination for QO (§1, §7): FIMT-style Hoeffding tree
regression where every leaf carries one Attribute Observer per numeric
feature.  Here the whole tree is a fixed-capacity array structure and the
hot path is three explicit stages (DESIGN.md §2.3):

* **route**   — leaf index per batch row through the batched
  level-synchronous routing engine (:func:`repro.kernels.ops.route`, one
  fused transition sweep for the whole batch — DESIGN.md §2.6);
* **absorb**  — ALL (leaf x feature) QO tables update in one fused pass
  through :func:`repro.kernels.ops.forest_update` (a Pallas kernel on TPU,
  an XLA-fused segment-reduction elsewhere);
* **attempt** — split candidates evaluate through
  :func:`repro.kernels.ops.forest_best_splits`, gated so the work only
  runs when some leaf passed its grace period AND capacity remains, and
  COMPACTED so its cost scales with the number of attempting leaves K
  rather than capacity M (DESIGN.md §2.5).  ``HTRConfig.attempt_schedule``
  picks the scheduling policy ("grace": re-attempt only after
  ``grace_period`` *new* mass since the last attempt, tracked by the
  ``seen_since_attempt`` counter; "eager": every mature leaf attempts
  every batch), and ``compact_query`` can force the full-scan reference.

``HTRConfig.split_backend`` selects the engine: ``"auto"`` dispatches to
the compiled kernels on TPU and the fused-jnp lowering elsewhere;
``"oracle"`` keeps the original per-stat segment-scatter + per-table scan
path as the correctness reference (benchmarks/tree.py times both head to
head).  Growth follows FIRT/FIMT: under the default
``decision_backend="hoeffding"`` a leaf splits when the ratio of the
second-best to best Variance Reduction drops below ``1 - eps`` with
``eps = sqrt(ln(1/delta) / (2 n))`` (Hoeffding bound, R = 1 for the ratio),
or when ``eps < tau`` (tie break); ``decision_backend="anytime"`` swaps in
:mod:`repro.core.decide`'s e-process test, which stays valid under the
continuous peeking the ``eager`` schedule does (DESIGN.md §2.7).

Functional API: ``init_state`` -> ``update`` (learn a batch) -> ``predict``;
``update_stream`` scans a whole stream through ``update`` in one dispatch.
``update`` takes optional per-instance sample weights and a per-tree
feature-subspace mask; states vmap/shard over a leading tree axis, and
:mod:`repro.core.forest` builds the online-bagged ensemble on top by
folding that axis into the kernels' table axis (DESIGN.md §5).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core import decide as dc
from repro.core import stats
from repro.kernels import ops as kops
from repro.kernels import ref as kref

TreeState = Dict[str, jax.Array]

__all__ = ["HTRConfig", "init_state", "update", "update_local",
           "attempt_splits", "update_stream", "pad_stream", "predict",
           "attempt_mask", "n_leaves", "depth_histogram"]


@dataclass(frozen=True)
class HTRConfig:
    n_features: int
    max_nodes: int = 127          # total capacity (internal + leaves)
    n_bins: int = 64              # QO table capacity per (leaf, feature)
    grace_period: int = 200       # observations between split attempts
    delta: float = 1e-4           # Hoeffding confidence
    tau: float = 0.05             # tie-break threshold
    max_depth: int = 12
    r0: float = 0.05              # cold-start quantization radius (paper §5.2)
    sigma_k: float = 2.0          # dynamic radius r = sigma / k for children
    split_backend: str = "auto"   # auto | pallas | interpret | jnp | oracle
    # attempt scheduling (DESIGN.md §2.5): "grace" re-attempts a leaf only
    # after grace_period NEW weight mass since its last attempt (the
    # paper-faithful FIMT semantics — the attempt set stays sparse);
    # "eager" keeps every mature leaf (total mass >= grace_period) in the
    # attempt set every batch (Manapragada-style eager splitting — more
    # split opportunities, K ~ #leaves query work)
    attempt_schedule: str = "grace"   # grace | eager
    compact_query: bool = True    # K-compacted split query (§2.5); False
    #                               forces the full M-table scan reference
    # split-decision test (DESIGN.md §2.7): "hoeffding" is the classic
    # fixed-n ratio test above; "anytime" is core/decide.py's e-process,
    # valid at every look — the right pairing for attempt_schedule="eager"
    decision_backend: str = "hoeffding"   # hoeffding | anytime
    alpha: float = 0.05           # anytime-valid false-split level
    # attribute-observer layout (DESIGN.md §2.8): "qo" keeps the dense
    # (M, F, C) bin planes (C = n_bins, the default — bit-identical to
    # every pre-sketch release); "sketch" replaces them with K = sketch_k
    # rank-bucket centroids per (leaf, feature) — O(K·F) state, bounded
    # O(1/K) rank error on thresholds, same mergeability contract
    observer_backend: str = "qo"  # qo | sketch
    sketch_k: int = 16            # sketch capacity K (slots per table)

    def observer_bins(self) -> int:
        """Slot count of the observer's last table axis: ``n_bins`` under
        the dense layout, ``sketch_k`` centroids under the sketch — the
        ONE place state shapes and decision corrections read C from."""
        return self.n_bins if self.observer_backend == "qo" else self.sketch_k

    def __post_init__(self):
        if self.observer_backend not in ("qo", "sketch"):
            raise ValueError(
                f"observer_backend={self.observer_backend!r}: expected "
                f"'qo' (dense bins) or 'sketch' (rank-bucket centroids)")
        if self.observer_backend == "sketch" and self.split_backend == "oracle":
            raise ValueError(
                "observer_backend='sketch' has no oracle engine: the seed "
                "path quantizes into dense bins; use split_backend in "
                "('auto', 'pallas', 'interpret', 'jnp')")
        if self.sketch_k < 2:
            raise ValueError(f"sketch_k={self.sketch_k}: need >= 2 slots "
                             f"for a split boundary to exist")
        if self.attempt_schedule not in ("grace", "eager"):
            raise ValueError(
                f"attempt_schedule={self.attempt_schedule!r}: expected "
                f"'grace' (re-attempt after grace_period new mass) or "
                f"'eager' (every mature leaf attempts every batch)")
        if self.decision_backend not in dc.DECISION_BACKENDS:
            raise ValueError(
                f"decision_backend={self.decision_backend!r}: expected "
                f"one of {dc.DECISION_BACKENDS}")
        if not 0.0 < self.alpha < 1.0:
            raise ValueError(f"alpha={self.alpha}: expected 0 < alpha < 1")


def init_state(cfg: HTRConfig) -> TreeState:
    """Empty single-root tree.

    Returns a dict pytree (all fixed-capacity, so it vmaps/shards over a
    leading tree axis — :mod:`repro.core.forest` relies on this):

    =============  =============  ================================================
    key            shape          meaning
    =============  =============  ================================================
    ``feature``    (M,) i32       split feature of internal nodes
    ``threshold``  (M,) f32       split threshold (x <= thr goes left)
    ``child``      (M, 2) i32     children ids, -1 for leaves
    ``is_leaf``    (M,) bool      leaf mask (node 0 starts as the root leaf)
    ``depth``      (M,) i32       node depth
    ``ystats``     Stats (M,)     per-node target (n, mean, M2) — the predictor
    ``ao_sum_x``   (M, F, C) f32  QO per-bin sum of x (prototype numerator)
    ``ao_y``       Stats (M,F,C)  QO per-bin target statistics
    ``ao_radius``  (M, F) f32     per-(node, feature) quantization radius
    ``ao_origin``  (M, F) f32     value mapped to the middle bin
    ``seen_since_attempt``  (M,) f32  weight mass since the last split
                                  attempt (the grace-period counter: reset
                                  on every attempt, successful or not)
    ``dec_logE``   (M, F) f32     running log e-value per (leaf, feature)
                                  (:mod:`repro.core.decide`; zeros under
                                  the Hoeffding backend)
    ``dec_n_last`` (M,) f32       leaf mass at the previous decision look
    ``n_nodes``    () i32         allocated node count
    =============  =============  ================================================

    with ``M = cfg.max_nodes``, ``F = cfg.n_features`` and
    ``C = cfg.observer_bins()`` — ``n_bins`` dense QO bins under the
    default observer, ``sketch_k`` rank-bucket centroids under
    ``observer_backend="sketch"`` (same keys, same treedef; only the
    last-axis length changes, and ``ao_radius``/``ao_origin`` ride inert
    under the sketch so checkpoints and the §4.1 delta protocol are
    layout-independent).  The ``dec_*`` decision-stage leaves are present
    under BOTH decision backends (inert zeros under ``"hoeffding"``) so
    the treedef — and every shape-keyed jit cache — is independent of
    ``decision_backend``.
    """
    M, F, C = cfg.max_nodes, cfg.n_features, cfg.observer_bins()
    return {
        "feature": jnp.zeros((M,), jnp.int32),
        "threshold": jnp.zeros((M,), jnp.float32),
        "child": jnp.full((M, 2), -1, jnp.int32),
        "is_leaf": jnp.zeros((M,), jnp.bool_).at[0].set(True),
        "depth": jnp.zeros((M,), jnp.int32),
        "ystats": stats.init((M,)),          # leaf predictor / variance source
        "ao_sum_x": jnp.zeros((M, F, C), jnp.float32),
        "ao_y": stats.init((M, F, C)),       # QO bins per (node, feature)
        "ao_radius": jnp.full((M, F), cfg.r0, jnp.float32),
        "ao_origin": jnp.zeros((M, F), jnp.float32),
        "seen_since_attempt": jnp.zeros((M,), jnp.float32),
        **dc.decision_state(M, F),
        "n_nodes": jnp.int32(1),
    }


def _route(state: TreeState, X: jax.Array, max_depth: int,
           backend: str = "auto") -> jax.Array:
    """Leaf index for each row of X.  X: (B, F) -> (B,) int32.

    Dispatches to the batched level-synchronous routing engine
    (:func:`repro.kernels.ops.route` — one fused transition sweep for the
    whole batch, DESIGN.md §2.6); ``backend="oracle"`` keeps the seed's
    vmap-of-scalar ``fori_loop`` walk (:func:`repro.kernels.ref.route_ref`)
    as the correctness reference.  Called with a concrete state the sweep
    is trimmed to the tree's *realized* depth (extra plies are self-loop
    no-ops, so results are bit-identical) and dispatched through cached
    jits bucketed on (batch, ply count) — the serving path never
    recompiles per request size.
    """
    if backend == "oracle":
        return kref.route_ref(state["feature"], state["threshold"],
                              state["child"], state["is_leaf"], X, max_depth)
    depth = max_depth
    if not kops._is_traced(state["feature"], state["depth"], X):
        depth = min(max_depth, int(state["depth"].max()))
    return kops.route(state["feature"], state["threshold"], state["child"],
                      state["is_leaf"], X, depth=depth, backend=backend)


def predict(cfg: HTRConfig, state: TreeState, X: jax.Array) -> jax.Array:
    """Mean-of-leaf (centroid) prediction, the paper's §2 framing.

    X: (B, F) f32 — returns (B,) f32 leaf-mean predictions (0.0 from an
    untrained root).  Routes through the batched engine selected by
    ``cfg.split_backend`` (``"oracle"`` keeps the seed's scalar walk);
    for repeated serving of a *frozen* state prefer
    :mod:`repro.core.serve`, which also trims storage to the realized
    tree and pre-gathers the leaf means.
    """
    leaf = _route(state, X, cfg.max_depth, cfg.split_backend)
    return state["ystats"]["mean"][leaf]


def _segment_stats(vals_y, seg, num, w=None):
    """Exact per-segment weighted (n, mean, M2) from a flat batch.

    M2 uses the two-pass residual form (residuals against the segment
    mean, gathered back per element) — the same robust formulation as
    :func:`repro.core.qo.update`, not the cancellation-prone
    ``sum(y^2) - n*mean^2`` (paper §3).  ``w`` defaults to unit weights;
    a weight-0 element contributes nothing.
    """
    w = jnp.ones_like(vals_y) if w is None else w
    n = jax.ops.segment_sum(w, seg, num)
    sy = jax.ops.segment_sum(w * vals_y, seg, num)
    safe = jnp.where(n > 0, n, 1.0)
    mean = jnp.where(n > 0, sy / safe, 0.0)
    m2 = jax.ops.segment_sum(w * (vals_y - mean[seg]) ** 2, seg, num)
    return {"n": n, "mean": mean, "m2": jnp.where(n > 0, m2, 0.0)}


# --------------------------------------------------------------------------
# absorb stage
# --------------------------------------------------------------------------

def _absorb_oracle(cfg: HTRConfig, state: TreeState, leaf, X, y, w) -> TreeState:
    """Seed path: four segment-scatter reductions over the flat M*F*C space
    (kept as the correctness oracle for :func:`kernels.ops.forest_update`)."""
    M, F, C = cfg.max_nodes, cfg.n_features, cfg.n_bins
    bins = kops.forest_bin_ids(state["ao_radius"], state["ao_origin"],
                               leaf, X, C)
    seg = (leaf[:, None] * F + jnp.arange(F)[None, :]) * C + bins
    seg = seg.reshape(-1)
    y_rep = jnp.repeat(y, F)
    w_rep = jnp.repeat(w, F)
    x_flat = X.reshape(-1)
    tile = _segment_stats(y_rep, seg, M * F * C, w_rep)
    tile = jax.tree.map(lambda a: a.reshape(M, F, C), tile)
    sum_x = jax.ops.segment_sum(w_rep * x_flat, seg, M * F * C).reshape(M, F, C)
    return dict(state,
                ao_y=stats.merge(state["ao_y"], tile),
                ao_sum_x=state["ao_sum_x"] + sum_x)


def _absorb(cfg: HTRConfig, state: TreeState, leaf, X, y, w) -> TreeState:
    if cfg.split_backend == "oracle":
        return _absorb_oracle(cfg, state, leaf, X, y, w)
    if cfg.observer_backend == "sketch":
        ao_y, ao_sum_x = kops.sketch_update(
            state["ao_y"], state["ao_sum_x"], leaf, X, y, w,
            backend=cfg.split_backend)
    else:
        ao_y, ao_sum_x = kops.forest_update(
            state["ao_y"], state["ao_sum_x"], state["ao_radius"],
            state["ao_origin"], leaf, X, y, w, backend=cfg.split_backend)
    return dict(state, ao_y=ao_y, ao_sum_x=ao_sum_x)


# --------------------------------------------------------------------------
# attempt stage
# --------------------------------------------------------------------------

def _query_oracle(state: TreeState, attempt) -> Tuple[jax.Array, jax.Array]:
    """Seed path: vmap(vmap(best_split)) over every (leaf, feature) table."""
    return kref.forest_query_ref(state["ao_y"], state["ao_sum_x"], attempt)


def _split_decision(cfg: HTRConfig, state: TreeState, merit, thr_all, attempt,
                    feat_mask=None):
    """Decision stage + vectorized child allocation.

    The statistical test itself lives in :func:`repro.core.decide.decide`
    (selected by ``cfg.decision_backend``); this wrapper adds the
    threshold gather and the child-slot allocation, and is shared by both
    attempt engines so the decision math can never desynchronize between
    the kernel pipeline and the oracle reference.  ``feat_mask``:
    optional (F,) bool random-subspace mask — features outside it can
    never win a split.  Returns
    (best_f, best_c, can, lidx, c0, c1, c0i, c1i, dec_new); index M
    means 'dropped scatter'; ``dec_new`` is the dict of updated
    decision-state leaves for the caller to fold into the new state
    (empty under the Hoeffding backend).
    """
    M = cfg.max_nodes
    want, best_f, dec_new = dc.decide(cfg, state, merit, attempt, feat_mask)
    best_c = thr_all[jnp.arange(M), best_f]

    # vectorized allocation of 2 children per splitting leaf
    k = jnp.cumsum(want.astype(jnp.int32)) - 1
    base = state["n_nodes"] + 2 * k
    can = want & (base + 1 < M)
    lidx = jnp.where(can, jnp.arange(M), M)
    c0, c1 = base, base + 1
    c0i = jnp.where(can, c0, M)
    c1i = jnp.where(can, c1, M)
    return best_f, best_c, can, lidx, c0, c1, c0i, c1i, dec_new


def _child_radius(cfg: HTRConfig, state: TreeState):
    """Dynamic child radius r = sigma_x / k and origin from the parent's
    per-feature x distribution estimated off the QO bins (paper §5.2)."""
    occ = state["ao_y"]["n"]                                  # (M, F, C)
    nb = jnp.maximum(occ, 1.0)
    proto = jnp.where(occ > 0, state["ao_sum_x"] / nb, 0.0)
    n_f = occ.sum(-1)
    mean_x = (occ * proto).sum(-1) / jnp.maximum(n_f, 1.0)
    var_x = (occ * (proto - mean_x[..., None]) ** 2).sum(-1) \
        / jnp.maximum(n_f - 1.0, 1.0)
    sigma = jnp.sqrt(jnp.maximum(var_x, 1e-12))               # (M, F)
    child_r = jnp.maximum(sigma / cfg.sigma_k, 1e-6)
    return child_r, mean_x


def _do_attempts_oracle(cfg: HTRConfig, state: TreeState, attempt,
                        feat_mask=None) -> TreeState:
    """The seed engine, preserved as the correctness reference: per-table
    scans, log-depth merge/subtract child recovery, one scatter per field.
    benchmarks/tree.py races it against :func:`_do_attempts`."""
    M = cfg.max_nodes
    merit, thr_all = _query_oracle(state, attempt)
    best_f, best_c, can, lidx, c0, c1, c0i, c1i, dec_new = _split_decision(
        cfg, state, merit, thr_all, attempt, feat_mask)

    st = dict(state, **dec_new)
    st["feature"] = st["feature"].at[lidx].set(best_f, mode="drop")
    st["threshold"] = st["threshold"].at[lidx].set(best_c, mode="drop")
    st["child"] = st["child"].at[lidx, 0].set(c0, mode="drop")
    st["child"] = st["child"].at[lidx, 1].set(c1, mode="drop")
    st["is_leaf"] = st["is_leaf"].at[lidx].set(False, mode="drop")
    st["seen_since_attempt"] = \
        st["seen_since_attempt"].at[lidx].set(0.0, mode="drop")

    child_depth = state["depth"] + 1
    for ci in (c0i, c1i):
        st["is_leaf"] = st["is_leaf"].at[ci].set(True, mode="drop")
        st["depth"] = st["depth"].at[ci].set(child_depth, mode="drop")
        st["child"] = st["child"].at[ci].set(-1, mode="drop")
        st["seen_since_attempt"] = \
            st["seen_since_attempt"].at[ci].set(0.0, mode="drop")
    # fresh e-processes for the children; the split parent's are retired
    for di in (lidx, c0i, c1i):
        st["dec_logE"] = st["dec_logE"].at[di].set(0.0, mode="drop")
        st["dec_n_last"] = st["dec_n_last"].at[di].set(0.0, mode="drop")

    idxM = jnp.arange(M)
    bins_f = jax.tree.map(lambda a: a[idxM, best_f], state["ao_y"])
    sumx_f = state["ao_sum_x"][idxM, best_f]
    occ_f = bins_f["n"] > 0
    proto_f = jnp.where(occ_f, sumx_f / jnp.where(occ_f, bins_f["n"], 1.0),
                        jnp.inf)
    maskL = occ_f & (proto_f <= best_c[:, None])
    left = stats.tree_reduce_merge(
        jax.tree.map(lambda a: jnp.where(maskL, a, 0.0), bins_f), axis=1)
    total_b = stats.tree_reduce_merge(bins_f, axis=1)
    right = stats.subtract(total_b, left)
    st["ystats"] = jax.tree.map(
        lambda a, v: a.at[c0i].set(v, mode="drop"), st["ystats"], left)
    st["ystats"] = jax.tree.map(
        lambda a, v: a.at[c1i].set(v, mode="drop"), st["ystats"], right)

    child_r, mean_x = _child_radius(cfg, state)
    for ci in (c0i, c1i):
        st["ao_radius"] = st["ao_radius"].at[ci].set(child_r, mode="drop")
        st["ao_origin"] = st["ao_origin"].at[ci].set(mean_x, mode="drop")
        st["ao_sum_x"] = st["ao_sum_x"].at[ci].set(0.0, mode="drop")
        st["ao_y"] = jax.tree.map(
            lambda a: a.at[ci].set(0.0, mode="drop"), st["ao_y"])

    st["n_nodes"] = state["n_nodes"] + 2 * jnp.sum(can.astype(jnp.int32))
    st["seen_since_attempt"] = jnp.where(attempt & ~can, 0.0,
                                         st["seen_since_attempt"])
    return st


def _apply_splits(cfg: HTRConfig, state: TreeState, merit, thr_all, attempt,
                  feat_mask=None) -> TreeState:
    """Decision + scatter stage of the kernel attempt engine, taking the
    already-computed (M, F) query results.  Factored out of
    :func:`_do_attempts` so the forest layer can run ONE flat query over
    all T*M tables and vmap only this cheap per-tree apply (DESIGN.md §5).
    """
    M = cfg.max_nodes
    best_f, best_c, can, lidx, c0, c1, c0i, c1i, dec_new = _split_decision(
        cfg, state, merit, thr_all, attempt, feat_mask)
    kids = jnp.concatenate([c0i, c1i])             # (2M,) fused child scatter

    st = dict(state, **dec_new)
    st["feature"] = st["feature"].at[lidx].set(best_f, mode="drop")
    st["threshold"] = st["threshold"].at[lidx].set(best_c, mode="drop")
    st["child"] = st["child"].at[lidx].set(jnp.stack([c0, c1], 1), mode="drop")
    st["child"] = st["child"].at[kids].set(-1, mode="drop")
    st["is_leaf"] = st["is_leaf"].at[lidx].set(False, mode="drop") \
                                 .at[kids].set(True, mode="drop")
    st["seen_since_attempt"] = st["seen_since_attempt"].at[
        jnp.concatenate([lidx, kids])].set(0.0, mode="drop")
    st["depth"] = st["depth"].at[kids].set(jnp.tile(state["depth"] + 1, 2),
                                           mode="drop")
    # fresh e-processes for the children; the split parent's are retired
    touched = jnp.concatenate([lidx, kids])
    st["dec_logE"] = st["dec_logE"].at[touched].set(0.0, mode="drop")
    st["dec_n_last"] = st["dec_n_last"].at[touched].set(0.0, mode="drop")

    # children INHERIT the split halves' target statistics, recovered from
    # the winning feature's QO bins with the paper's grouped two-pass form
    # (Eqs. 6-7 algebra, exact) — fresh leaves predict sensibly from step one
    idxM = jnp.arange(M)
    bins_f = jax.tree.map(lambda a: a[idxM, best_f], state["ao_y"])  # (M, C)
    sumx_f = state["ao_sum_x"][idxM, best_f]
    occ_f = bins_f["n"] > 0
    proto_f = jnp.where(occ_f, sumx_f / jnp.where(occ_f, bins_f["n"], 1.0),
                        jnp.inf)
    maskL = (occ_f & (proto_f <= best_c[:, None])).astype(jnp.float32)
    maskR = occ_f.astype(jnp.float32) - maskL
    nw = bins_f["n"]
    syw = nw * bins_f["mean"]

    def side(mask):
        nn = (mask * nw).sum(-1)
        sy = (mask * syw).sum(-1)
        mean = jnp.where(nn > 0, sy / jnp.where(nn > 0, nn, 1.0), 0.0)
        m2 = (mask * bins_f["m2"]).sum(-1) + \
            (mask * nw * (bins_f["mean"] - mean[:, None]) ** 2).sum(-1)
        return {"n": nn, "mean": mean, "m2": jnp.where(nn > 0, m2, 0.0)}

    left, right = side(maskL), side(maskR)
    st["ystats"] = jax.tree.map(
        lambda a, l, r: a.at[kids].set(jnp.concatenate([l, r]), mode="drop"),
        st["ystats"], left, right)

    child_r, mean_x = _child_radius(cfg, state)
    st["ao_radius"] = st["ao_radius"].at[kids].set(
        jnp.tile(child_r, (2, 1)), mode="drop")
    st["ao_origin"] = st["ao_origin"].at[kids].set(
        jnp.tile(mean_x, (2, 1)), mode="drop")
    st["ao_sum_x"] = st["ao_sum_x"].at[kids].set(0.0, mode="drop")
    st["ao_y"] = jax.tree.map(
        lambda a: a.at[kids].set(0.0, mode="drop"), st["ao_y"])

    st["n_nodes"] = state["n_nodes"] + 2 * jnp.sum(can.astype(jnp.int32))
    # failed attempts still reset the grace counter
    st["seen_since_attempt"] = jnp.where(attempt & ~can, 0.0,
                                         st["seen_since_attempt"])
    return st


def attempt_mask(cfg: HTRConfig, state: TreeState) -> jax.Array:
    """(M,) bool — which leaves attempt a split this batch (§2.5).

    ``attempt_schedule="grace"``: a leaf attempts once it has absorbed
    ``grace_period`` new weight mass since its last attempt
    (``seen_since_attempt``, reset on every attempt — the attempt set K
    stays sparse and the compacted query cost tracks it).
    ``"eager"``: every leaf whose TOTAL mass passed ``grace_period``
    attempts every batch (monotone; K grows with the leaf count).
    Depth-capped leaves never attempt; callers add the capacity gate.
    """
    if cfg.attempt_schedule == "grace":
        mature = state["seen_since_attempt"] >= cfg.grace_period
    else:  # "eager"
        mature = state["ystats"]["n"] >= cfg.grace_period
    return state["is_leaf"] & mature & (state["depth"] < cfg.max_depth)


def _do_attempts(cfg: HTRConfig, state: TreeState, attempt,
                 feat_mask=None) -> TreeState:
    ao_y, ao_sum_x = state["ao_y"], state["ao_sum_x"]
    if cfg.observer_backend == "sketch":
        # densify-at-attempt-time adapter (§2.8): sorted centroids ARE a
        # sorted bin table, so the §2.4 prefix-merge query — and with it
        # decide.py, compaction and both decision backends — rides
        # unchanged over the K-slot planes
        ao_y, ao_sum_x = kops.sketch_to_bins(ao_y, ao_sum_x)
    merit, thr_all = kops.forest_best_splits(
        ao_y, ao_sum_x, state["ao_radius"],
        state["ao_origin"], attempt, backend=cfg.split_backend,
        compact=cfg.compact_query)
    return _apply_splits(cfg, state, merit, thr_all, attempt, feat_mask)


# --------------------------------------------------------------------------
# update = route -> absorb -> attempt
# --------------------------------------------------------------------------

def update_local(cfg: HTRConfig, state: TreeState, X: jax.Array,
                 y: jax.Array, w: jax.Array | None = None) -> TreeState:
    """The monitor half of :func:`update`: route + absorb, NO attempts.

    Identical to the first two stages of :func:`update` (same op order,
    bitwise): routes the batch, folds per-leaf target statistics and the
    grace-period mass in, and absorbs every (leaf, feature) QO table.
    The tree TOPOLOGY is untouched — this is the shard-local step of the
    §4.1 data-parallel protocol, where split attempts are deferred to the
    merged state at a sync boundary (:func:`attempt_splits`).
    """
    M = cfg.max_nodes
    X = jnp.asarray(X, jnp.float32)
    y = jnp.asarray(y, jnp.float32).reshape(-1)
    w = jnp.ones_like(y) if w is None \
        else jnp.asarray(w, jnp.float32).reshape(-1)

    leaf = _route(state, X, cfg.max_depth, cfg.split_backend)   # (B,)

    # --- leaf target statistics (predictor + split-variance source) ------
    batch_leaf = _segment_stats(y, leaf, M, w)
    state = dict(state,
                 ystats=stats.merge(state["ystats"], batch_leaf),
                 seen_since_attempt=state["seen_since_attempt"]
                 + batch_leaf["n"])

    # --- absorb: one fused QO update for every (leaf, feature) table -----
    return _absorb(cfg, state, leaf, X, y, w)


def attempt_splits(cfg: HTRConfig, state: TreeState,
                   feat_mask: jax.Array | None = None) -> TreeState:
    """The attempt half of :func:`update`: evaluate + apply due splits.

    Runs the §2.5 scheduling mask over the CURRENT statistics (however
    they were accumulated — a local batch, or a §4.1 cross-shard merge),
    gates on capacity, and executes the compacted query + Hoeffding
    decision under ``lax.cond`` so a batch with no mature leaf pays
    nothing.  ``update == attempt_splits(update_local(...))`` bitwise.
    """
    M = cfg.max_nodes
    attempt = attempt_mask(cfg, state)
    if cfg.split_backend == "oracle":
        do = _do_attempts_oracle
    else:
        # capacity gate, part of the batched attempt mask: a full tree can
        # never split, so skipping the query is free and the learned tree
        # is bit-identical
        attempt = attempt & (state["n_nodes"] + 1 < M)
        do = _do_attempts

    return jax.lax.cond(
        attempt.any(), functools.partial(do, cfg, feat_mask=feat_mask),
        lambda s, a: dict(s), state, attempt)


def update(cfg: HTRConfig, state: TreeState, X: jax.Array, y: jax.Array,
           w: jax.Array | None = None,
           feat_mask: jax.Array | None = None) -> TreeState:
    """Learn one batch: route, absorb statistics, attempt splits.

    Args:
      cfg:   static :class:`HTRConfig` (jit with it as a static arg).
      state: tree pytree from :func:`init_state`.
      X:     (B, F) f32 features.
      y:     (B,) f32 targets.
      w:     optional (B,) f32 per-instance sample weights (default 1.0).
        Every statistic in the tree — leaf predictors, grace-period mass,
        QO bin stats — accumulates ``w`` instead of 1, so a weight-0 row
        is a no-op and integer weight k equals k repeated unit updates
        (Poisson online bagging, :mod:`repro.core.forest`).
      feat_mask: optional (F,) bool random-subspace mask; features outside
        it are still observed (their QO tables fill) but can never be
        chosen as a split feature.

    Returns the new TreeState (same shapes; purely functional).  The two
    stages are public on their own — :func:`update_local` (route/absorb)
    and :func:`attempt_splits` — so the §4.1 data-parallel trainer can
    absorb locally per shard and attempt globally on merged statistics.
    """
    return attempt_splits(cfg, update_local(cfg, state, X, y, w), feat_mask)


def pad_stream(X, y, w=None, batch_size: int = 256):
    """Chunk a stream into (n_batches, batch_size, ...) with a masked tail.

    X: (N, F), y: (N,), optional w: (N,) weights.  When N is not a
    multiple of ``batch_size`` the remainder rides in a final batch whose
    padding rows carry weight 0 — a no-op to every statistic by the
    weighted-absorption contract, so ALL N rows count.  Shared by the
    tree's and the forest's ``update_stream`` so their tail semantics can
    never drift apart.  Returns (Xc, yc, wc), shapes
    (ceil(N/batch_size), batch_size, ...).
    """
    X = jnp.asarray(X, jnp.float32)
    y = jnp.asarray(y, jnp.float32).reshape(-1)
    w = jnp.ones_like(y) if w is None \
        else jnp.asarray(w, jnp.float32).reshape(-1)
    pad = (-X.shape[0]) % batch_size
    if pad:
        X = jnp.concatenate([X, jnp.zeros((pad, X.shape[1]), X.dtype)])
        y = jnp.concatenate([y, jnp.zeros((pad,), y.dtype)])
        w = jnp.concatenate([w, jnp.zeros((pad,), w.dtype)])
    return (X.reshape(-1, batch_size, X.shape[1]),
            y.reshape(-1, batch_size), w.reshape(-1, batch_size))


@functools.partial(jax.jit, static_argnames=("cfg", "batch_size"))
def update_stream(cfg: HTRConfig, state: TreeState, X: jax.Array,
                  y: jax.Array, w: jax.Array | None = None,
                  batch_size: int = 256) -> TreeState:
    """Scan a whole stream through ``update`` in ONE dispatch.

    X: (N, F), y: (N,), optional w: (N,) sample weights.  A ragged tail
    rides in a final weight-0-masked batch (:func:`pad_stream`), so ALL
    N rows are learned — no silent tail drop.
    """
    Xc, yc, wc = pad_stream(X, y, w, batch_size)

    def body(s, xyw):
        return update(cfg, s, xyw[0], xyw[1], xyw[2]), None

    state, _ = jax.lax.scan(body, state, (Xc, yc, wc))
    return state


def n_leaves(state: TreeState) -> jax.Array:
    """Number of live leaves (allocated nodes with ``is_leaf`` set) — () i32."""
    active = jnp.arange(state["is_leaf"].shape[0]) < state["n_nodes"]
    return (state["is_leaf"] & active).sum()


def depth_histogram(state: TreeState) -> jax.Array:
    """(32,) i32 count of live leaves per depth (diagnostics)."""
    active = jnp.arange(state["is_leaf"].shape[0]) < state["n_nodes"]
    return jax.ops.segment_sum(
        (state["is_leaf"] & active).astype(jnp.int32),
        state["depth"], 32)
