"""Online-bagged forest of QO Hoeffding tree regressors (DESIGN.md §5).

The strongest streaming regressors in practice are ensembles of Hoeffding
trees (Adaptive Random Forests); the paper positions QO as the
split-attempt engine that makes each member cheap enough for real-time
ensembles.  This module is that ensemble layer, built so the whole forest
is ONE program over a leading tree axis:

* **online bagging** — each instance reaches tree t with a Poisson(λ)
  sample weight (Oza & Russell), threaded through every statistic of the
  member update (:func:`repro.core.hoeffding.update` with ``w``), so
  bagging costs nothing on top of the fused absorb;
* **random subspaces** — each member draws a feature mask of
  ``max(1, round(subspace * F))`` features; masked features still fill
  their QO tables but can never win a split (ARF-style decorrelation);
* **fused execution** — the T member updates run as ONE pass: the tree
  axis folds into the table axis of the PR-1 ``forest_update`` /
  ``forest_best_splits`` pipeline (global leaf ids ``t*M + leaf``), so
  absorb and the split query are each a single kernel/XLA call for the
  whole ensemble and only the cheap per-tree decision/scatter stage is
  vmapped (:func:`_fused_member_update`);
* **tree-axis sharding** — every leaf of the forest state carries the
  tree axis first, so :func:`repro.train.sharding.forest_state_specs`
  spreads T trees across the device mesh with ``shard_map``; members
  never communicate except the prediction reduce (``axis_name`` arg);
* **drift-aware member swap** — each tree keeps an ADWIN-style
  prequential-error window (long (n, mean, M2) window + short EWMA, the
  §3 algebra reused on the error stream).  When a short window rises
  ``drift_kappa`` standard deviations above its long reference, the
  WORST signalling member is swapped for a fresh tree + subspace +
  window (at most one per batch, so the forest's memory degrades
  gracefully under abrupt drift).  The test is per-member and local, so
  it adds no cross-tree communication.

Functional API mirrors the single tree: :func:`init_forest` ->
:func:`update` (returns ``(state, aux)`` with prequential metrics) ->
:func:`predict`; :func:`update_stream` scans a stream in one dispatch and
returns the prequential MSE traces the benchmarks report.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.core import hoeffding as ht
from repro.core import stats
from repro.kernels import ops as kops

ForestState = dict

__all__ = ["ForestConfig", "init_forest", "update", "update_stream",
           "predict", "member_predictions", "vote_weights",
           "n_leaves_per_tree"]


@dataclass(frozen=True)
class ForestConfig:
    """Static forest hyper-parameters (hashable: pass as a jit static arg).

    tree:      the shared member :class:`repro.core.hoeffding.HTRConfig`.
    n_trees:   T, the ensemble size (the vmapped/sharded axis).
    lam:       Poisson rate λ of the online-bagging sample weights
               (λ = 6 after Adaptive Random Forests).
    subspace:  fraction of features each member may split on;
               k = max(1, round(subspace * F)) features are drawn per tree
               (and re-drawn when the member is reset).
    vote:      "mean" or "inverse_error" — prediction reduce over members,
               the latter weighting each tree by
               (1 / (EWMA prequential MSE + eps)) ** vote_power; members
               with no error history yet (fresh after init or a reset)
               vote with weight 0 until their first prequential batch.
    vote_power: sharpness of the inverse-error vote (higher -> closer to
               picking the single best member).
    drift_alpha:       EWMA rate of the short error window.
    drift_decay:       per-batch decay of the long window's effective count
               (effective window length 1/(1-decay) batches), so the
               cold-start transient washes out of the reference.
    drift_kappa:       sigmas above the long window mean that signal drift.
    drift_min_batches: effective batches a member's long window must hold
               before its drift test may fire (cold-start guard; must be
               below 1/(1-drift_decay) or the test never arms).
    """
    tree: ht.HTRConfig
    n_trees: int = 8
    lam: float = 6.0
    subspace: float = 0.7
    vote: str = "inverse_error"
    vote_power: float = 4.0
    drift_alpha: float = 0.5
    drift_decay: float = 0.9
    drift_kappa: float = 3.0
    drift_min_batches: int = 8

    def __post_init__(self):
        if not 0.0 < self.drift_decay < 1.0:
            raise ValueError(
                f"drift_decay={self.drift_decay} must be in (0, 1): it is "
                f"the per-batch retention of the long window's count")
        limit = 1.0 / (1.0 - self.drift_decay)
        if self.drift_min_batches >= limit:
            raise ValueError(
                f"drift_min_batches={self.drift_min_batches} can never be "
                f"reached: the decayed window's effective count asymptotes "
                f"to 1/(1-drift_decay)={limit:.1f}")
        if self.tree.n_features >= 2 and self.subspace_k() < 2:
            raise ValueError(
                f"subspace={self.subspace} leaves each member a single "
                f"candidate feature: the Hoeffding ratio test degenerates "
                f"(second-best merit is -inf, so any positive merit splits "
                f"immediately); raise subspace so k >= 2")

    def subspace_k(self) -> int:
        return max(1, int(round(self.subspace * self.tree.n_features)))


def _draw_mask(key, F: int, k: int):
    perm = jax.random.permutation(key, F)
    return jnp.zeros((F,), bool).at[perm[:k]].set(True)


def _poisson_cdf(lam: float, tail: float = 1e-7):
    """Static inverse-CDF table: [P(X<=0), P(X<=1), ...] up to 1-tail."""
    import math
    cdf, p, k, c = [], math.exp(-lam), 0, math.exp(-lam)
    while c < 1.0 - tail and k < 64:
        cdf.append(c)
        k += 1
        p *= lam / k
        c += p
    cdf.append(c)
    return cdf


def _poisson_weights(key, cdf: jax.Array, shape):
    """Poisson draw by inverse-CDF table lookup.

    Exact up to the table's 1e-7 tail truncation, and — unlike
    ``jax.random.poisson``'s rejection sampler — free of ``while_loop``:
    ~10x cheaper per batch on CPU and transparent to vmap/shard_map
    replication checking.  ``X = #{k : u >= P(X<=k)}``.
    """
    u = jax.random.uniform(key, shape)
    return (u[..., None] >= cdf).sum(-1).astype(jnp.float32)


def init_forest(cfg: ForestConfig, key) -> ForestState:
    """Fresh forest state — a dict pytree whose EVERY leaf has the tree
    axis (T) first, the invariant the sharding layer relies on:

    ``trees``     member TreeStates stacked on axis 0 (T, ...)
    ``feat_mask`` (T, F) bool random-subspace masks
    ``keys``      (T, 2) u32 per-member PRNG keys (bagging + subspace
                  draws stay independent per member and per shard)
    ``err_win``   Stats (T,) — long prequential-error window since reset
    ``err_ewma``  (T,) f32 — short (EWMA) prequential-error window
    ``vote_w``    (T,) f32 — member vote weights, refreshed once per
                  ``update`` from the error windows (the serving read
                  path and :mod:`repro.core.serve` snapshots consume
                  them for free instead of recomputing per call)
    ``resets``    (T,) i32 — drift-reset count (diagnostics)
    """
    T, F = cfg.n_trees, cfg.tree.n_features
    base = ht.init_state(cfg.tree)
    trees = jax.tree.map(
        lambda a: jnp.broadcast_to(a[None], (T,) + a.shape), base)
    keys = jax.random.split(key, T + 1)
    masks = jax.vmap(
        functools.partial(_draw_mask, F=F, k=cfg.subspace_k()))(keys[1:])
    return {
        "trees": trees,
        "feat_mask": masks,
        "keys": jax.random.split(keys[0], T),
        "err_win": stats.init((T,)),
        "err_ewma": jnp.zeros((T,), jnp.float32),
        "vote_w": jnp.zeros((T,), jnp.float32),   # == vote_weights(fresh)
        "resets": jnp.zeros((T,), jnp.int32),
    }


def member_predictions(cfg: ForestConfig, state: ForestState,
                       X: jax.Array) -> jax.Array:
    """(T, B) f32 — every member's prediction for every row of X (B, F).

    ONE fused route for the whole ensemble: the tree axis folds into the
    routing kernel's node axis (:func:`repro.kernels.ops.forest_route`,
    the read-side twin of the §5.1 table fold), then every member's leaf
    means gather in one take — no per-tree dispatch, no vmapped scalar
    walk.  ``split_backend="oracle"`` keeps the seed's vmap-of-scalar
    engine as the correctness reference.  Concrete states route with a
    sweep trimmed to the deepest member's *realized* depth.
    """
    trees = state["trees"]
    backend = cfg.tree.split_backend
    if backend == "oracle":
        return jax.vmap(functools.partial(ht.predict, cfg.tree),
                        in_axes=(0, None))(trees, X)
    depth = cfg.tree.max_depth
    if not kops._is_traced(trees["feature"], trees["depth"], X):
        depth = min(depth, int(trees["depth"].max()))
    leaf = kops.forest_route(trees["feature"], trees["threshold"],
                             trees["child"], trees["is_leaf"], X,
                             depth=depth, backend=backend)
    return jnp.take_along_axis(trees["ystats"]["mean"], leaf, axis=1)


def vote_weights(cfg: ForestConfig, state: ForestState) -> jax.Array:
    """(T,) f32 un-normalized member vote weights from the error windows.

    ``inverse_error`` weights a member by
    ``(1 / (EWMA prequential MSE + eps)) ** vote_power``; members with no
    error history yet (fresh after init or a drift reset) vote 0 so a
    just-reset blank tree cannot drag the ensemble (an all-fresh forest
    predicts 0 either way; :func:`predict` guards the 0/0).

    :func:`update` calls this ONCE per learned batch and carries the
    result in ``state["vote_w"]``; the read path (:func:`predict`, the
    prequential vote inside :func:`update`, :func:`repro.core.serve`
    snapshots) consumes the carried weights instead of re-deriving them
    per prediction call.
    """
    T = state["err_ewma"].shape[0]
    if cfg.vote == "mean":
        return jnp.ones((T,), jnp.float32)
    assert cfg.vote == "inverse_error", cfg.vote
    seen = state["err_win"]["n"] > 0
    return jnp.where(
        seen, (1.0 / (state["err_ewma"] + 1e-6)) ** cfg.vote_power, 0.0)


def _vote_combine(yhat, wts, axis_name):
    """(T_local, B) member predictions + (T_local,) weights -> (B,) vote.

    The single definition of the prediction reduce, shared by
    :func:`predict` and the prequential error in :func:`update` so the
    reported forest_mse always describes the predictor predict serves.
    With ``axis_name`` (inside shard_map) the num/den psum pair is the
    forest's only collective.
    """
    num = (wts[:, None] * yhat).sum(0)
    den = wts.sum()
    if axis_name is not None:
        num, den = jax.lax.psum((num, den), axis_name)
    return num / jnp.maximum(den, 1e-12)


@kops.register_jit_cache
@functools.lru_cache(maxsize=None)
def _jit_predict_live(backend: str, plies: int):
    """Keyed handle for the whole live read path of one (backend,
    ply-bucket) — serving a live forest dispatches ONE compiled program
    per call instead of an eager epilogue.  The body IS the snapshot
    serving body (:func:`repro.core.serve._predict_impl` — route ->
    gather -> vote), traced over the live state's full-capacity tables
    through the shared :func:`repro.kernels.ops._dispatch` factory (no
    donation: the live state owns X's buffer lifetime, not this path),
    so the two read paths can never diverge."""
    from repro.core import serve as sv
    return kops._dispatch(sv._predict_impl, plies=plies, backend=backend,
                          single=False)


def predict(cfg: ForestConfig, state: ForestState, X: jax.Array,
            axis_name: str | None = None) -> jax.Array:
    """Forest prediction: the vote-weighted mean of member predictions.

    X: (B, F) -> (B,) f32.  ``axis_name``: when the tree axis is split
    over devices with ``shard_map``, pass the mesh axis name — the only
    cross-tree communication in the whole forest is this one psum pair.
    Reads the ``vote_w`` carried by the last :func:`update` (refreshed
    once per learned batch), so serving pays one fused route + one
    gather + one reduce per call and nothing else.  Called with a
    concrete state (the live-serving pattern) the whole read path
    dispatches as ONE cached jit, routing trimmed to the deepest
    member's *realized* depth; results are bit-identical to the traced
    composition.  (The trim costs one tiny device reduce + host sync
    per call — the price of tracking a still-training state; freezing
    with :mod:`repro.core.serve` bakes the depth in as static metadata
    and drops the probe, so prefer snapshots for a frozen model.)
    """
    backend = cfg.tree.split_backend
    trees = state["trees"]
    X = jnp.asarray(X, jnp.float32)
    if (axis_name is None and backend != "oracle"
            and not kops._is_traced(trees["feature"], state["vote_w"], X)):
        depth = min(cfg.tree.max_depth, int(trees["depth"].max()))
        rbackend = kops.resolve_backend(backend)
        T, M = trees["feature"].shape
        p = kops.tuned("forest_route", rbackend,
                       kops._shape_class_route(T, M, int(X.shape[1])))
        X, B, padded = kops.pad_rows(X, 128, p["batch_ladder"])
        out = _jit_predict_live(
            rbackend, kops.depth_bucket(depth, p["ply_round"]))(
            trees["feature"], trees["threshold"], trees["child"],
            trees["is_leaf"], trees["ystats"]["mean"], state["vote_w"], X)
        return out[:B] if padded else out
    return _vote_combine(member_predictions(cfg, state, X),
                         state["vote_w"], axis_name)


def _fold_tables(a, T, M):
    """(T, M, ...) -> (T*M, ...): the tree axis folds into the table axis."""
    return a.reshape((T * M,) + a.shape[2:])


def _fused_route_stats(cfg: ForestConfig, trees, X, y, w):
    """Route all T members and reduce the batch's per-leaf target stats.

    ONE fused route for all T trees (the §2.6 folded-node-axis sweep) and
    one flat segment reduction over global leaf ids ``t*M + leaf``.
    Returns ``(gl, leaf, batch_leaf)``: the (T*B,) folded leaf ids, the
    unfolded (T, B) per-tree leaf ids, and the batch's (T, M) Stats —
    the shard-local monitor quantities of the §4.1 data-parallel
    protocol (which accumulates them in a delta instead of folding them
    straight into ``trees``).
    """
    tcfg = cfg.tree
    M = tcfg.max_nodes
    T = trees["feature"].shape[0]
    leaf = kops.forest_route(trees["feature"], trees["threshold"],
                             trees["child"], trees["is_leaf"], X,
                             depth=tcfg.max_depth,
                             backend=tcfg.split_backend)
    gl = (jnp.arange(T, dtype=leaf.dtype)[:, None] * M + leaf).reshape(-1)
    batch_leaf = jax.tree.map(
        lambda a: a.reshape(T, M),
        ht._segment_stats(jnp.tile(y, T), gl, T * M, w.reshape(-1)))
    return gl, leaf, batch_leaf


def _fused_absorb_tables(cfg: ForestConfig, ao_y, ao_sum_x, trees, gl,
                         X, y, w):
    """Absorb a routed batch into ANY (T, M, F, C) table set in one pass.

    ``ao_y``/``ao_sum_x`` are the accumulation target (the live
    ``trees["ao_*"]`` tables, or a shard-local DELTA starting from
    zero — §4.1); the quantization grid (radius/origin) always comes
    from ``trees``, so every shard bins identically, which is what makes
    the deltas mergeable.  ``gl``: (T*B,) folded leaf ids from
    :func:`_fused_route_stats`; w: (T, B).  Returns the merged tables.
    """
    tcfg = cfg.tree
    M = tcfg.max_nodes
    T = trees["feature"].shape[0]
    flat = functools.partial(_fold_tables, T=T, M=M)
    if tcfg.observer_backend == "sketch":
        # the sketch needs no quantization grid — folded leaf ids alone
        # segment the batch, so shard deltas stay mergeable by the rank
        # contract instead of by a shared grid
        ao_y, ao_sum_x = kops.sketch_update(
            jax.tree.map(flat, ao_y), flat(ao_sum_x),
            gl, jnp.tile(X, (T, 1)), jnp.tile(y, T), w.reshape(-1),
            backend=tcfg.split_backend)
    else:
        ao_y, ao_sum_x = kops.forest_update(
            jax.tree.map(flat, ao_y), flat(ao_sum_x),
            flat(trees["ao_radius"]), flat(trees["ao_origin"]),
            gl, jnp.tile(X, (T, 1)), jnp.tile(y, T), w.reshape(-1),
            backend=tcfg.split_backend)
    unflat = lambda a: a.reshape((T, M) + a.shape[1:])
    return jax.tree.map(unflat, ao_y), unflat(ao_sum_x)


def _fused_member_attempt(cfg: ForestConfig, trees, feat_mask):
    """Attempt stage for all T members on their CURRENT statistics.

    The scheduling mask is the shared single-tree definition
    (:func:`repro.core.hoeffding.attempt_mask`) plus the per-tree
    capacity gate; the ONE compacted query spans the whole ensemble's
    folded T*M table axis, and only the cheap O(M) decision/scatter
    stage is vmapped.  Statistics may come from the local batch (the
    fused update below) or from a §4.1 cross-shard merge — the decision
    math is identical either way.
    """
    tcfg = cfg.tree
    M, F = tcfg.max_nodes, tcfg.n_features
    T = feat_mask.shape[0]
    flat = functools.partial(_fold_tables, T=T, M=M)
    attempt = jax.vmap(functools.partial(ht.attempt_mask, tcfg))(trees) \
        & (trees["n_nodes"][:, None] + 1 < M)

    def do(tr, att):
        # the folded T*M table axis compacts across trees: the ONE query
        # gathers only the attempting leaves of the whole ensemble
        ao_y, ao_sum_x = jax.tree.map(flat, tr["ao_y"]), flat(tr["ao_sum_x"])
        if tcfg.observer_backend == "sketch":
            ao_y, ao_sum_x = kops.sketch_to_bins(ao_y, ao_sum_x)  # §2.8
        merit, thr = kops.forest_best_splits(
            ao_y, ao_sum_x,
            flat(tr["ao_radius"]), flat(tr["ao_origin"]),
            att.reshape(-1), backend=tcfg.split_backend,
            compact=tcfg.compact_query)
        return jax.vmap(functools.partial(ht._apply_splits, tcfg))(
            tr, merit.reshape(T, M, F), thr.reshape(T, M, F), att,
            feat_mask)

    return jax.lax.cond(attempt.any(), do, lambda tr, a: dict(tr),
                        trees, attempt)


def _fused_member_update(cfg: ForestConfig, trees, feat_mask, X, y, w):
    """All T member updates as ONE flat pass over the PR-1 forest kernels.

    A naive ``vmap(hoeffding.update)`` turns every segment-reduction and
    scatter into a *batched* scatter, which XLA (CPU especially) lowers
    poorly — measured ~4x slower than a python loop over trees.  Instead
    the tree axis is folded into the table axis the kernels already
    batch over: T trees x M nodes become one (T*M, F, C) forest with
    global leaf ids ``t*M + leaf``, so absorb is ONE
    :func:`repro.kernels.ops.forest_update`, the split query ONE
    :func:`repro.kernels.ops.forest_best_splits` (both tree-count
    agnostic on every backend), and only the cheap O(M) decision/scatter
    stage (:func:`repro.core.hoeffding._apply_splits`) is vmapped.
    The three stages are factored (:func:`_fused_route_stats`,
    :func:`_fused_absorb_tables`, :func:`_fused_member_attempt`) so the
    §4.1 data-parallel trainer can run the first two per shard and the
    attempt globally on merged statistics.

    trees: stacked TreeStates (T leading); w: (T, B) sample weights.
    """
    gl, _, batch_leaf = _fused_route_stats(cfg, trees, X, y, w)
    trees = dict(trees,
                 ystats=stats.merge(trees["ystats"], batch_leaf),
                 seen_since_attempt=trees["seen_since_attempt"]
                 + batch_leaf["n"])
    ao_y, ao_sum_x = _fused_absorb_tables(
        cfg, trees["ao_y"], trees["ao_sum_x"], trees, gl, X, y, w)
    trees = dict(trees, ao_y=ao_y, ao_sum_x=ao_sum_x)
    return _fused_member_attempt(cfg, trees, feat_mask)


def update(cfg: ForestConfig, state: ForestState, X: jax.Array,
           y: jax.Array, axis_name: str | None = None,
           w: jax.Array | None = None):
    """Learn one batch, test-then-train.

    Evaluates every member on the incoming batch (prequential), folds the
    batch into every member with fresh Poisson(λ) sample weights, advances
    the per-member drift windows and resets the worst drifting member.
    ``w``: optional (B,) per-row weights multiplying every member's
    Poisson draw AND weighting the prequential errors — a weight-0 row is
    invisible to both learning and the drift windows, which is how
    :func:`update_stream` folds a ragged tail batch in without bias.

    Returns ``(state, aux)`` with
    ``aux = {"member_mse": (T,), "forest_mse": (), "drift": (T,) bool}``
    — prequential (pre-update) errors of this batch.  The member updates
    execute as one fused flat-forest pass (:func:`_fused_member_update`;
    ``split_backend="oracle"`` falls back to ``vmap(hoeffding.update)``
    as the correctness reference); with ``axis_name`` set (inside
    ``shard_map``) only the forest_mse vote reduce communicates.
    """
    X = jnp.asarray(X, jnp.float32)
    y = jnp.asarray(y, jnp.float32).reshape(-1)
    B = y.shape[0]
    row_w = jnp.ones_like(y) if w is None \
        else jnp.asarray(w, jnp.float32).reshape(-1)
    wsum = jnp.maximum(row_w.sum(), 1e-12)

    # --- test: prequential member + forest errors on the raw stream ------
    yhat = member_predictions(cfg, state, X)                   # (T, B)
    member_mse = (row_w[None, :] * (yhat - y[None, :]) ** 2).sum(1) / wsum
    fpred = _vote_combine(yhat, state["vote_w"], axis_name)
    forest_mse = (row_w * (fpred - y) ** 2).sum() / wsum

    # --- train: Poisson(λ) bagging weights, one fused member update ------
    split = jax.vmap(functools.partial(jax.random.split, num=3))(
        state["keys"])                                         # (T, 3, 2)
    keys, wkeys, mkeys = split[:, 0], split[:, 1], split[:, 2]
    cdf = jnp.asarray(_poisson_cdf(cfg.lam), jnp.float32)
    w = jax.vmap(lambda k: _poisson_weights(k, cdf, (B,)))(wkeys) \
        * row_w[None, :]                                       # (T, B)
    if cfg.tree.split_backend == "oracle":
        trees = jax.vmap(functools.partial(ht.update, cfg.tree),
                         in_axes=(0, None, None, 0, 0))(
            state["trees"], X, y, w, state["feat_mask"])
    else:
        trees = _fused_member_update(cfg, state["trees"], state["feat_mask"],
                                     X, y, w)

    # --- drift: ADWIN-style short-vs-long window test per member ---------
    # the short (EWMA) window is compared against the long window BEFORE
    # this batch is folded in — once errors jump, the reference must not
    # absorb the jump or the test chases its own tail and never fires.
    # The long window decays (effective length 1/(1-drift_decay) batches)
    # so the cold-start transient washes out of the reference.
    # Both windows advance by the batch's REAL-row fraction, not a full
    # step: a masked tail batch with one live row must not move the EWMA
    # at full drift_alpha (one outlier row could otherwise fire a
    # spurious member swap at stream end).
    live = row_w.sum() > 0
    # clamped at 1: importance weights > 1 must not push the EWMA rate
    # past drift_alpha (alpha > 1 would make the recursion sign-flip)
    frac = jnp.where(live,
                     jnp.minimum(wsum / jnp.maximum(jnp.float32(B), 1.0),
                                 1.0), 0.0)
    alpha = cfg.drift_alpha * frac
    first = (state["err_win"]["n"] < 0.5) & live
    ewma = jnp.where(first, member_mse,
                     (1.0 - alpha) * state["err_ewma"]
                     + alpha * member_mse)
    ref = state["err_win"]
    sd = jnp.sqrt(jnp.maximum(stats.variance(ref), 1e-12))
    signal = (ref["n"] >= cfg.drift_min_batches) \
        & (ewma > ref["mean"] + cfg.drift_kappa * sd)
    # swap at most the WORST signalling member per batch (per shard when
    # the tree axis is sharded): staggered resets keep the forest's memory
    worst = jnp.argmax(jnp.where(signal, ewma, -jnp.inf))
    drift = signal & (jnp.arange(signal.shape[0]) == worst)
    # the reference decays by the same real-mass fraction it observes
    # (decay^frac), so persistently sub-unit weights shift the window's
    # time constant instead of silently lowering its n equilibrium below
    # drift_min_batches (which would disarm detection); frac == 1 takes
    # the exact python constant so unweighted streams are bit-identical
    decay = jnp.where(frac >= 1.0, cfg.drift_decay,
                      jnp.float32(cfg.drift_decay) ** frac)
    decayed = {"n": decay * ref["n"], "mean": ref["mean"],
               "m2": decay * ref["m2"]}
    observed = stats.observe(decayed, member_mse, frac)
    # a signalling member's reference FREEZES (no decay, no observe): if it
    # wasn't this batch's worst it must keep its clean pre-drift reference
    # so it can fire again next batch — otherwise the window absorbs the
    # jump and simultaneous drifts beyond the first are never swapped
    win = jax.tree.map(
        lambda o, r: jnp.where(signal, r, o), observed, ref)

    # --- swap: reset drifting members (fresh tree, subspace, window) -----
    T = drift.shape[0]                   # local shard size under shard_map
    fresh = jax.tree.map(
        lambda a: jnp.broadcast_to(a[None], (T,) + a.shape),
        ht.init_state(cfg.tree))

    def swap(a, f):
        return jnp.where(drift.reshape((T,) + (1,) * (a.ndim - 1)), f, a)

    trees = jax.tree.map(swap, trees, fresh)
    new_masks = jax.vmap(functools.partial(
        _draw_mask, F=cfg.tree.n_features, k=cfg.subspace_k()))(mkeys)
    state = {
        "trees": trees,
        "feat_mask": jnp.where(drift[:, None], new_masks, state["feat_mask"]),
        "keys": keys,
        "err_win": jax.tree.map(lambda a: jnp.where(drift, 0.0, a), win),
        "err_ewma": jnp.where(drift, 0.0, ewma),
        "resets": state["resets"] + drift.astype(jnp.int32),
    }
    # vote weights refresh ONCE per learned batch; every read (predict,
    # the next batch's prequential vote, serve.freeze) reuses them
    state["vote_w"] = vote_weights(cfg, state)
    return state, {"member_mse": member_mse, "forest_mse": forest_mse,
                   "drift": drift}


@functools.partial(jax.jit, static_argnames=("cfg", "batch_size"))
def update_stream(cfg: ForestConfig, state: ForestState, X: jax.Array,
                  y: jax.Array, batch_size: int = 256):
    """Scan a whole stream through :func:`update` in ONE dispatch.

    X: (N, F), y: (N,).  A ragged tail rides in a final weight-0-masked
    batch (:func:`repro.core.hoeffding.pad_stream`: invisible to
    learning, bagging draws and the prequential windows), so ALL N rows
    are learned.  Returns ``(state, trace)`` where ``trace["forest_mse"]``
    is the (ceil(N / batch_size),) prequential forest MSE and
    ``trace["member_mse"]`` the (n_batches, T) per-member traces — the
    benchmark's acceptance data.
    """
    Xc, yc, wc = ht.pad_stream(X, y, None, batch_size)

    def body(s, xyw):
        s, aux = update(cfg, s, xyw[0], xyw[1], w=xyw[2])
        return s, (aux["forest_mse"], aux["member_mse"])

    state, (fmse, mmse) = jax.lax.scan(body, state, (Xc, yc, wc))
    return state, {"forest_mse": fmse, "member_mse": mmse}


def n_leaves_per_tree(state: ForestState) -> jax.Array:
    """(T,) i32 live-leaf count of every member (diagnostics)."""
    return jax.vmap(ht.n_leaves)(state["trees"])
