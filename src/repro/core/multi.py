"""Multi-target QO — the paper's §7 future-work extension, implemented.

For multi-target regression (iSOUP-Tree setting) each bin keeps one
(n, mean, M2) triple PER TARGET; the split merit is the mean Variance
Reduction across targets (Kocev et al.'s intra-cluster variance), computed
with the same prefix-merge/subtract machinery — the robust algebra of §3
is elementwise, so the extension is exactly the broadcast the paper
anticipated.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.core import stats
from repro.core.qo import SplitResult

MTQOTable = Dict[str, jax.Array]

__all__ = ["init", "update", "best_split", "n_slots"]


def init(capacity: int, n_targets: int, radius: float,
         origin: float = 0.0) -> MTQOTable:
    """Empty multi-target QO table.

    capacity: number of bins C; n_targets: targets per instance T;
    radius/origin: quantization as in :func:`repro.core.qo.init`.
    Returns a dict pytree with per-bin ``sum_x`` (C,) and target stats
    ``y`` of shape (C, T).
    """
    return {
        "radius": jnp.asarray(radius, jnp.float32),
        "origin": jnp.asarray(origin, jnp.float32),
        "sum_x": jnp.zeros((capacity,), jnp.float32),
        "y": stats.init((capacity, n_targets)),
    }


def _bin_ids(table, x):
    cap = table["sum_x"].shape[0]
    h = jnp.floor((x - table["origin"]) / table["radius"]).astype(jnp.int32)
    return jnp.clip(h + cap // 2, 0, cap - 1)


def update(table: MTQOTable, x, Y) -> MTQOTable:
    """Batched insert: one quantized bin per instance, all T targets.

    x: (n,) f32 feature values; Y: (n, T) f32 targets.  Returns a new
    table; per-bin (n, mean, M2) update as in the single-target
    :func:`repro.core.qo.update`, broadcast across the target axis.
    """
    x = jnp.asarray(x, jnp.float32).reshape(-1)
    Y = jnp.asarray(Y, jnp.float32)
    cap, T = table["y"]["n"].shape
    ids = _bin_ids(table, x)
    ones = jnp.ones_like(x)
    n_b = jax.ops.segment_sum(ones, ids, cap)                      # (C,)
    sx_b = jax.ops.segment_sum(x, ids, cap)
    sy_b = jax.ops.segment_sum(Y, ids, cap)                        # (C, T)
    safe = jnp.where(n_b > 0, n_b, 1.0)[:, None]
    mean_b = jnp.where(n_b[:, None] > 0, sy_b / safe, 0.0)
    m2_b = jax.ops.segment_sum((Y - mean_b[ids]) ** 2, ids, cap)
    tile = {"n": jnp.broadcast_to(n_b[:, None], (cap, T)),
            "mean": mean_b, "m2": m2_b}
    return {
        "radius": table["radius"],
        "origin": table["origin"],
        "sum_x": table["sum_x"] + sx_b,
        "y": stats.merge(table["y"], tile),
    }


def best_split(table: MTQOTable) -> SplitResult:
    """Mean-VR-across-targets split (multi-target Algorithm 2).

    Per-target VR is normalized by that target's whole-sample variance
    (Kocev et al.) before averaging, so large-scale targets don't
    dominate.  Returns a scalar :class:`repro.core.qo.SplitResult`.
    """
    ybins = table["y"]                                             # (C, T)
    occ = ybins["n"][:, 0] > 0
    cap = occ.shape[0]

    left = jax.lax.associative_scan(stats.merge, ybins)
    tot = jax.tree.map(lambda v: v[-1], left)
    right = stats.subtract(
        jax.tree.map(lambda v: jnp.broadcast_to(v, left["n"].shape), tot), left)
    n_tot = jnp.maximum(tot["n"], 1.0)
    vr_t = stats.variance(tot) \
        - (left["n"] / n_tot) * stats.variance(left) \
        - (right["n"] / n_tot) * stats.variance(right)             # (C, T)
    # normalize per target so large-scale targets don't dominate, then mean
    s2 = jnp.maximum(stats.variance(tot), 1e-12)
    vr = jnp.mean(vr_t / s2, axis=-1)                              # (C,)

    proto = jnp.where(occ, table["sum_x"] / jnp.where(occ, ybins["n"][:, 0], 1.0), 0.0)
    idx = jnp.arange(cap)
    last_occ = jax.lax.associative_scan(jnp.maximum, jnp.where(occ, idx, -1))
    first_from = jax.lax.associative_scan(
        jnp.minimum, jnp.where(occ, idx, cap)[::-1])[::-1]
    nxt = jnp.concatenate([first_from[1:], jnp.full((1,), cap)])
    ok = (last_occ >= 0) & (nxt < cap)
    cand = 0.5 * (proto[jnp.maximum(last_occ, 0)] + proto[jnp.minimum(nxt, cap - 1)])
    score = jnp.where(ok, vr, -jnp.inf)
    best = jnp.argmax(score)
    return SplitResult(threshold=cand[best],
                       merit=jnp.where(jnp.isfinite(score[best]),
                                       score[best], 0.0),
                       valid=ok.any())


def n_slots(table: MTQOTable) -> jax.Array:
    """|H| — number of occupied bins (the paper's memory metric), () i32."""
    return (table["y"]["n"][:, 0] > 0).sum()
