"""Frozen serving snapshots: the forest's read-optimized twin (DESIGN.md §5.5).

A live :mod:`repro.core.hoeffding` / :mod:`repro.core.forest` state is
write-optimized: fixed ``cfg.max_nodes`` capacity, allocation-ordered
node ids, QO tables and drift windows riding along — none of which the
read path needs.  :func:`freeze` packs a trained state into a
:class:`Snapshot` built for the paper's stated destination (real-time
prediction streams):

* **breadth-first reindex** — nodes renumber level by level, so a
  routing sweep touches a contiguous, front-loaded id range (ply d only
  ever selects ids below level d+1's end) and the hot top of every tree
  shares cache lines;
* **realized trim** — capacity drops from ``cfg.max_nodes`` to the
  nodes actually allocated (bucketed to a power of two so repeated
  freezes of a growing forest reuse compiled programs), and the stored
  ``depth`` is the deepest *realized* leaf, not ``cfg.max_depth`` — the
  routing sweep runs exactly as many plies as the trained tree needs;
* **pre-gathered read state** — leaf means (the predictor) and the
  forest's vote weights (carried by ``forest.update``) are baked in;
  QO tables, target stats and windows are dropped, shrinking serving
  state by ~C·F per node.

:func:`predict_snapshot` serves a snapshot through the §2.6 batched
routing engine with donated, cached jits bucketed on (batch, ply count)
— repeated calls at any request size hit compiled programs, never
retrace.  Predictions are bit-identical to the live state's
``predict`` on every backend: routing decisions are preserved by the
reindex (per-node feature/threshold ride along), gathered means are the
same f32 values, and the forest vote reuses
:func:`repro.core.forest._vote_combine` verbatim.
:func:`repro.train.sharding.build_sharded_serving` wraps the same body
in a batch-axis ``shard_map`` — the read-side complement of the
tree-axis training shard.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops as kops

__all__ = ["Snapshot", "SnapshotValidationError", "freeze",
           "validate_snapshot", "predict_snapshot", "clear_jit_caches"]


@dataclass(frozen=True)
class Snapshot:
    """Dense breadth-first serving layout (a registered pytree).

    Arrays carry a (T, Mr) tree axis even for a single tree (T = 1,
    ``single=True``): ``feature``/``is_leaf`` i32/bool, ``threshold``
    f32, ``child`` (T, Mr, 2) i32 (-1 at leaves), ``leaf_mean`` (T, Mr)
    f32, ``vote_w`` (T,) f32 (ones for a single tree).  ``depth`` (the
    realized ply count) and ``single`` are static aux data, so a
    Snapshot passes through jit/shard_map whole.

    ``version`` / ``step`` are scalar i32 *leaves*, not aux data: a
    publisher stamps every freeze with a monotonically increasing
    version and the trainer step it froze at, and because they ride as
    array leaves (i) re-publishing never changes the treedef — cached
    serving jits and ``build_sharded_serving`` builds stay warm across
    versions — and (ii) they round-trip through
    :class:`repro.checkpoint.ckpt.Checkpointer` by *value*, so staleness
    and rollback tests pin snapshot identity instead of comparing whole
    pytrees.
    """
    feature: jax.Array
    threshold: jax.Array
    child: jax.Array
    is_leaf: jax.Array
    leaf_mean: jax.Array
    vote_w: jax.Array
    depth: int
    single: bool
    version: jax.Array | int = 0
    step: jax.Array | int = 0


jax.tree_util.register_pytree_node(
    Snapshot,
    lambda s: ((s.feature, s.threshold, s.child, s.is_leaf, s.leaf_mean,
                s.vote_w, s.version, s.step), (s.depth, s.single)),
    lambda aux, ch: Snapshot(*ch[:6], *aux, *ch[6:]))


class SnapshotValidationError(ValueError):
    """A Snapshot violates the serving invariants (torn/corrupt model)."""


def validate_snapshot(snap: Snapshot) -> Snapshot:
    """Check the serving invariants; raise :class:`SnapshotValidationError`.

    The publish gate of the continuous-serving engine (DESIGN.md §5.6):
    every snapshot must satisfy, per tree,

    * finite thresholds and in-range feature ids on internal nodes;
    * children ids inside ``[0, Mr)``, each strictly greater than its
      parent's id and claimed by exactly one parent, root never a child
      — the BFS level-order contract :func:`_bfs_reindex` establishes;
    * ``-1`` children at leaves (pad rows are self-contained leaves);
    * finite leaf means and finite, non-negative vote weights;
    * non-negative ``version`` / ``step`` stamps.

    A host-side O(T·Mr) numpy pass — called once per freeze/publish,
    never on the per-request path.  Returns ``snap`` unchanged so
    callers can gate inline: ``publish(validate_snapshot(s))``.
    """
    feat = np.asarray(snap.feature)
    thr = np.asarray(snap.threshold)
    child = np.asarray(snap.child)
    is_leaf = np.asarray(snap.is_leaf)
    mean = np.asarray(snap.leaf_mean)
    vote_w = np.asarray(snap.vote_w)
    T, Mr = feat.shape

    def bad(msg):
        raise SnapshotValidationError(
            f"snapshot v{int(np.asarray(snap.version))} "
            f"(step {int(np.asarray(snap.step))}): {msg}")

    if not (np.isfinite(vote_w).all() and (vote_w >= 0).all()):
        bad("vote weights must be finite and non-negative")
    if not np.isfinite(mean).all():
        bad("leaf means must be finite")
    if int(np.asarray(snap.version)) < 0 or int(np.asarray(snap.step)) < 0:
        bad("version/step stamps must be non-negative")
    for t in range(T):
        internal = ~is_leaf[t]
        if not np.isfinite(thr[t][internal]).all():
            bad(f"tree {t}: non-finite threshold on an internal node")
        if internal.any() and (feat[t][internal] < 0).any():
            bad(f"tree {t}: negative feature id on an internal node")
        ch = child[t][internal]                       # (n_internal, 2)
        if (child[t][~internal] != -1).any():
            bad(f"tree {t}: leaf rows must carry -1 children")
        if internal.any():
            if ch.min() < 0 or ch.max() >= Mr:
                bad(f"tree {t}: child id out of range [0, {Mr})")
            parents = np.nonzero(internal)[0]
            if (ch <= parents[:, None]).any():
                bad(f"tree {t}: child id <= parent id breaks the BFS "
                    f"level-order contract")
            flat = ch.reshape(-1)
            if len(np.unique(flat)) != len(flat) or (flat == 0).any():
                bad(f"tree {t}: a node is claimed by two parents (or the "
                    f"root is a child)")
    return snap


def _bfs_reindex(feature, threshold, child, is_leaf, mean, Mr: int):
    """One tree's numpy arrays -> breadth-first arrays of capacity Mr.

    Walks the realized tree from the root (unallocated capacity is
    unreachable by construction and simply dropped).  Pad rows are
    self-contained leaves (mean 0) that routing can never reach.
    Returns the reindexed arrays + the realized depth.
    """
    order, node_depth = [0], [0]
    new_id = {0: 0}
    head = 0
    while head < len(order):
        u = order[head]
        head += 1
        if not is_leaf[u]:
            for c in child[u]:
                new_id[int(c)] = len(order)
                order.append(int(c))
                node_depth.append(node_depth[new_id[u]] + 1)
    n = len(order)
    assert n <= Mr, (n, Mr)
    f = np.zeros(Mr, np.int32)
    thr = np.zeros(Mr, np.float32)
    ch = np.full((Mr, 2), -1, np.int32)
    lf = np.ones(Mr, bool)
    mu = np.zeros(Mr, np.float32)
    for i, u in enumerate(order):
        f[i], thr[i], lf[i] = feature[u], threshold[u], is_leaf[u]
        mu[i] = mean[u] if is_leaf[u] else 0.0
        if not is_leaf[u]:
            ch[i] = [new_id[int(child[u][0])], new_id[int(child[u][1])]]
    return f, thr, ch, lf, mu, (max(node_depth) if n else 0)


def freeze(state, *, version: int = 0, step: int = 0) -> Snapshot:
    """Pack a trained tree or forest state into a serving Snapshot.

    ``state``: a :func:`repro.core.hoeffding.init_state` pytree (single
    tree) or a :func:`repro.core.forest.init_forest` pytree (detected by
    its ``"trees"`` key; the carried ``vote_w`` is read for free).  A
    host-side packing step — arrays must be concrete (freeze at the
    train/serve boundary, not inside a jit).  Capacity is trimmed to the
    realized node count (power-of-two bucketed, min 8) and ``depth`` to
    the deepest realized leaf across members.

    ``version``/``step``: the publisher's identity stamps (monotone
    version counter, trainer step frozen at) — scalar i32 leaves on the
    returned snapshot.  Every freeze runs :func:`validate_snapshot`
    before returning, so a snapshot that ever reaches a serving engine
    is structurally valid by construction; the engine's publish path
    re-validates after its fault-injection hooks (the rollback gate).
    """
    if "trees" in state:
        trees, vote_w, single = state["trees"], state["vote_w"], False
    else:
        trees = jax.tree.map(lambda a: a[None], state)
        vote_w, single = jnp.ones((1,), jnp.float32), True
    feat = np.asarray(trees["feature"])
    thr = np.asarray(trees["threshold"])
    child = np.asarray(trees["child"])
    is_leaf = np.asarray(trees["is_leaf"])
    mean = np.asarray(trees["ystats"]["mean"])
    n_nodes = np.asarray(trees["n_nodes"])
    T = feat.shape[0]

    Mr = 8
    while Mr < int(n_nodes.max()):
        Mr *= 2
    packed = [_bfs_reindex(feat[t], thr[t], child[t], is_leaf[t], mean[t], Mr)
              for t in range(T)]
    stack = lambda i: jnp.asarray(np.stack([p[i] for p in packed]))
    return validate_snapshot(Snapshot(
        feature=stack(0), threshold=stack(1), child=stack(2),
        is_leaf=stack(3), leaf_mean=stack(4),
        vote_w=jnp.asarray(vote_w, jnp.float32),
        depth=max(p[5] for p in packed), single=single,
        version=jnp.asarray(version, jnp.int32),
        step=jnp.asarray(step, jnp.int32)))


def _predict_impl(feature, threshold, child, is_leaf, leaf_mean, vote_w, X,
                  *, plies: int, backend: str, single: bool):
    """Route -> gather -> (vote): the whole read path, one fused body."""
    from repro.core.forest import _vote_combine
    leaf = kops.forest_route(feature, threshold, child, is_leaf, X,
                             depth=plies, backend=backend)
    member = jnp.take_along_axis(leaf_mean, leaf, axis=1)        # (T, B)
    if single:
        return member[0]
    return _vote_combine(member, vote_w, None)


@kops.register_jit_cache
@functools.lru_cache(maxsize=None)
def _jit_predict(backend: str, plies: int, single: bool):
    """Keyed handle for one (backend, ply-bucket) serving program (the
    ``_cache_size()``/``cache_info()`` regression hook); delegates to
    the shared :func:`repro.kernels.ops._dispatch` with ``donate_x`` —
    the X buffer is donated so XLA can reuse it for the sweep's
    node-state temporaries; :func:`predict_snapshot` guarantees the
    donated buffer is engine-owned (its pad copy, or an explicit device
    copy).  XLA:CPU cannot alias donated buffers (it would only warn per
    compile), so donation engages on TPU only — the shared factory's
    donation policy."""
    return kops._dispatch(_predict_impl, donate_x=True, plies=plies,
                          backend=backend, single=single)


def predict_snapshot(snap: Snapshot, X, *,
                     backend: str | None = None) -> jax.Array:
    """Serve a frozen snapshot: X (B, F) -> (B,) f32 predictions.

    Bit-identical to ``hoeffding.predict`` / ``forest.predict`` on the
    live state that was frozen, on every backend.  Concrete requests pad
    to their batch-ladder bucket and dispatch through donated cached
    jits keyed on (backend, realized-depth bucket) — a steady request
    stream never recompiles (``_jit_predict(...)._cache_size()`` is the
    regression hook).  The ladder and ply rounding are the tuned
    ``forest_route`` schedule knobs (the predict program IS a routing
    sweep plus a gather), so one tuning entry steers route and serve
    together.  Only an engine-owned buffer is ever donated: the padded
    copy when padding happened, else (TPU only) a defensive device copy
    of X — the caller's array is never consumed out from under a later
    reuse.  Under an enclosing trace the body inlines.
    """
    backend = kops.resolve_backend(backend)
    X = jnp.asarray(X, jnp.float32)
    tabs = (snap.feature, snap.threshold, snap.child, snap.is_leaf,
            snap.leaf_mean, snap.vote_w)
    if kops._is_traced(*tabs, X):
        return _predict_impl(*tabs, X, plies=snap.depth, backend=backend,
                             single=snap.single)
    T, Mr = snap.feature.shape
    p = kops.tuned("forest_route", backend,
                   kops._shape_class_route(T, Mr, int(X.shape[1])))
    X, B, padded = kops.pad_rows(X, 128, p["batch_ladder"])
    if not padded and jax.default_backend() == "tpu":
        X = jnp.copy(X)     # donate our copy, not the caller's buffer
    out = _jit_predict(backend, kops.depth_bucket(snap.depth,
                                                  p["ply_round"]),
                       snap.single)(*tabs, X)
    return out[:B] if padded else out


def clear_jit_caches() -> None:
    """Drop the cached serving jits (test hook; resets ``_cache_size``).
    Delegates to the shared :func:`repro.kernels.ops.clear_jit_caches`
    hook (this module's factory is registered there), so one call resets
    the whole process."""
    kops.clear_jit_caches()
