"""§Perf hillclimb runner: compile variant configurations of a dry-run
cell and report the roofline-term deltas.

    PYTHONPATH=src python -m repro.launch.perf --cell grok-1-314b:train_4k \
        --variant seq_parallel

Each variant is a named set of build overrides; results append to
perf_results.json with (cell, variant, three terms, deltas vs baseline).
"""
import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402

import jax  # noqa: E402

from repro.configs import get_arch, get_shape  # noqa: E402
from repro.launch import hlocost  # noqa: E402
from repro.launch.dryrun import (PEAK_FLOPS, HBM_BW, ICI_BW,  # noqa: E402
                                 model_flops)
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.train import steps as ST  # noqa: E402

VARIANTS = {
    "baseline": {},
    "seq_parallel": {"seq_parallel": True},
    "microbatch8": {"microbatch": 8},
    "microbatch16": {"microbatch": 16},
    "no_remat": {"remat": False},
    "no_remat_mb8": {"remat": False, "microbatch": 8},
    "seqpar_mb8": {"seq_parallel": True, "microbatch": 8},
    "seqpar_mb16": {"seq_parallel": True, "microbatch": 16},
    "kv2048": {"kv_chunk": 2048},
    "kv128": {"kv_chunk": 128},
    "seqpar_norematmb8": {"seq_parallel": True, "remat": False,
                          "microbatch": 8},
    "moe_bf16_combine": {"moe_bf16": True},
    "moe_bf16_mb16": {"moe_bf16": True, "microbatch": 16},
    "mamba2_ssd": {"ssd": True},
    "mamba2_ssd_mb8": {"ssd": True, "microbatch": 8},
    "weight_gather": {"sharding_style": "gather"},
    "wg_seqpar": {"sharding_style": "gather", "seq_parallel": True},
    "wg_mb16": {"sharding_style": "gather", "microbatch": 16},
    "wg_seqpar_mb8": {"sharding_style": "gather", "seq_parallel": True,
                      "microbatch": 8},
    "wg_ssd": {"sharding_style": "gather", "ssd": True},
    "wg_ssd_mb8": {"sharding_style": "gather", "ssd": True, "microbatch": 8},
    "lean": {"lean": True},
    "lean_mb16": {"lean": True, "microbatch": 16},
    "wg_seqpar_lean": {"sharding_style": "gather", "seq_parallel": True,
                       "lean": True},
    "ssd_mb8_lean": {"ssd": True, "microbatch": 8, "lean": True},
}


def run_variant(arch, shape_name, variant, extra=None, multi_pod=False):
    cfg = get_arch(arch)
    shape = get_shape(shape_name)
    over = dict(VARIANTS[variant])
    over.update(extra or {})
    # module-level implementation switches (not build args)
    import jax.numpy as jnp
    from repro.models import layers as L
    from repro.models import ssm as S
    L.set_moe_combine_dtype(
        jnp.bfloat16 if over.pop("moe_bf16", False) else jnp.float32)
    L.set_lean_internals(over.pop("lean", False))
    S.set_mamba2_impl("ssd" if over.pop("ssd", False) else "scan")
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    fn, _, _, shapes = ST.build_train_step(
        cfg, shape, mesh, donate=False, **over)
    with mesh:
        compiled = fn.lower(*shapes).compile()
    walked = hlocost.analyze(compiled.as_text())
    mem = compiled.memory_analysis()
    chips = mesh.devices.size
    mf = model_flops(cfg, shape)
    t_c = walked["flops"] / PEAK_FLOPS
    t_m = walked["bytes"] / HBM_BW
    t_x = walked["collective_bytes"] / ICI_BW
    return {
        "arch": arch, "shape": shape_name, "variant": variant,
        "overrides": over,
        "compile_s": round(time.time() - t0, 1),
        "t_compute_s": t_c, "t_memory_s": t_m, "t_collective_s": t_x,
        "dominant": max([("compute", t_c), ("memory", t_m),
                         ("collective", t_x)], key=lambda kv: kv[1])[0],
        "collectives": walked["collectives"],
        "useful_flops_ratio": (mf / chips) / walked["flops"],
        "roofline_fraction": (mf / chips / PEAK_FLOPS) / max(t_c, t_m, t_x),
        "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True, help="arch:shape")
    ap.add_argument("--variant", required=True,
                    help=f"one of {sorted(VARIANTS)} (comma separated ok)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default="/root/repo/perf_results.json")
    args = ap.parse_args()
    arch, shape = args.cell.split(":")

    results = []
    if os.path.exists(args.out):
        results = json.load(open(args.out))
    for variant in args.variant.split(","):
        print(f"=== {arch}:{shape} [{variant}] ===", flush=True)
        r = run_variant(arch, shape, variant, multi_pod=args.multi_pod)
        if args.multi_pod:
            r["variant"] = variant + "@2x16x16"
        print(json.dumps({k: v for k, v in r.items()
                          if k not in ("collectives",)}), flush=True)
        results.append(r)
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)


if __name__ == "__main__":
    main()
