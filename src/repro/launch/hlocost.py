"""Trip-count-aware HLO cost model.

``compiled.cost_analysis()`` counts every computation ONCE — a scan over
64 layers reports 1/64th of the real flops.  The scheduled HLO, however,
annotates every while op with ``backend_config={"known_trip_count":{"n":N}}``,
so we walk the module ourselves:

  * multiplicity(entry) = 1; a while op inside a computation with
    multiplicity m executes its body with multiplicity m * trip_count
    (nested scans multiply);
  * flops: counted for ``dot`` ops as 2 * prod(output) * prod(contracted
    lhs dims) * multiplicity (elementwise flops are <5% for these models
    and are ignored);
  * HBM bytes: for traffic-bearing ops (fusion, dot, copy, gather/scatter,
    dynamic-(update-)slice, reduce, transpose, collectives) we charge
    operand + result bytes * multiplicity.  Loop-invariant weights streamed
    each iteration are real HBM traffic and are correctly charged per trip;
  * collective bytes: result-shape bytes * multiplicity per collective op,
    reported by kind.

This is the flops/bytes source for :mod:`repro.perf.profile`'s
per-op cost harvest (cross-checkable against the analytic models in
:mod:`benchmarks.roofline`, DESIGN §8.2); raw cost_analysis numbers
are also recorded for reference.
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, List, Tuple

DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "u64": 8, "s64": 8,
               "u32": 4, "s32": 4, "u16": 2, "s16": 2, "u8": 1, "s8": 1,
               "pred": 1, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
               "f8e4m3": 1, "token": 0, "s4": 1, "u4": 1}

_SHAPE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->")
_INSTR = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\(")
_TRIP = re.compile(r'"known_trip_count":\{"n":"?(\d+)"?\}')
_CALLED = re.compile(r"(?:body|condition|to_apply|branch_computations|called_computations)=\{?%?([\w.\-]+(?:,\s*%?[\w.\-]+)*)\}?")

TRAFFIC_OPS = {
    "fusion", "dot", "copy", "gather", "scatter", "dynamic-slice",
    "dynamic-update-slice", "reduce", "transpose", "convolution",
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute", "all-gather-start", "all-reduce-start",
    "reduce-scatter-start", "all-to-all-start", "collective-permute-start",
    "reduce-window", "select-and-scatter", "sort", "concatenate", "pad",
    "slice", "reverse", "cholesky", "triangular-solve", "rng",
}
COLLECTIVES = {
    "all-gather": "all-gather", "all-gather-start": "all-gather",
    "all-reduce": "all-reduce", "all-reduce-start": "all-reduce",
    "reduce-scatter": "reduce-scatter", "reduce-scatter-start": "reduce-scatter",
    "all-to-all": "all-to-all", "all-to-all-start": "all-to-all",
    "collective-permute": "collective-permute",
    "collective-permute-start": "collective-permute",
}
SKIP_OPS = {"parameter", "constant", "tuple", "get-tuple-element", "bitcast",
            "reshape", "broadcast", "iota", "after-all", "partition-id",
            "replica-id", "while", "conditional", "call", "custom-call",
            "bitcast-convert", "convert", "compare", "add", "multiply",
            "subtract", "divide", "select", "exponential", "tanh", "negate",
            "maximum", "minimum", "rsqrt", "sqrt", "log", "and", "or", "not",
            "clamp", "floor", "ceil", "sign", "abs", "power", "remainder",
            "all-gather-done", "all-reduce-done", "reduce-scatter-done",
            "all-to-all-done", "collective-permute-done", "optimization-barrier",
            "get-dimension-size", "rng-bit-generator", "domain", "send",
            "recv", "send-done", "recv-done", "infeed", "outfeed", "map",
            "exponential-minus-one", "log-plus-one", "atan2", "cosine", "sine"}


def cost_dict(compiled) -> Dict[str, float]:
    """``compiled.cost_analysis()`` across jax versions: older jax returns
    one dict per partition, newer a single dict — normalize to a dict."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE.findall(type_str):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str):
    m = _SHAPE.search(type_str)
    if not m:
        return None, ()
    dims = tuple(int(d) for d in m.group(2).split(",") if d)
    return m.group(1), dims


class Instruction:
    __slots__ = ("name", "rtype", "op", "line")

    def __init__(self, name, rtype, op, line):
        self.name, self.rtype, self.op, self.line = name, rtype, op, line


def parse_module(hlo: str) -> Dict[str, List[Instruction]]:
    comps: Dict[str, List[Instruction]] = {}
    cur = None
    entry = None
    for line in hlo.splitlines():
        if not line.strip():
            continue
        if not line.startswith(" ") and ("->" in line) and "{" in line:
            m = _COMP_HDR.match(line.strip())
            if m:
                cur = m.group(1)
                comps[cur] = []
                if line.lstrip().startswith("ENTRY"):
                    entry = cur
                continue
        if cur is None:
            continue
        m = _INSTR.match(line)
        if m:
            comps[cur].append(Instruction(m.group(1), m.group(2),
                                          m.group(3), line))
    comps["__entry__"] = comps.get(entry, [])
    comps["__entry_name__"] = entry  # type: ignore
    return comps


def _dot_flops(instr: Instruction, symtab: Dict[str, Tuple[str, tuple]]) -> float:
    _, out_dims = _shape_dims(instr.rtype)
    out_n = 1
    for d in out_dims:
        out_n *= d
    # lhs operand: shape literals carry commas ("f32[64,64]{1,0} %name"),
    # so match the first inline shape (or fall back to the symbol table)
    # rather than splitting the argument list on ","
    lhs_dims = None
    ops = re.search(rf"{re.escape(instr.op)}\((.*?)\)", instr.line)
    if ops:
        args = ops.group(1)
        shape = _SHAPE.search(args)
        if shape:
            lhs_dims = tuple(int(d) for d in shape.group(2).split(",") if d)
        else:
            names = re.findall(r"%([\w.\-]+)", args)
            if names and names[0] in symtab:
                lhs_dims = symtab[names[0]][1]
    contract = 1
    mm = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", instr.line)
    if mm and lhs_dims:
        for idx in mm.group(1).split(","):
            if idx:
                i = int(idx)
                if i < len(lhs_dims):
                    contract *= lhs_dims[i]
    return 2.0 * out_n * max(contract, 1)


def analyze(hlo: str) -> Dict[str, float]:
    comps = parse_module(hlo)
    entry = comps.pop("__entry_name__")
    comps.pop("__entry__")

    # per-computation instruction symbol tables
    symtabs = {}
    for cname, instrs in comps.items():
        symtabs[cname] = {i.name: _shape_dims(i.rtype) for i in instrs}

    # trip count of the while loop DIRECTLY enclosing each computation —
    # used to de-amortize stacked scan buffers (see below)
    own_trip: Dict[str, float] = {}
    for cname, instrs in comps.items():
        for instr in instrs:
            if instr.op == "while":
                t = _TRIP.search(instr.line)
                trip = float(t.group(1)) if t else 1.0
                bodym = re.search(r"body=%?([\w.\-]+)", instr.line)
                if bodym:
                    own_trip[bodym.group(1)] = max(
                        own_trip.get(bodym.group(1), 1.0), trip)

    # multiplicities via BFS from entry
    mult = defaultdict(float)
    mult[entry] = 1.0
    order = [entry]
    seen = {entry}
    # iterate to fixpoint over call graph (it is a DAG)
    changed = True
    passes = 0
    while changed and passes < 50:
        changed = False
        passes += 1
        mult2 = defaultdict(float)
        mult2[entry] = 1.0
        for cname in list(comps):
            m = mult[cname] if cname in mult else 0.0
            if m == 0.0:
                continue
            for instr in comps[cname]:
                called = _CALLED.findall(instr.line)
                if not called:
                    continue
                factor = m
                if instr.op == "while":
                    t = _TRIP.search(instr.line)
                    trip = float(t.group(1)) if t else 1.0
                    bodym = re.search(r"body=%?([\w.\-]+)", instr.line)
                    condm = re.search(r"condition=%?([\w.\-]+)", instr.line)
                    if bodym:
                        mult2[bodym.group(1)] += m * trip
                    if condm:
                        mult2[condm.group(1)] += m * (trip + 1)
                    continue
                if instr.op == "fusion":
                    continue  # fusion subcomputation = internal, no HBM
                for group in called:
                    for cal in group.split(","):
                        mult2[cal.strip().lstrip("%")] += factor
        if dict(mult2) != dict(mult):
            mult = mult2
            changed = True

    flops = 0.0
    bytes_ = 0.0
    coll = defaultdict(float)
    for cname, instrs in comps.items():
        m = mult.get(cname, 0.0)
        if m == 0.0:
            continue
        trip = own_trip.get(cname, 1.0)
        symtab = symtabs[cname]

        def tensor_bytes(dt, dims):
            """Bytes of one tensor; a leading dim equal to the enclosing
            loop's trip count marks a stacked scan buffer (xs/ys or saved
            residuals) of which each iteration touches ONE slice."""
            n = 1
            for d in dims:
                n *= d
            b = n * DTYPE_BYTES.get(dt, 4)
            if trip > 1 and dims and float(dims[0]) == trip:
                b /= trip
            return b

        def operand_tensors(instr):
            ops = re.search(rf"{re.escape(instr.op)}\((.*?)\)(?:,|$)",
                            instr.line)
            out = []
            if ops:
                for opnd in ops.group(1).split(","):
                    nm = opnd.strip().split(" ")[-1].lstrip("%")
                    if nm in symtab:
                        out.append(symtab[nm])
            return out

        for instr in instrs:
            if instr.op in SKIP_OPS:
                continue
            if instr.op == "dot":
                flops += m * _dot_flops(instr, symtab)
            if instr.op in TRAFFIC_OPS:
                operands = operand_tensors(instr)
                rdt, rdims = _shape_dims(instr.rtype)
                if instr.op in ("dynamic-slice", "slice", "gather"):
                    # reads only the slice it produces
                    tb = 2 * tensor_bytes(rdt, rdims)
                elif instr.op in ("dynamic-update-slice", "scatter"):
                    # in-place: read+write of the update operand only
                    upd = operands[1] if len(operands) > 1 else (rdt, rdims)
                    tb = 2 * tensor_bytes(*upd)
                else:
                    tb = sum(tensor_bytes(*o) for o in operands)
                    for sdt, sdims in _SHAPE.findall(instr.rtype):
                        dims = tuple(int(d) for d in sdims.split(",") if d)
                        tb += tensor_bytes(sdt, dims)
                bytes_ += m * tb
            if instr.op in COLLECTIVES:
                coll[COLLECTIVES[instr.op]] += m * _shape_bytes(instr.rtype)
    return {
        "flops": flops,
        "bytes": bytes_,
        "collectives": dict(coll),
        "collective_bytes": sum(coll.values()),
    }
