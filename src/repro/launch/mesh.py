"""Production mesh construction (assignment spec).

A FUNCTION, not a module-level constant — importing this module must not
touch jax device state (the dry-run sets XLA_FLAGS before any jax init).
"""
from __future__ import annotations

import jax

__all__ = ["make_mesh_auto", "make_production_mesh", "make_local_mesh"]


def make_mesh_auto(shape, axes):
    """``jax.make_mesh`` with explicit Auto axis types where this jax
    version supports them (``axis_types`` landed after 0.4.37; Auto is the
    default either way)."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(axis_type.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_auto(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests / CPU smoke)."""
    n = len(jax.devices())
    data = min(data, n)
    model = min(model, n // data)
    return make_mesh_auto((data, model), ("data", "model"))
