"""CLI training launcher.

    PYTHONPATH=src python -m repro.launch.train \
        --arch qwen3-8b --reduced --steps 200 --batch 8 --seq 256

On a real TPU deployment: drop --reduced, point --mesh at production
(16x16 / 2x16x16) and the same code paths run; the container runs reduced
configs on a local CPU mesh.  Auto-resumes from --ckpt-dir if a checkpoint
exists; SIGTERM triggers a final save (preemption-safe).
"""
from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp

from repro import configs
from repro.configs import ShapeConfig, reduced
from repro.data.tokens import TokenStream
from repro.launch.mesh import make_local_mesh, make_production_mesh
from repro.models import layers as L
from repro.optim import adamw
from repro.train.loop import LoopConfig, Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--mesh", default="local", choices=["local", "pod", "multipod"])
    ap.add_argument("--data-par", type=int, default=1)
    ap.add_argument("--model-par", type=int, default=1)
    ap.add_argument("--f32", action="store_true", default=True)
    args = ap.parse_args()

    cfg = configs.get_arch(args.arch)
    if args.reduced:
        cfg = reduced(cfg, d_model=args.d_model, n_layers=args.layers,
                      n_heads=max(4, args.d_model // 32),
                      n_kv_heads=max(4, args.d_model // 32) if cfg.n_kv_heads else 0,
                      d_ff=args.d_model * 4, head_dim=32)
    if args.f32 and jax.default_backend() != "tpu":
        L.set_compute_dtype(jnp.float32)

    if args.mesh == "local":
        mesh = make_local_mesh(args.data_par, args.model_par)
    else:
        mesh = make_production_mesh(multi_pod=(args.mesh == "multipod"))

    shape = ShapeConfig("cli_train", args.seq, args.batch, "train")
    data = TokenStream(vocab=cfg.vocab, seq_len=args.seq,
                       global_batch=args.batch)
    lc = LoopConfig(total_steps=args.steps, ckpt_every=args.ckpt_every,
                    ckpt_dir=args.ckpt_dir, microbatch=args.microbatch)
    opt = adamw.AdamWConfig(lr=args.lr, total_steps=args.steps,
                            warmup_steps=max(10, args.steps // 20))
    trainer = Trainer(cfg, shape, mesh, data, lc, opt)
    _, _, mon, history = trainer.run(
        log_fn=lambda rec: print(json.dumps(rec), flush=True))
    from repro.train import monitor as MON
    print(json.dumps({"monitor": {
        k: {kk: float(vv) for kk, vv in s.items()}
        for k, s in MON.summaries(mon).items()}}, indent=1))


if __name__ == "__main__":
    main()
