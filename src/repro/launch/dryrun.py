"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

MUST be the very first two lines — before ANY other import — because jax
locks the device count on first init:
"""
import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

import argparse  # noqa: E402
import json  # noqa: E402
import re  # noqa: E402
import sys  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro import configs  # noqa: E402
from repro.configs import SHAPES, get_arch, get_shape  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.train import steps as ST  # noqa: E402

# TPU v5e-like roofline constants (assignment spec)
PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s / link

DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "u32": 4, "s32": 4,
               "u16": 2, "s16": 2, "u8": 1, "s8": 1, "pred": 1,
               "s64": 8, "u64": 8, "c64": 8, "c128": 16,
               "f8e4m3fn": 1, "f8e5m2": 1}

SHAPE_RE = re.compile(r"\b(f64|f32|bf16|f16|f8e4m3fn|f8e5m2|u64|s64|u32|s32|"
                      r"u16|s16|u8|s8|pred|c64|c128)\[([0-9,]*)\]")

_COLL_LINE = re.compile(
    r"=\s*(.+?)\s+(all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(-start)?\(")


def collective_bytes(hlo_text: str):
    """Sum result-shape bytes of every collective op in the HLO.

    The result shape is what travels per device for all-gather/all-to-all;
    for all-reduce it is ~2x on a ring (ignored — constant factor).  Async
    ``-start`` forms are counted once; ``-done`` lines don't match (no
    shape between '=' and the op keyword matters — they still parse, so we
    explicitly skip them).
    """
    per_kind = {}
    for line in hlo_text.splitlines():
        if "-done(" in line or "-done.(" in line:
            continue
        m = _COLL_LINE.search(line)
        if not m:
            continue
        kind = m.group(2)
        bytes_ = 0
        for dt, dims in SHAPE_RE.findall(m.group(1)):
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            bytes_ += n * DTYPE_BYTES.get(dt, 4)
        per_kind[kind] = per_kind.get(kind, 0) + bytes_
    return per_kind


def model_flops(cfg, shape):
    """Analytic MODEL_FLOPS: 6·N·D train, 2·N·D per generated token decode
    (N = active params)."""
    n = cfg.n_active_params()
    if shape.kind == "train":
        return 6.0 * n * shape.seq_len * shape.global_batch
    if shape.kind == "prefill":
        return 2.0 * n * shape.seq_len * shape.global_batch
    return 2.0 * n * shape.global_batch  # one decode step


def should_skip(cfg, shape) -> str:
    """Returns a reason string if this cell is a designed skip, else ''."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return "full attention at 524k ctx (quadratic) — designed skip per assignment"
    return ""


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             kv_chunk=512, microbatch=0, remat=True):
    cfg = get_arch(arch)
    shape = get_shape(shape_name)
    skip = should_skip(cfg, shape)
    result = {"arch": arch, "shape": shape_name,
              "mesh": "2x16x16" if multi_pod else "16x16"}
    if skip:
        result["status"] = "skipped"
        result["reason"] = skip
        return result

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    t0 = time.time()
    try:
        if shape.kind == "train":
            fn, in_sh, _, shapes = ST.build_train_step(
                cfg, shape, mesh, microbatch=microbatch, remat=remat,
                kv_chunk=kv_chunk, with_monitor=True, donate=False)
            pshapes, oshapes, bshapes, mshape = shapes
            with mesh:
                lowered = fn.lower(pshapes, oshapes, bshapes, mshape)
        elif shape.kind == "prefill":
            prefill_jit, _, shapes = ST.build_serve_steps(
                cfg, shape, mesh, kv_chunk=kv_chunk)
            pshapes, cache_shapes, prefill_shapes, _ = shapes
            with mesh:
                lowered = prefill_jit.lower(pshapes, prefill_shapes, cache_shapes)
        else:  # decode
            _, decode_jit, shapes = ST.build_serve_steps(
                cfg, shape, mesh, kv_chunk=kv_chunk)
            pshapes, cache_shapes, _, dec = shapes
            with mesh:
                lowered = decode_jit.lower(pshapes, dec["token"], cache_shapes,
                                           dec["pos"])
        compiled = lowered.compile()
    except Exception as e:  # a failure here is a bug in our sharding
        result["status"] = "FAILED"
        result["error"] = f"{type(e).__name__}: {e}"
        result["traceback"] = traceback.format_exc()[-2000:]
        return result

    from repro.launch import hlocost
    cost = hlocost.cost_dict(compiled)
    mem = compiled.memory_analysis()
    hlo = compiled.as_text()
    # trip-count-aware walk (cost_analysis counts scan bodies once)
    walked = hlocost.analyze(hlo)
    coll = walked["collectives"]
    coll_total = walked["collective_bytes"]

    flops = walked["flops"]
    bytes_ = walked["bytes"]
    t_compute = flops / PEAK_FLOPS
    t_memory = bytes_ / HBM_BW
    t_coll = coll_total / ICI_BW
    mf = model_flops(cfg, shape)

    result.update({
        "status": "ok",
        "compile_s": round(time.time() - t0, 1),
        "chips": chips,
        "hlo_flops_per_chip": flops,
        "hlo_bytes_per_chip": bytes_,
        "collective_bytes_per_chip": coll_total,
        "collective_breakdown": coll,
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "bottleneck": max(
            [("compute", t_compute), ("memory", t_memory),
             ("collective", t_coll)], key=lambda kv: kv[1])[0],
        "model_flops_total": mf,
        "useful_flops_ratio": (mf / chips) / flops if flops else 0.0,
        "roofline_fraction": (mf / chips / PEAK_FLOPS)
            / max(t_compute, t_memory, t_coll)
            if max(t_compute, t_memory, t_coll) > 0 else 0.0,
        "raw_cost_analysis_flops": float(cost.get("flops", 0.0)),
        "memory_analysis": {
            "argument_size_bytes": getattr(mem, "argument_size_in_bytes", 0),
            "output_size_bytes": getattr(mem, "output_size_in_bytes", 0),
            "temp_size_bytes": getattr(mem, "temp_size_in_bytes", 0),
            "generated_code_size_bytes": getattr(
                mem, "generated_code_size_in_bytes", 0),
        },
    })
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="/root/repo/dryrun_results.json")
    ap.add_argument("--kv-chunk", type=int, default=512)
    ap.add_argument("--append", action="store_true")
    args = ap.parse_args()

    archs = sorted(configs.ARCHS) if args.arch == "all" else [args.arch]
    shapes = [s.name for s in SHAPES] if args.shape == "all" else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    results = []
    if args.append and os.path.exists(args.out):
        results = json.load(open(args.out))
    done = {(r["arch"], r["shape"], r["mesh"]) for r in results}

    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                key = (arch, shape, "2x16x16" if mp else "16x16")
                if key in done:
                    continue
                print(f"=== {arch} x {shape} x {key[2]} ===", flush=True)
                r = run_cell(arch, shape, mp, kv_chunk=args.kv_chunk)
                print(json.dumps({k: v for k, v in r.items()
                                  if k not in ("traceback", "collective_breakdown",
                                               "memory_analysis")}),
                      flush=True)
                results.append(r)
                with open(args.out, "w") as f:
                    json.dump(results, f, indent=1)

    n_fail = sum(1 for r in results if r["status"] == "FAILED")
    print(f"\n{len(results)} cells, {n_fail} failures")
    sys.exit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
