"""Sharded, atomic, async checkpointing with elastic resharding.

Layout on disk::

    <dir>/step_000123/
        manifest.json       # step, leaf paths, shapes, dtypes, crc32s
        shard_<host>.npz    # this host's param/opt leaves (flattened keys)
    <dir>/LATEST            # atomic pointer (written via rename)

Design points for 1000+ node deployments (DESIGN.md §7):
* writes go to a temp dir then ``os.rename`` — a preempted writer never
  corrupts the latest checkpoint;
* an async writer thread overlaps serialization with the next train steps
  (the train loop only blocks if a previous write is still in flight);
* ``restore`` validates the save-time manifest (per-leaf CRC32 + the
  schema: shape/dtype of every leaf) and raises
  :class:`CheckpointCorruption` on ANY defect — truncated/unreadable
  shard files included — instead of crashing mid-deserialize;
* ``restore_latest`` walks checkpoints newest-first and silently skips
  corrupt or truncated ones, falling back to the previous good step (the
  serving engine's crash-recovery entry point: a trainer killed mid-save
  must never take recovery down with it);
* ``restore`` returns leaves for the *current* mesh —
  resharding to a different device count/mesh is free because leaves are
  stored unsharded per host here (single-host container); the
  ``reshard`` helper re-places a restored tree onto any new sharding tree,
  which is the elastic-restart path;
* per-host shard files mean restore IO parallelizes across hosts.
"""
from __future__ import annotations

import json
import os
import shutil
import sys
import threading
import zlib
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

_SEP = "/"


class CheckpointCorruption(IOError):
    """A checkpoint step failed validation (CRC/schema/shape mismatch,
    missing leaf, truncated or unreadable file).  Subclasses ``IOError``
    so pre-existing ``except IOError`` call sites keep working."""


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _unflatten_into(template, flat: Dict[str, np.ndarray]):
    paths, tdef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in paths:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        if key not in flat:
            raise CheckpointCorruption(f"checkpoint missing leaf {key!r}")
        arr = flat[key]
        if arr.shape != tuple(leaf.shape):
            raise CheckpointCorruption(
                f"checkpoint leaf {key!r} shape {arr.shape} != template "
                f"{tuple(leaf.shape)}")
        leaves.append(arr.astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(tdef, leaves)


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3, host_id: int = 0):
        self.dir = directory
        self.keep = keep
        self.host_id = host_id
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # -- write ------------------------------------------------------------

    def save(self, step: int, tree, blocking: bool = False):
        """Snapshot ``tree`` (device -> host copy happens synchronously so
        training can donate buffers; file IO happens on a worker thread)."""
        host_tree = jax.tree.map(np.asarray, tree)  # sync device->host
        self.wait()  # one write in flight at a time

        def _write():
            tmp = os.path.join(self.dir, f".tmp_step_{step:09d}")
            final = os.path.join(self.dir, f"step_{step:09d}")
            os.makedirs(tmp, exist_ok=True)
            flat = _flatten(host_tree)
            crcs = {}
            shard = os.path.join(tmp, f"shard_{self.host_id}.npz")
            np.savez(shard, **flat)
            for k, v in flat.items():
                crcs[k] = zlib.crc32(v.tobytes())
            manifest = {
                "step": step,
                "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype),
                               "crc32": crcs[k]} for k, v in flat.items()},
            }
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
            # atomic LATEST pointer
            ptr_tmp = os.path.join(self.dir, ".LATEST.tmp")
            with open(ptr_tmp, "w") as f:
                f.write(f"step_{step:09d}")
            os.rename(ptr_tmp, os.path.join(self.dir, "LATEST"))
            self._gc()

        if blocking:
            _write()
        else:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = sorted(d for d in os.listdir(self.dir) if d.startswith("step_"))
        for d in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, d), ignore_errors=True)

    # -- read -------------------------------------------------------------

    def latest_step(self) -> Optional[int]:
        ptr = os.path.join(self.dir, "LATEST")
        if not os.path.exists(ptr):
            return None
        with open(ptr) as f:
            name = f.read().strip()
        if not os.path.isdir(os.path.join(self.dir, name)):
            return None
        return int(name.split("_")[1])

    def available_steps(self) -> List[int]:
        """All step directories on disk, ascending (completed renames
        only — a crashed writer's ``.tmp_step_*`` never appears)."""
        return sorted(int(d.split("_")[1]) for d in os.listdir(self.dir)
                      if d.startswith("step_"))

    def restore(self, step: int, template, verify: bool = True):
        """CRC-checked restore into the structure of ``template``.

        ``template`` is any pytree of arrays or ShapeDtypeStructs (from
        ``jax.eval_shape``) with the saved tree's structure — including
        registered-pytree dataclasses, whose static aux data (e.g. a
        :class:`repro.core.serve.Snapshot`'s ``depth``/``single``) rides
        in the treedef and is reproduced exactly.  Streaming-forest
        states (:func:`repro.core.forest.init_forest`) and serving
        snapshots round-trip bit-exactly: every leaf is a plain f32 /
        int / bool array, so ``save`` → ``restore`` → ``predict`` is
        pinned bitwise by tests/test_checkpoint.py.
        """
        d = os.path.join(self.dir, f"step_{step:09d}")
        try:
            with open(os.path.join(d, "manifest.json")) as f:
                manifest = json.load(f)
            flat = dict(np.load(os.path.join(d, f"shard_{self.host_id}.npz")))
        except CheckpointCorruption:
            raise
        except Exception as e:
            # truncated npz (BadZipFile), missing files, mangled json, a
            # leaf npy cut short mid-write — all surface as ONE typed
            # error instead of crashing mid-deserialize
            raise CheckpointCorruption(
                f"checkpoint step {step} unreadable: {e!r}") from e
        if verify:
            leaves = manifest.get("leaves", {})
            if set(leaves) != set(flat):
                raise CheckpointCorruption(
                    f"checkpoint corruption at step {step}: manifest names "
                    f"{len(leaves)} leaves, shard holds {len(flat)}")
            for k, v in flat.items():
                meta = leaves[k]
                if (list(v.shape) != meta["shape"]
                        or str(v.dtype) != meta["dtype"]):
                    raise CheckpointCorruption(
                        f"checkpoint corruption in leaf {k!r}: saved "
                        f"{v.shape}/{v.dtype} != manifest "
                        f"{meta['shape']}/{meta['dtype']}")
                if meta["crc32"] != zlib.crc32(v.tobytes()):
                    raise CheckpointCorruption(
                        f"checkpoint corruption in leaf {k!r}")
        return _unflatten_into(template, flat)

    def restore_latest(self, template, verify: bool = True,
                       return_step: bool = False):
        """Restore the newest *valid* checkpoint (the crash-recovery
        entry point).

        Starts at the LATEST pointer, then walks every completed step
        directory newest-first: a corrupt, truncated or schema-mismatched
        step is logged and SKIPPED (falling back to the previous good
        one) instead of crashing recovery — the fault the atomic-rename
        writer cannot rule out is a torn *disk*, not a torn rename.
        Raises ``FileNotFoundError`` when no valid checkpoint exists.
        ``return_step=True`` returns ``(tree, step)`` so a recovering
        trainer knows where to resume its stream.
        """
        candidates = []
        latest = self.latest_step()
        if latest is not None:
            candidates.append(latest)
        for s in sorted(self.available_steps(), reverse=True):
            if s not in candidates:
                candidates.append(s)
        for step in candidates:
            try:
                tree = self.restore(step, template, verify=verify)
            except CheckpointCorruption as e:
                print(f"checkpoint: skipping step {step}: {e}",
                      file=sys.stderr)
                continue
            return (tree, step) if return_step else tree
        raise FileNotFoundError(f"no valid checkpoint under {self.dir!r}")


def reshard(tree, sharding_tree):
    """Re-place a (restored, host-resident) tree onto new shardings —
    the elastic-restart path when the mesh shape changed between runs."""
    return jax.tree.map(
        lambda x, s: jax.device_put(x, s), tree, sharding_tree)
