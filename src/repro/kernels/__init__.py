# Pallas kernel layer for the QO hot spots (DESIGN.md §2):
#   qo_update.py        — single-table batched insert (Algorithm 1)
#   qo_query.py         — single-table split query (Algorithm 2)
#   qo_update_leaves.py — forest-scale insert: every (leaf, feature) table
#   qo_query_batched.py — forest-scale query with attempt masking
#   qo_route.py         — level-synchronous batched routing (read path)
#   ops.py              — public wrappers (pallas | interpret | jnp backends)
#   ref.py              — pure-jnp oracles delegating to repro.core.qo
