"""Pallas TPU kernel for the QO split-candidate query (paper Algorithm 2).

Dense bin ids arrive pre-sorted, so the paper's ``sorted(H)`` sweep becomes
an inclusive prefix *merge* over the lane dimension.  The Chan merge is
associative, so the scan is computed with log2(C) Hillis-Steele steps of
shift + merge — all vectorized over the C lanes, no sequential loop.

For every boundary i (split between bin i and the next occupied bin) the
kernel evaluates the Variance Reduction

    VR_i = s2(d) - nL/n * s2(left_i) - nR/n * s2(right_i)

with right = total - left via the paper's subtraction (Eqs. 6-7), plus the
candidate threshold (midpoint of neighbouring occupied prototypes, as in
Algorithm 2).  Outputs (8, C) f32: row 0 = VR scores (-inf where invalid),
row 1 = candidate thresholds.  The argmax is a trivial epilogue in ops.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.qo_update import ROW_N, ROW_MEAN, ROW_M2, ROW_SUMX, TABLE_ROWS


def _shift_right(arr, d, fill):
    """arr shifted right by static d along its (only) axis, filled left."""
    pad = jnp.full((d,), fill, arr.dtype)
    return jnp.concatenate([pad, arr[:-d]])


def _merge(n_a, mean_a, m2_a, n_b, mean_b, m2_b):
    n = n_a + n_b
    safe = jnp.where(n > 0, n, 1.0)
    delta = mean_b - mean_a
    mean = jnp.where(n > 0, (n_a * mean_a + n_b * mean_b) / safe, 0.0)
    m2 = jnp.where(n > 0, m2_a + m2_b + delta * delta * (n_a * n_b) / safe, 0.0)
    return n, mean, m2


def _qo_query_kernel(tab_ref, out_ref):
    cap = tab_ref.shape[1]
    n = tab_ref[ROW_N, :]
    mean = tab_ref[ROW_MEAN, :]
    m2 = tab_ref[ROW_M2, :]
    sum_x = tab_ref[ROW_SUMX, :]
    occ = n > 0

    # ---- inclusive prefix merge (Hillis-Steele over lanes) ---------------
    pn, pmean, pm2 = n, mean, m2
    d = 1
    while d < cap:
        sn = _shift_right(pn, d, 0.0)
        smean = _shift_right(pmean, d, 0.0)
        sm2 = _shift_right(pm2, d, 0.0)
        pn, pmean, pm2 = _merge(sn, smean, sm2, pn, pmean, pm2)
        d *= 2

    tot_n = pn[cap - 1]
    tot_mean = pmean[cap - 1]
    tot_m2 = pm2[cap - 1]

    # ---- complement via the paper's subtraction (Eqs. 6-7) ---------------
    rn = tot_n - pn
    safe_rn = jnp.where(rn > 0, rn, 1.0)
    rmean = jnp.where(rn > 0, (tot_n * tot_mean - pn * pmean) / safe_rn, 0.0)
    delta = pmean - rmean
    safe_tot = jnp.where(tot_n > 0, tot_n, 1.0)
    rm2 = tot_m2 - pm2 - delta * delta * (rn * pn) / safe_tot
    rm2 = jnp.where(rn > 0, jnp.maximum(rm2, 0.0), 0.0)

    def var(nn, mm2):
        d_ = nn - 1.0
        return jnp.where(d_ > 0, mm2 / jnp.where(d_ > 0, d_, 1.0), 0.0)

    s2_d = jnp.where(tot_n > 1, tot_m2 / jnp.maximum(tot_n - 1.0, 1.0), 0.0)
    n_tot = jnp.maximum(tot_n, 1.0)
    vr = s2_d - (pn / n_tot) * var(pn, pm2) - (rn / n_tot) * var(rn, rm2)

    # ---- candidate thresholds & validity ---------------------------------
    lane = jax.lax.broadcasted_iota(jnp.int32, (1, cap), 1)[0, :]
    # last occupied index at-or-before i: max-scan of (occ ? lane : -1)
    lastv = jnp.where(occ, lane, -1)
    d = 1
    while d < cap:
        lastv = jnp.maximum(lastv, _shift_right(lastv, d, -1))
        d *= 2
    # first occupied index at-or-after i: cap - 1 - reversed-max-scan trick
    firstv = jnp.where(occ, lane, 2 * cap)
    d = 1
    while d < cap:
        shifted = jnp.concatenate([firstv[d:], jnp.full((d,), 2 * cap, firstv.dtype)])
        firstv = jnp.minimum(firstv, shifted)
        d *= 2
    nxt = jnp.concatenate([firstv[1:], jnp.full((1,), 2 * cap, firstv.dtype)])
    ok = (lastv >= 0) & (nxt < cap)

    proto = jnp.where(occ, sum_x / jnp.where(occ, n, 1.0), 0.0)
    gather_l = jnp.sum(
        jnp.where(lane[None, :] == jnp.maximum(lastv, 0)[:, None], proto[None, :], 0.0),
        axis=1)
    gather_r = jnp.sum(
        jnp.where(lane[None, :] == jnp.minimum(nxt, cap - 1)[:, None], proto[None, :], 0.0),
        axis=1)
    cand = 0.5 * (gather_l + gather_r)

    out_ref[0, :] = jnp.where(ok, vr, -jnp.inf)
    out_ref[1, :] = cand
    zero = jnp.zeros((cap,), jnp.float32)
    for r in range(2, TABLE_ROWS):
        out_ref[r, :] = zero


@functools.partial(jax.jit, static_argnames=("interpret",))
def qo_query_pallas(table: jax.Array, *, interpret: bool = False) -> jax.Array:
    """table: (8, C) -> (8, C): row 0 = VR scores, row 1 = thresholds."""
    cap = table.shape[1]
    return pl.pallas_call(
        _qo_query_kernel,
        in_specs=[pl.BlockSpec((TABLE_ROWS, cap), lambda: (0, 0))],
        out_specs=pl.BlockSpec((TABLE_ROWS, cap), lambda: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((TABLE_ROWS, cap), jnp.float32),
        interpret=interpret,
    )(table)
