"""Pallas TPU kernel: rank-bucket compaction of sorted sketch centroids.

The compute stage of the sketch observer's compaction (DESIGN.md §2.8):
the jnp caller sorts each table's J centroids by prototype and assigns
rank buckets (``repro.core.sketch.sort_planes`` / ``_bucket_ids`` — sort
networks don't pay their way in a hand kernel), and this kernel reduces
each bucket with the exact grouped two-pass (n, mean, M2) form:

    grid  = (row-tiles,)
    in    = (5, tile_r, Jp)     rows: n / mean / M2 / sum_x / bucket
    out   = (4, tile_r, Kp)

with the (T·M, F) table axes flattened to R rows (same packing idiom as
``qo_merge``), J input centroids and K output buckets each padded to the
128-lane tile.  Per output bucket k (static unrolled loop — K is a
config constant, typically 8-64):

    mask_k = (bucket == k)                            VPU compare
    n_k, Σwy_k, Σwx_k = Σ_lanes mask_k · plane        row reduction
    mean_k = Σwy_k / n_k                              (0 where n_k == 0)
    M2_k   = Σ_lanes mask_k · (M2 + n·(mean − mean_k)²)

and the k-th output lane is selected with a ``broadcasted_iota`` one-hot
(1-D iota doesn't lower on TPU).  Pad lanes carry bucket = −1 and zero
weight, so they match no k and contribute nothing; pad rows produce
all-zero output rows.  Exactness: bucket statistics are bit-for-bit a
fixed-order reduction of their member centroids, so kernel vs jnp
``segment_sum`` agree to f32 reduction-order tolerance (the tuner gate
compares bitwise only within one backend).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.qo_update_leaves import round_up

__all__ = ["pack_compact_planes", "unpack_compact_planes",
           "sketch_compact_pallas"]


def pack_compact_planes(n, mean, m2, sum_x, bucket, *,
                        tile_r: int = 256) -> jax.Array:
    """Sorted (..., J) centroid planes + bucket ids -> (5, Rp, Jp) blocks.

    Leading axes flatten row-major to R rows; rows pad to the row tile
    and lanes to 128.  Bucket ids ride as f32 with −1 in every pad lane
    and pad row, so padding can never alias a real bucket.
    """
    J = n.shape[-1]
    R = 1
    for d in n.shape[:-1]:
        R *= d
    Jp, Rp = round_up(J, 128), round_up(R, tile_r)
    planes = jnp.stack([a.reshape(R, J) for a in
                        (n, mean, m2, sum_x, bucket.astype(jnp.float32))])
    return jnp.full((5, Rp, Jp), -1.0, jnp.float32) \
        .at[:4].set(0.0).at[:, :R, :J].set(planes)


def unpack_compact_planes(dense: jax.Array, lead, k_out: int):
    """Dense (4, Rp, Kp) -> four ``lead + (k_out,)`` planes."""
    R = 1
    for d in lead:
        R *= d
    planes = dense[:, :R, :k_out].reshape((4,) + tuple(lead) + (k_out,))
    return planes[0], planes[1], planes[2], planes[3]


def _sketch_compact_kernel(a_ref, o_ref, *, k_out: int):
    n, mean, m2, sx, bk = (a_ref[i] for i in range(5))
    tile_r, Kp = n.shape[0], o_ref.shape[-1]
    lane = jax.lax.broadcasted_iota(jnp.float32, (tile_r, Kp), 1)
    out_n = jnp.zeros((tile_r, Kp), jnp.float32)
    out_mean = jnp.zeros((tile_r, Kp), jnp.float32)
    out_m2 = jnp.zeros((tile_r, Kp), jnp.float32)
    out_sx = jnp.zeros((tile_r, Kp), jnp.float32)
    for k in range(k_out):
        mask = (bk == k).astype(jnp.float32)
        n_k = jnp.sum(mask * n, axis=-1)
        sy_k = jnp.sum(mask * n * mean, axis=-1)
        sx_k = jnp.sum(mask * sx, axis=-1)
        occ = n_k > 0
        mean_k = jnp.where(occ, sy_k / jnp.where(occ, n_k, 1.0), 0.0)
        d = mean - mean_k[:, None]
        m2_k = jnp.where(occ, jnp.sum(mask * (m2 + n * d * d), axis=-1), 0.0)
        col = (lane == k).astype(jnp.float32)
        out_n = out_n + n_k[:, None] * col
        out_mean = out_mean + mean_k[:, None] * col
        out_m2 = out_m2 + m2_k[:, None] * col
        out_sx = out_sx + sx_k[:, None] * col
    o_ref[0] = out_n
    o_ref[1] = out_mean
    o_ref[2] = out_m2
    o_ref[3] = out_sx


@functools.partial(jax.jit, static_argnames=("k_out", "tile_r", "interpret"))
def sketch_compact_pallas(packed: jax.Array, *, k_out: int,
                          tile_r: int = 256,
                          interpret: bool = False) -> jax.Array:
    """Reduce packed (5, Rp, Jp) sorted-centroid blocks to (4, Rp, Kp)."""
    rows, Rp, Jp = packed.shape
    assert rows == 5, packed.shape
    assert Rp % tile_r == 0, (Rp, tile_r)
    Kp = round_up(k_out, 128)
    return pl.pallas_call(
        functools.partial(_sketch_compact_kernel, k_out=k_out),
        grid=(Rp // tile_r,),
        in_specs=[pl.BlockSpec((5, tile_r, Jp), lambda i: (0, i, 0))],
        out_specs=pl.BlockSpec((4, tile_r, Kp), lambda i: (0, i, 0)),
        out_shape=jax.ShapeDtypeStruct((4, Rp, Kp), jnp.float32),
        interpret=interpret,
    )(packed)
