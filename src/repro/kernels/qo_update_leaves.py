"""Pallas TPU kernel: fused QO update for EVERY (leaf, feature) table.

This is the forest-scale generalization of :mod:`repro.kernels.qo_update`
(DESIGN.md §2.3).  The tree-level hot path routes a batch of B instances to
leaves and must fold each row into F per-feature QO tables of its leaf —
`M*F` tables of C bins each.  The pure-jnp seed path did this with four
``segment_sum`` scatters over a flat ``M*F*C`` id space; here the whole
absorb stage is one ``pallas_call`` with a

    grid = (F, leaf-tiles, batch-tiles)

so each grid step owns a (tile_m, Cp) slab of tables for one feature and
streams a (tile_b,) slice of the batch through the MXU:

    onehot_leaf : (T, tile_m)   row t -> local leaf slot (0 outside tile)
    onehot_bin  : (T, Cp)       row t -> quantized bin of x[t, f]
    n_add       = onehot_leaf^T @ onehot_bin                  (weighted)
    sum_x_add   = onehot_leaf^T @ (onehot_bin * x)
    sum_y_add   = onehot_leaf^T @ (onehot_bin * y)

The per-(leaf, bin) tile M2 uses the two-pass residual form: the tile bin
means are gathered back per row with one more MXU matvec and squared
residuals are contracted exactly like the sums — no naive `sum y^2`
cancellation (paper §3).  Tile statistics merge into the running table
with the Chan operator (Eqs. 4-5) kept in VMEM across the (sequential)
batch-tile grid dimension, so each table slab does one HBM round-trip per
call regardless of B.

Dense forest layout (lane dim Cp = C rounded up to 128):

    tables : (F, 8, Mp, Cp) f32
      row 0: n        row 1: mean     row 2: M2      row 3: sum_x
      row 4: radius   row 5: origin   (broadcast along lanes)
      row 6: attempt mask (query kernel only)        row 7: padding

Routed leaf ids ride along as an int32 ``(1, Bp)`` vector; rows whose leaf
falls outside the current leaf tile contribute nothing (their one-hot leaf
row is all zero), which also makes batch padding (leaf id = -1, w = 0)
free.  No ``(B*F,)`` segment-id array is ever materialized.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import qo as qo_lib  # noqa: F401  (layout mirrors the dict table)

FOREST_ROWS = 8
ROW_N, ROW_MEAN, ROW_M2, ROW_SUMX = 0, 1, 2, 3
ROW_RADIUS, ROW_ORIGIN, ROW_ATTEMPT = 4, 5, 6

__all__ = [
    "FOREST_ROWS", "ROW_N", "ROW_MEAN", "ROW_M2", "ROW_SUMX",
    "ROW_RADIUS", "ROW_ORIGIN", "ROW_ATTEMPT",
    "round_up", "pack_forest", "unpack_forest", "qo_update_leaves_pallas",
]


def round_up(n: int, m: int) -> int:
    return ((n + m - 1) // m) * m


def pack_forest(ao_y, ao_sum_x, ao_radius, ao_origin, attempt=None,
                *, tile_m: int = 128) -> jax.Array:
    """(M, F, C) dict-of-arrays state -> dense (F, 8, Mp, Cp) forest."""
    M, F, C = ao_sum_x.shape
    Mp = round_up(M, min(tile_m, round_up(M, 8)))
    Cp = round_up(C, 128)
    dense = jnp.zeros((F, FOREST_ROWS, Mp, Cp), jnp.float32)

    def put(row, arr):  # arr: (M, F, C)
        return dense.at[:, row, :M, :C].set(jnp.transpose(arr, (1, 0, 2)))

    dense = put(ROW_N, ao_y["n"])
    dense = put(ROW_MEAN, ao_y["mean"])
    dense = put(ROW_M2, ao_y["m2"])
    dense = put(ROW_SUMX, ao_sum_x)
    # per-(leaf, feature) scalars broadcast along the lane dim
    dense = dense.at[:, ROW_RADIUS, :M, :].set(ao_radius.T[:, :, None])
    dense = dense.at[:, ROW_ORIGIN, :M, :].set(ao_origin.T[:, :, None])
    if attempt is not None:
        att = attempt.astype(jnp.float32)[None, :, None]          # (1, M, 1)
        dense = dense.at[:, ROW_ATTEMPT, :M, :].set(jnp.broadcast_to(
            att, (F, M, Cp)))
    return dense


def unpack_forest(dense: jax.Array, M: int, C: int):
    """Dense (F, 8, Mp, Cp) -> (ao_y dict, ao_sum_x), shapes (M, F, C)."""
    def get(row):
        return jnp.transpose(dense[:, row, :M, :C], (1, 0, 2))

    ao_y = {"n": get(ROW_N), "mean": get(ROW_MEAN), "m2": get(ROW_M2)}
    return ao_y, get(ROW_SUMX)


def _qo_update_leaves_kernel(leaf_ref, x_ref, y_ref, w_ref, tab_ref, out_ref,
                             *, n_bins: int, tile_m: int):
    j = pl.program_id(1)          # leaf tile
    i = pl.program_id(2)          # batch tile (innermost: VMEM accumulation)

    @pl.when(i == 0)
    def _seed():
        out_ref[...] = tab_ref[...]

    Cp = out_ref.shape[3]
    T = x_ref.shape[1]
    x = x_ref[0, :]
    yv = y_ref[0, :]
    w = w_ref[0, :]
    leaf = leaf_ref[0, :]

    # one-hot over the local leaf slots; rows outside this tile are all-zero
    lloc = leaf - j * tile_m
    slot = jax.lax.broadcasted_iota(jnp.int32, (T, tile_m), 1)
    oh_leaf = (lloc[:, None] == slot).astype(jnp.float32)

    # per-row radius/origin: gather via MXU, read back from lane 0
    dot_lm = functools.partial(
        jax.lax.dot_general, dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    lane = jax.lax.broadcasted_iota(jnp.int32, (T, Cp), 1)
    r_row = jnp.sum(jnp.where(lane == 0, dot_lm(oh_leaf, out_ref[0, ROW_RADIUS]),
                              0.0), axis=1)
    o_row = jnp.sum(jnp.where(lane == 0, dot_lm(oh_leaf, out_ref[0, ROW_ORIGIN]),
                              0.0), axis=1)

    safe_r = jnp.where(r_row > 0, r_row, 1.0)
    ids = jnp.floor((x - o_row) / safe_r).astype(jnp.int32) + n_bins // 2
    ids = jnp.clip(ids, 0, n_bins - 1)
    oh_bin = lane == ids[:, None]
    wbin = jnp.where(oh_bin, w[:, None], 0.0)

    # (tile_m, Cp) <- (T, tile_m)^T @ (T, Cp) contractions on the MXU
    contract = functools.partial(
        jax.lax.dot_general, dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    n_b = contract(oh_leaf, wbin)
    sx_b = contract(oh_leaf, wbin * x[:, None])
    sy_b = contract(oh_leaf, wbin * yv[:, None])

    safe_nb = jnp.where(n_b > 0, n_b, 1.0)
    mean_b = jnp.where(n_b > 0, sy_b / safe_nb, 0.0)
    # two-pass M2: gather each row's tile bin mean back, contract residuals
    mean_i = jnp.sum(jnp.where(oh_bin, dot_lm(oh_leaf, mean_b), 0.0), axis=1)
    resid = yv - mean_i
    m2_b = contract(oh_leaf, wbin * (resid * resid)[:, None])

    # Chan merge (Eqs. 4-5) of tile stats into the running table
    n0 = out_ref[0, ROW_N]
    mean0 = out_ref[0, ROW_MEAN]
    m20 = out_ref[0, ROW_M2]
    n = n0 + n_b
    safe_n = jnp.where(n > 0, n, 1.0)
    delta = mean_b - mean0
    mean = jnp.where(n > 0, (n0 * mean0 + n_b * mean_b) / safe_n, 0.0)
    m2 = jnp.where(n > 0, m20 + m2_b + delta * delta * (n0 * n_b) / safe_n, 0.0)

    out_ref[0, ROW_N] = n
    out_ref[0, ROW_MEAN] = mean
    out_ref[0, ROW_M2] = m2
    out_ref[0, ROW_SUMX] = out_ref[0, ROW_SUMX] + sx_b


@functools.partial(jax.jit,
                   static_argnames=("n_bins", "tile_b", "tile_m", "interpret"))
def qo_update_leaves_pallas(tab: jax.Array, leaf: jax.Array, x: jax.Array,
                            y: jax.Array, w: jax.Array, *, n_bins: int,
                            tile_b: int = 256, tile_m: int = 128,
                            interpret: bool = False) -> jax.Array:
    """tab: (F, 8, Mp, Cp); leaf: (1, Bp) i32; x: (F, Bp); y/w: (1, Bp).

    Bp must be a multiple of ``tile_b`` and Mp of ``tile_m`` (ops.py pads
    with w = 0 / leaf = -1).  Returns the merged dense forest.
    """
    F, rows, Mp, Cp = tab.shape
    assert rows == FOREST_ROWS
    Bp = x.shape[1]
    assert Bp % tile_b == 0 and Mp % tile_m == 0
    grid = (F, Mp // tile_m, Bp // tile_b)

    kernel = functools.partial(_qo_update_leaves_kernel,
                               n_bins=n_bins, tile_m=tile_m)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, tile_b), lambda f, j, i: (0, i)),    # leaf ids
            pl.BlockSpec((1, tile_b), lambda f, j, i: (f, i)),    # x feature
            pl.BlockSpec((1, tile_b), lambda f, j, i: (0, i)),    # y
            pl.BlockSpec((1, tile_b), lambda f, j, i: (0, i)),    # w
            pl.BlockSpec((1, FOREST_ROWS, tile_m, Cp),
                         lambda f, j, i: (f, 0, j, 0)),           # seed tables
        ],
        out_specs=pl.BlockSpec((1, FOREST_ROWS, tile_m, Cp),
                               lambda f, j, i: (f, 0, j, 0)),
        out_shape=jax.ShapeDtypeStruct((F, FOREST_ROWS, Mp, Cp), jnp.float32),
        interpret=interpret,
    )(leaf, x, y, w, tab)
