"""Pallas TPU kernel for the QO update (paper Algorithm 1).

TPU adaptation (DESIGN.md §2): the per-instance hash insert becomes a
tile-streaming accumulation.  Each grid step loads a (1, T) tile of
observations into VMEM, quantizes to bin ids, expands to a one-hot
(T, C) matrix and reduces with MXU matmuls:

    n_b   = 1^T @ onehot          sum_x_b = x^T @ onehot
    sy_b  = y^T @ onehot          syy_b   = (y*y)^T @ onehot

The per-tile exact statistics are then folded into the running (n, mean,
M2) table with the Chan merge (paper Eqs. 4-5) — the same operator the
reference uses, so kernel and oracle agree to float tolerance.

The bin table lives in the output ref with a constant index map, so it
persists across the (sequential) TPU grid steps; step 0 seeds it from the
input table, making the kernel resumable across calls.

Table layout (row-major, lane dim = C, a multiple of 128):
    row 0: n      row 1: mean      row 2: M2      row 3: sum_x
    rows 4-7: zero padding for (8, 128) tiling alignment.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TABLE_ROWS = 8  # padded sublane dim
ROW_N, ROW_MEAN, ROW_M2, ROW_SUMX = 0, 1, 2, 3


def _qo_update_kernel(scal_ref, x_ref, y_ref, w_ref, tab_ref, out_ref):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _seed():
        out_ref[...] = tab_ref[...]

    cap = out_ref.shape[1]
    radius = scal_ref[0, 0]
    origin = scal_ref[0, 1]

    x = x_ref[0, :]
    y = y_ref[0, :]
    w = w_ref[0, :]

    ids = jnp.floor((x - origin) / radius).astype(jnp.int32) + cap // 2
    ids = jnp.clip(ids, 0, cap - 1)

    # one-hot expansion -> MXU reductions (T, C) x (T,) contractions
    lanes = jax.lax.broadcasted_iota(jnp.int32, (x.shape[0], cap), 1)
    mask = (lanes == ids[:, None])
    onehot = jnp.where(mask, w[:, None], 0.0).astype(jnp.float32)

    n_b = jnp.sum(onehot, axis=0)
    sx_b = jax.lax.dot_general(x, onehot, (((0,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32)
    sy_b = jax.lax.dot_general(y, onehot, (((0,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32)

    safe = jnp.where(n_b > 0, n_b, 1.0)
    mean_b = jnp.where(n_b > 0, sy_b / safe, 0.0)
    # two-pass M2: the tile is VMEM-resident, so gather each element's bin
    # mean back (one more MXU matvec) and reduce squared residuals exactly —
    # avoids the sum-of-squares cancellation the paper warns about (§1)
    mean_i = jax.lax.dot_general(mask.astype(jnp.float32), mean_b,
                                 (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    resid = (y - mean_i)
    m2_b = jax.lax.dot_general(resid * resid, onehot, (((0,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32)

    # Chan merge (Eqs. 4-5) of the tile stats into the running table
    n0 = out_ref[ROW_N, :]
    mean0 = out_ref[ROW_MEAN, :]
    m20 = out_ref[ROW_M2, :]
    n = n0 + n_b
    safe_n = jnp.where(n > 0, n, 1.0)
    delta = mean_b - mean0
    mean = jnp.where(n > 0, (n0 * mean0 + n_b * mean_b) / safe_n, 0.0)
    m2 = jnp.where(n > 0, m20 + m2_b + delta * delta * (n0 * n_b) / safe_n, 0.0)

    out_ref[ROW_N, :] = n
    out_ref[ROW_MEAN, :] = mean
    out_ref[ROW_M2, :] = m2
    out_ref[ROW_SUMX, :] = out_ref[ROW_SUMX, :] + sx_b


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def qo_update_pallas(table: jax.Array, scalars: jax.Array, x: jax.Array,
                     y: jax.Array, w: jax.Array, *, tile: int = 1024,
                     interpret: bool = False) -> jax.Array:
    """table: (8, C) f32; scalars: (1, 2) [radius, origin]; x/y/w: (N,).

    N must be a multiple of ``tile`` (ops.py pads with w=0).
    """
    cap = table.shape[1]
    n = x.shape[0]
    assert n % tile == 0, "pad inputs to a multiple of the tile size"
    grid = (n // tile,)
    xg = x.reshape(grid[0], tile)
    yg = y.reshape(grid[0], tile)
    wg = w.reshape(grid[0], tile)

    return pl.pallas_call(
        _qo_update_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 2), lambda i: (0, 0)),          # scalars
            pl.BlockSpec((1, tile), lambda i: (i, 0)),        # x tile
            pl.BlockSpec((1, tile), lambda i: (i, 0)),        # y tile
            pl.BlockSpec((1, tile), lambda i: (i, 0)),        # w tile
            pl.BlockSpec((TABLE_ROWS, cap), lambda i: (0, 0)),  # seed table
        ],
        out_specs=pl.BlockSpec((TABLE_ROWS, cap), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((TABLE_ROWS, cap), jnp.float32),
        interpret=interpret,
    )(scalars, xg, yg, wg, table)
