"""Pallas TPU kernel: QO split-candidate query for ALL M*F tables at once.

Grid-over-tables variant of :mod:`repro.kernels.qo_query` (DESIGN.md §2.3).
The seed evaluated every (leaf, feature) table with ``vmap(vmap(best_split))``
— hundreds of tiny interpreter-glued scans.  Here one ``pallas_call`` with

    grid = (F, leaf-tiles)

lays a (tile_m, Cp) slab of tables across VPU sublanes and runs the
Hillis-Steele inclusive prefix *merge* (Chan operator, paper Eqs. 4-5)
along the lane dimension for all tables simultaneously: log2(Cp) steps of
shift + merge, no sequential per-table work.  The right-hand complement
comes from the paper's subtraction (Eqs. 6-7), giving the Variance
Reduction of every candidate boundary

    VR_i = s2(d) - nL_i/n * s2(left_i) - nR_i/n * s2(right_i)

Candidate thresholds are midpoints of neighbouring occupied prototypes,
found with two more log-depth last/next-valid-value propagations (no
gathers — TPU lanes shift, they don't scatter).

Attempt masking: row 6 of each table slab carries the leaf's attempt flag
(set when the leaf passed its grace period).  A slab whose leaves are all
below grace skips the whole evaluation via ``pl.when`` — split attempts
cost nothing for quiet regions of the forest — and masked tables report
``-inf`` scores.

Input:  dense forest (F, 8, Mp, Cp) — layout of qo_update_leaves.
Output: (F, 8, Mp, Cp): row 0 = VR scores (-inf invalid), row 1 =
candidate thresholds, rows 2-7 zero.  The per-table argmax is a trivial
epilogue in ops.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.qo_update_leaves import (
    FOREST_ROWS, ROW_N, ROW_MEAN, ROW_M2, ROW_SUMX, ROW_ATTEMPT)

__all__ = ["qo_query_batched_pallas"]


def _shift_right(a, d, fill):
    """(R, C) shifted right by static d along lanes, filled on the left."""
    pad = jnp.full((a.shape[0], d), fill, a.dtype)
    return jnp.concatenate([pad, a[:, :-d]], axis=1)


def _shift_left(a, d, fill):
    pad = jnp.full((a.shape[0], d), fill, a.dtype)
    return jnp.concatenate([a[:, d:], pad], axis=1)


def _qo_query_batched_kernel(tab_ref, out_ref):
    Cp = tab_ref.shape[3]
    zero = jnp.zeros(out_ref.shape[2:], jnp.float32)

    att = tab_ref[0, ROW_ATTEMPT, :, 0:1] > 0                 # (tile_m, 1)

    # grace-period gate: a quiet slab writes -inf and skips all the math
    @pl.when(jnp.logical_not(jnp.any(att)))
    def _quiet():
        out_ref[0, 0] = jnp.full(zero.shape, -jnp.inf, jnp.float32)
        for r in range(1, FOREST_ROWS):
            out_ref[0, r] = zero

    @pl.when(jnp.any(att))
    def _evaluate():
        n = tab_ref[0, ROW_N]                                  # (tile_m, Cp)
        mean = tab_ref[0, ROW_MEAN]
        m2 = tab_ref[0, ROW_M2]
        sum_x = tab_ref[0, ROW_SUMX]
        occ = n > 0

        # ---- inclusive prefix merge, Hillis-Steele over lanes ------------
        pn, pmean, pm2 = n, mean, m2
        d = 1
        while d < Cp:
            sn = _shift_right(pn, d, 0.0)
            smean = _shift_right(pmean, d, 0.0)
            sm2 = _shift_right(pm2, d, 0.0)
            tn = sn + pn
            safe = jnp.where(tn > 0, tn, 1.0)
            delta = pmean - smean
            pmean = jnp.where(tn > 0, (sn * smean + pn * pmean) / safe, 0.0)
            pm2 = jnp.where(tn > 0,
                            sm2 + pm2 + delta * delta * (sn * pn) / safe, 0.0)
            pn = tn
            d *= 2

        tot_n = pn[:, Cp - 1:Cp]
        tot_mean = pmean[:, Cp - 1:Cp]
        tot_m2 = pm2[:, Cp - 1:Cp]

        # ---- complement via the paper's subtraction (Eqs. 6-7) -----------
        rn = tot_n - pn
        safe_rn = jnp.where(rn > 0, rn, 1.0)
        rmean = jnp.where(rn > 0, (tot_n * tot_mean - pn * pmean) / safe_rn,
                          0.0)
        delta = pmean - rmean
        safe_tot = jnp.where(tot_n > 0, tot_n, 1.0)
        rm2 = tot_m2 - pm2 - delta * delta * (rn * pn) / safe_tot
        rm2 = jnp.where(rn > 0, jnp.maximum(rm2, 0.0), 0.0)

        def var(nn, mm2):
            dd = nn - 1.0
            return jnp.where(dd > 0, mm2 / jnp.where(dd > 0, dd, 1.0), 0.0)

        s2_d = var(tot_n, tot_m2)
        n_tot = jnp.maximum(tot_n, 1.0)
        vr = s2_d - (pn / n_tot) * var(pn, pm2) - (rn / n_tot) * var(rn, rm2)

        # ---- neighbouring occupied prototypes via value propagation ------
        proto = jnp.where(occ, sum_x / jnp.where(occ, n, 1.0), 0.0)
        lval, lhas = proto, occ          # last occupied value at-or-before i
        rval, rhas = proto, occ          # first occupied value at-or-after i
        d = 1
        while d < Cp:
            slv = _shift_right(lval, d, 0.0)
            slh = _shift_right(lhas, d, False)
            lval = jnp.where(lhas, lval, slv)
            lhas = jnp.logical_or(lhas, slh)
            srv = _shift_left(rval, d, 0.0)
            srh = _shift_left(rhas, d, False)
            rval = jnp.where(rhas, rval, srv)
            rhas = jnp.logical_or(rhas, srh)
            d *= 2
        nval = _shift_left(rval, 1, 0.0)  # first occupied STRICTLY after i
        nhas = _shift_left(rhas, 1, False)

        ok = jnp.logical_and(jnp.logical_and(lhas, nhas), att)
        cand = 0.5 * (lval + nval)

        out_ref[0, 0] = jnp.where(ok, vr, -jnp.inf)
        out_ref[0, 1] = cand
        for r in range(2, FOREST_ROWS):
            out_ref[0, r] = zero


@functools.partial(jax.jit, static_argnames=("tile_m", "interpret"))
def qo_query_batched_pallas(tab: jax.Array, *, tile_m: int = 128,
                            interpret: bool = False) -> jax.Array:
    """tab: (F, 8, Mp, Cp) with attempt flags in row 6 -> scores/thresholds."""
    F, rows, Mp, Cp = tab.shape
    assert rows == FOREST_ROWS and Mp % tile_m == 0
    grid = (F, Mp // tile_m)
    return pl.pallas_call(
        _qo_query_batched_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((1, FOREST_ROWS, tile_m, Cp),
                               lambda f, j: (f, 0, j, 0))],
        out_specs=pl.BlockSpec((1, FOREST_ROWS, tile_m, Cp),
                               lambda f, j: (f, 0, j, 0)),
        out_shape=jax.ShapeDtypeStruct((F, FOREST_ROWS, Mp, Cp), jnp.float32),
        interpret=interpret,
    )(tab)
