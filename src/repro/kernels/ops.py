"""Public jit'd wrappers over the Pallas QO kernels.

Single-table ops (``qo_update`` / ``qo_best_split``) and the forest-scale
ops the Hoeffding tree hot path dispatches through (``forest_update`` /
``forest_best_splits``).  Every op takes a ``backend``:

* ``"pallas"``    — the compiled kernel: native on TPU, the Triton
                    lowering on GPU, and the Pallas interpreter as the
                    fallback everywhere else (so "pallas" is a legal,
                    if slow, backend on any host — the smoke-test
                    contract, not TPU-only in principle),
* ``"interpret"`` — the same kernel body under Pallas' CPU interpreter
                    (correctness validation against :mod:`repro.kernels.ref`),
* ``"jnp"``       — a fused pure-jnp lowering of the same math (XLA-fused
                    scatters + cumulative scans), the fast path off-TPU.

``backend=None`` resolves to ``"pallas"`` on TPU and ``"jnp"`` elsewhere.
The jnp lowering of the query uses prefix *sums* of (n, n*mean,
m2 + n*mean^2) rather than log-depth Chan merges — one fused ``cumsum``
instead of hundreds of tiny ops; the kernels and the
:mod:`repro.core.qo` oracle keep the fully robust merge (DESIGN.md §2.4).

Dispatch discipline (DESIGN.md §2.5, §8): both forest ops auto-detect
whether they are being traced.  Called with *concrete* arrays they
dispatch through cached jits keyed on (shape bucket, backend) — batch
sizes round up to bucket ladders and the split query compacts to the
smallest power-of-two bucket holding the K attempting tables, so the
compile cache stays bounded and two same-bucket calls never retrace.
Called under an enclosing trace (e.g. inside ``jax.jit(hoeffding.update)``)
they inline, so the caller's jit still fuses the whole stage; the query
then selects its K bucket at *runtime* with ``lax.switch``.

Every concrete dispatch flows through ONE shared helper pair —
:func:`_dispatch` (the cached-jit factory: one lru keyed on
(impl, statics), one donation policy, one clear hook) and
:func:`dispatch_rows` (the pad-to-bucket → cached jit → slice prologue)
— so the query, route, predict, update and merge families cannot drift
apart in bucketing or caching discipline.  The per-family ``_jit_*``
handles remain as thin keyed shims over :func:`_dispatch` (they are the
``_cache_size()`` / ``cache_info()`` regression hooks).

Tile/grid constants are *schedule* knobs, never semantics: pad rows
vanish (w = 0 / leaf = -1 / attempt = False) and extra route plies
self-loop, so every dispatch-shaping choice (ladders, ply rounding,
query buckets, table-axis tiles) is bit-identical on every backend.
The one exception is the batch-STREAMING tile width on the kernel path
(forest_update ``tile_b``, qo_update ``tile``): it sets the granularity
of a sequential Chan merge, so a different width reorders f32
accumulation — same math, different bits — and the tuner therefore
pins those knobs at their defaults off the jnp backend
(``repro.perf.tune.KERNEL_STREAM_KNOBS``).  Defaults were eyeballed on
one container, so
:mod:`repro.perf.tune` can override them per (family, backend, shape
class) through :func:`set_tuning` — a caller-supplied explicit value
always wins, and with no tuning installed the defaults (and therefore
the jit cache keys) are exactly the historical constants.
"""
from __future__ import annotations

import bisect
import functools

import jax
import jax.numpy as jnp

from repro.core import qo as qo_lib
from repro.core import stats
from repro.kernels import ref as _ref
from repro.kernels.qo_update import qo_update_pallas
from repro.kernels.qo_query import qo_query_pallas
from repro.kernels.qo_update_leaves import (
    pack_forest, unpack_forest, qo_update_leaves_pallas, round_up)
from repro.kernels.qo_query_batched import qo_query_batched_pallas
from repro.kernels.qo_route import (
    fold_route_tables, pack_route_attrs, qo_route_pallas)
from repro.kernels.qo_merge import (
    pack_merge_planes, unpack_merge_planes, qo_merge_pallas)
from repro.core import sketch as sketch_lib
from repro.kernels.sketch_compact import (
    pack_compact_planes, unpack_compact_planes, sketch_compact_pallas)

__all__ = [
    "qo_update", "qo_best_split", "default_interpret", "resolve_backend",
    "forest_bin_ids", "forest_update", "forest_best_splits", "forest_merge",
    "sketch_update", "sketch_merge", "sketch_to_bins",
    "route", "forest_route", "depth_bucket",
    "query_buckets", "clear_jit_caches", "QUERY_MIN_BUCKET",
    "set_tuning", "get_tuning", "tuned", "DEFAULT_PARAMS",
]


def default_interpret() -> bool:
    """True off-TPU: single-table kernels run under the Pallas interpreter
    unless the caller forces compiled mode."""
    return jax.default_backend() != "tpu"


def resolve_backend(backend: str | None) -> str:
    """None/'auto' -> compiled kernels on TPU, fused jnp elsewhere."""
    if backend in (None, "auto"):
        return "pallas" if jax.default_backend() == "tpu" else "jnp"
    assert backend in ("pallas", "interpret", "jnp"), backend
    return backend


def _kernel_interpret(backend: str) -> bool:
    """Interpreter-mode flag for a kernel-path backend: ``"interpret"``
    always interprets; ``"pallas"`` compiles natively on TPU and GPU
    (Mosaic / Triton lowerings) and *falls back* to the interpreter on
    hosts with neither — slow, but correct, so ``backend="pallas"`` is
    smoke-testable everywhere (the multi-backend contract)."""
    if backend == "interpret":
        return True
    return jax.default_backend() not in ("tpu", "gpu")


# --------------------------------------------------------------------------
# tuned dispatch parameters (DESIGN.md §8; populated by repro.perf.tune)
# --------------------------------------------------------------------------

#: The historical hard-coded schedule constants, per dispatch family.
#: These are the fallbacks when no tuning entry matches — an untuned
#: machine dispatches (and caches) exactly as before the perf layer
#: existed — and the per-family search space in repro.perf.tune must
#: stay a superset of them.
DEFAULT_PARAMS = {
    "qo_update": {"tile": 1024},
    "forest_update": {"tile_b": 256, "tile_m": 128, "batch_ladder": "pow2"},
    "forest_query": {"tile_m": 128, "min_bucket": 8},
    "forest_route": {"tile_b": 256, "batch_ladder": "pow2", "ply_round": 2},
    "forest_merge": {"tile_r": 256},
    "sketch_update": {"tile_r": 256, "batch_ladder": "pow2"},
    "sketch_merge": {"tile_r": 256},
}

# (family, backend, shape_class) -> {param: value} overrides.  Kept
# deliberately dumb (a dict the perf layer swaps in) so kernels never
# import the tuner: repro.perf.tune owns measurement, persistence and
# device-kind filtering and calls set_tuning with the survivors.
_TUNING: dict = {}


def set_tuning(table: dict) -> None:
    """Install tuned dispatch parameters: ``{(family, backend,
    shape_class): {param: value}}``.  Replaces the whole table.  Entries
    apply only where the caller left a parameter unspecified; unknown
    params are ignored by :func:`tuned`.  Changing the table does not
    drop already-compiled programs (old keys stay warm; call
    :func:`clear_jit_caches` to reclaim them)."""
    global _TUNING
    _TUNING = dict(table)


def get_tuning() -> dict:
    """The installed tuning table (read-only view for tests/tools)."""
    return dict(_TUNING)


def tuned(family: str, backend: str, shape_class: str, **overrides):
    """Resolve the dispatch parameters for one (family, backend, shape
    class): start from :data:`DEFAULT_PARAMS`, apply the installed
    tuning entry, then apply caller ``overrides`` whose value is not
    None (an explicit argument always beats the tuner).  Returns a fresh
    dict — pure lookup, no measurement, safe at trace time."""
    p = dict(DEFAULT_PARAMS[family])
    entry = _TUNING.get((family, backend, shape_class))
    if entry:
        p.update({k: v for k, v in entry.items() if k in p})
    p.update({k: v for k, v in overrides.items() if v is not None})
    return p


def _shape_class_tables(M: int, F: int, C: int) -> str:
    """Tuner key for the table-axis families (update/query/merge): the
    dense (M, F, C) geometry IS the workload; B rides the bucket ladder."""
    return f"M{M}xF{F}xC{C}"


def _shape_class_route(T: int, M: int, F: int) -> str:
    """Tuner key for the routing/predict families: folded node count and
    feature width set the sweep's working set; B rides the ladder and
    the ply count is a dispatch key, not a tuning key."""
    return f"T{T}xM{M}xF{F}"


# --------------------------------------------------------------------------
# the ONE cached-jit dispatch engine (all concrete entry points funnel here)
# --------------------------------------------------------------------------

def _is_traced(*trees) -> bool:
    """True when any leaf of the argument pytrees is a JAX tracer — i.e.
    the caller is already inside a jit/vmap/scan trace and the op must
    inline rather than dispatch through its own cached jit."""
    return any(isinstance(leaf, jax.core.Tracer)
               for t in trees for leaf in jax.tree.leaves(t))


@functools.lru_cache(maxsize=None)
def _dispatch_cached(impl, donate_x: bool, statics: tuple):
    """The single cached-jit factory behind every concrete dispatch
    family: one entry per (impl, donation policy, static params).  The
    inner jit cache is keyed on argument shapes, which the public
    wrappers bucket.  ``donate_x=True`` donates the batch argument
    (every row-dispatch impl names it ``X``) so XLA can reuse the
    request buffer for sweep temporaries; XLA:CPU cannot alias donated
    buffers (it would only warn per compile), so donation engages on
    TPU only and callers must hand an engine-owned buffer."""
    donate = ("X",) if donate_x and jax.default_backend() == "tpu" else ()
    return jax.jit(functools.partial(impl, **dict(statics)),
                   donate_argnames=donate)


def _dispatch(impl, *, donate_x: bool = False, **statics):
    """Resolve the cached jit for ``impl`` closed over ``statics``.
    Same (impl, statics) -> the same jit object, process-wide — the
    no-recompile invariant every ``_jit_*`` family shim inherits."""
    return _dispatch_cached(impl, donate_x, tuple(sorted(statics.items())))


def _ladder_bucket(n: int, lo: int, ladder: str) -> int:
    """Smallest bucket >= n on the chosen ladder (``lo`` a power of two).

    ``"pow2"``: {lo, 2lo, 4lo, ...} — O(log n) compiled programs, up to
    2x pad waste just past a boundary.  ``"pow2_half"``: half-steps
    {lo, 1.5lo, 2lo, 3lo, 4lo, ...} — still O(log n) programs (two per
    octave) but caps pad waste at 1.33x; the tuner picks it when the
    measured per-row cost outweighs the extra compiles for a shape
    class.  Both ladders are schedule-only: pad rows vanish on every
    backend."""
    b = lo
    while b < n:
        if ladder == "pow2_half":
            h = b + b // 2
            if n <= h:
                return h
        b *= 2
    return b


def _pow2_bucket(n: int, lo: int) -> int:
    """Smallest power-of-two multiple of ``lo`` holding ``n`` (``lo`` must
    itself be a power of two) — the shape-bucketing rule that bounds the
    cached-jit compile count to O(log n) entries."""
    return _ladder_bucket(n, lo, "pow2")


def pad_rows(X, lo: int = 128, ladder: str = "pow2"):
    """Pad request rows up to their ladder bucket — the dispatch
    prologue every concrete row-dispatch entry point shares.  Returns
    ``(padded X, original B, padded?)``; pad rows are zero and the
    callers slice ``[:B]`` back iff padding happened."""
    B, F = X.shape
    Bp = _ladder_bucket(max(B, lo), lo, ladder)
    if Bp == B:
        return X, B, False
    return jnp.concatenate([X, jnp.zeros((Bp - B, F), X.dtype)]), B, True


def pad_rows_pow2(X, lo: int = 128):
    """:func:`pad_rows` on the power-of-two ladder (the historical
    default; kept as the stable public name)."""
    return pad_rows(X, lo, "pow2")


def dispatch_rows(impl, tables, X, *, statics: dict, ladder: str = "pow2",
                  donate_x: bool = False):
    """Concrete row dispatch: pad ``X`` to its ladder bucket, run the
    cached jit for (impl, statics) over ``(*tables, X)``, slice the
    padded rows back off the LAST axis of the result.  The one body
    behind ``forest_route``/``route``/``predict_snapshot``/live forest
    predict — the three read-path dispatch layers this replaces each
    hand-rolled the same four lines."""
    X, B, padded = pad_rows(X, 128, ladder)
    if donate_x and not padded and jax.default_backend() == "tpu":
        X = jnp.copy(X)     # donate our copy, not the caller's buffer
    out = _dispatch(impl, donate_x=donate_x, **statics)(*tables, X)
    return out[..., :B] if padded else out


# --------------------------------------------------------------------------
# single-table ops
# --------------------------------------------------------------------------

def _pad_to(arr, mult, fill=0.0):
    n = arr.shape[0]
    rem = (-n) % mult
    if rem == 0:
        return arr
    return jnp.concatenate([arr, jnp.full((rem,), fill, arr.dtype)])


#: A batch whose pow-2 round-up fits this width is absorbed in ONE tile
#: pass no matter what tile was requested (see :func:`qo_update_tile`).
QO_SINGLE_PASS_MAX = 1024


def qo_update_tile(B: int, tile: int) -> int:
    """Resolve the streamed batch-tile width for a B-row update.

    The requested ``tile`` is a *streaming-granularity cap for big
    batches*, not a splitter for small ones: a batch whose pow-2
    round-up fits one maximal tile (:data:`QO_SINGLE_PASS_MAX`) is
    absorbed in a single pass of exactly that round-up (floored at the
    128-lane alignment), so for B <= 1024 EVERY tile request is
    bit-identical — pad rows carry w = 0 and vanish, and there is no
    partial-tile Chan merge whose f32 order could differ
    (tests/test_kernels.py pins B in {1, 127, 128, 129} across tile
    choices).  The old ``min(tile, round_up)`` clamp split B = 129 into
    two 128-passes under ``tile=128`` but one 256-pass under larger
    requests — same math, different bits.  Batches past the single-pass
    width stream at the requested tile, where granularity is a real
    VMEM/occupancy knob (and IS bit-sensitive, which is why the tuner
    never searches it on the kernel path — repro.perf.tune)."""
    up = max(128, 1 << (B - 1).bit_length())
    if up <= QO_SINGLE_PASS_MAX:
        return up
    return min(max(tile, 128), up)


def _qo_update_impl(table, x, y, w, *, tile: int, interpret: bool):
    dense, scal = _ref.pack_table(table)
    dense = qo_update_pallas(dense, scal, x, y, w, tile=tile,
                             interpret=interpret)
    return _ref.unpack_table(dense, scal)


def qo_update(table: qo_lib.QOTable, x, y, w=None, *, tile: int | None = None,
              interpret: bool | None = None) -> qo_lib.QOTable:
    """Kernel-backed equivalent of :func:`repro.core.qo.update`.

    table: dict QO table (capacity C); x/y: (B,) f32 observations;
    w: optional (B,) f32 sample weights (default 1, weight-0 rows vanish);
    tile: batch tile streamed through VMEM per grid step (None: the
    tuned value for this capacity class, default 1024, clamped by
    :func:`qo_update_tile`).  Returns the merged table (same shapes).
    """
    interpret = default_interpret() if interpret is None else interpret
    x = jnp.asarray(x, jnp.float32).reshape(-1)
    y = jnp.asarray(y, jnp.float32).reshape(-1)
    w = jnp.ones_like(x) if w is None else jnp.asarray(w, jnp.float32).reshape(-1)
    cap = int(table["sum_x"].shape[0])
    tile = tuned("qo_update", "pallas", f"C{cap}", tile=tile)["tile"]
    tile = qo_update_tile(int(x.shape[0]), tile)
    xp, yp, wp = _pad_to(x, tile), _pad_to(y, tile), _pad_to(w, tile)
    if _is_traced(table, xp, yp, wp):
        return _qo_update_impl(table, xp, yp, wp, tile=tile,
                               interpret=interpret)
    return _dispatch(_qo_update_impl, tile=tile, interpret=interpret)(
        table, xp, yp, wp)


@functools.partial(jax.jit, static_argnames=("interpret",))
def qo_best_split(table: qo_lib.QOTable, *,
                  interpret: bool | None = None) -> qo_lib.SplitResult:
    """Kernel-backed equivalent of :func:`repro.core.qo.best_split`.

    Returns a scalar :class:`repro.core.qo.SplitResult` (threshold, VR
    merit, validity) evaluated for all C boundaries in one pass.
    """
    interpret = default_interpret() if interpret is None else interpret
    dense, _ = _ref.pack_table(table)
    out = qo_query_pallas(dense, interpret=interpret)
    score, cand = out[0], out[1]
    best = jnp.argmax(score)
    valid = jnp.isfinite(score[best])
    return qo_lib.SplitResult(
        threshold=cand[best],
        merit=jnp.where(valid, score[best], 0.0),
        valid=valid,
    )


# --------------------------------------------------------------------------
# forest-scale ops: every (leaf, feature) table of a Hoeffding tree at once
# --------------------------------------------------------------------------

def forest_bin_ids(ao_radius, ao_origin, leaf, X, n_bins: int) -> jax.Array:
    """Quantize each routed row into its leaf's per-feature tables.

    ao_radius/ao_origin: (M, F) per-(leaf, feature) quantization; leaf:
    (B,) i32 routed leaf ids; X: (B, F) f32.  Returns (B, F) i32 bin ids
    clipped into [0, n_bins).
    """
    r = ao_radius[leaf]                     # (B, F)
    o = ao_origin[leaf]
    h = jnp.floor((X - o) / r).astype(jnp.int32) + n_bins // 2
    return jnp.clip(h, 0, n_bins - 1)


def _forest_update_jnp(ao_y, ao_sum_x, ao_radius, ao_origin, leaf, X, y, w):
    """Fused-jnp lowering: ONE stacked segment-reduction + two-pass M2."""
    M, F, C = ao_sum_x.shape
    bins = forest_bin_ids(ao_radius, ao_origin, leaf, X, C)
    seg = ((leaf[:, None] * F + jnp.arange(F)[None, :]) * C + bins).reshape(-1)
    wr = jnp.repeat(w, F)
    yr = jnp.repeat(y, F)
    xf = X.reshape(-1)
    pay = jnp.stack([wr, wr * yr, wr * xf], 1)              # (B*F, 3)
    acc = jax.ops.segment_sum(pay, seg, M * F * C)
    nb, syb, sxb = acc[:, 0], acc[:, 1], acc[:, 2]
    meanb = jnp.where(nb > 0, syb / jnp.where(nb > 0, nb, 1.0), 0.0)
    # second pass: residuals against the tile bin mean (exact within tile)
    m2b = jax.ops.segment_sum(wr * (yr - meanb[seg]) ** 2, seg, M * F * C)
    tile = {"n": nb.reshape(M, F, C), "mean": meanb.reshape(M, F, C),
            "m2": m2b.reshape(M, F, C)}
    # Chan merge (Eqs. 4-5) of the tile into the running tables
    return stats.merge(ao_y, tile), ao_sum_x + sxb.reshape(M, F, C)


def _pad_batch(leaf, X, y, w, tile_b):
    B, F = X.shape
    Bp = round_up(max(B, tile_b), tile_b)
    pad = Bp - B
    if pad:
        leaf = jnp.concatenate([leaf, jnp.full((pad,), -1, leaf.dtype)])
        X = jnp.concatenate([X, jnp.zeros((pad, F), X.dtype)])
        y = jnp.concatenate([y, jnp.zeros((pad,), y.dtype)])
        w = jnp.concatenate([w, jnp.zeros((pad,), w.dtype)])
    return leaf, X, y, w


def _forest_update_impl(ao_y, ao_sum_x, ao_radius, ao_origin, leaf, X, y, w,
                        *, backend: str, tile_b: int, tile_m: int):
    """Backend dispatch body of :func:`forest_update` (inputs normalized)."""
    if backend == "jnp":
        return _forest_update_jnp(ao_y, ao_sum_x, ao_radius, ao_origin,
                                  leaf, X, y, w)

    M, F, C = ao_sum_x.shape
    tile_m = min(tile_m, round_up(M, 8))
    tile_b = min(tile_b, round_up(X.shape[0], 128))
    leaf, X, y, w = _pad_batch(leaf, X, y, w, tile_b)
    dense = pack_forest(ao_y, ao_sum_x, ao_radius, ao_origin, tile_m=tile_m)
    dense = qo_update_leaves_pallas(
        dense, leaf[None, :], X.T, y[None, :], w[None, :], n_bins=C,
        tile_b=tile_b, tile_m=tile_m, interpret=_kernel_interpret(backend))
    return unpack_forest(dense, M, C)


def _jit_forest_update(backend: str, tile_b: int, tile_m: int):
    """Keyed handle for the absorb op's cached jit (the ``_cache_size``
    regression hook); delegates to the shared :func:`_dispatch`."""
    return _dispatch(_forest_update_impl, backend=backend,
                     tile_b=tile_b, tile_m=tile_m)


def forest_update(ao_y, ao_sum_x, ao_radius, ao_origin, leaf, X, y, w=None, *,
                  backend: str | None = None, tile_b: int | None = None,
                  tile_m: int | None = None):
    """Absorb a routed batch into every (leaf, feature) QO table.

    ao_y: Stats dict of (M, F, C); ao_sum_x: (M, F, C); ao_radius/ao_origin:
    (M, F); leaf: (B,) int32 routed leaf ids; X: (B, F); y: (B,);
    w: optional (B,) f32 sample weights (default 1) — every accumulated
    statistic carries w, so weight-0 rows vanish and integer weight k
    equals k repeated unit rows (the online-bagging contract,
    property-tested in tests/test_weighted.py).
    Returns the merged (ao_y, ao_sum_x).

    ``tile_b``/``tile_m`` (None: tuned, defaults 256/128) are schedule
    knobs; pad rows carry leaf = -1, w = 0 and vanish on every backend.
    ``tile_m`` (table-axis grid) and the batch ladder are bit-identical
    under any value everywhere; ``tile_b`` is bit-identical on jnp (the
    fused lowering ignores it) but sets the streaming Chan-merge order
    on the kernel path, where the tuner pins it.  Called with concrete arrays
    this dispatches through a cached jit with the batch padded to its
    ladder bucket, so ragged streaming batches reuse a bounded set of
    compiled programs.  Under an enclosing trace it inlines, so the
    caller's jit fuses the whole absorb stage.
    """
    backend = resolve_backend(backend)
    leaf = jnp.asarray(leaf, jnp.int32).reshape(-1)
    X = jnp.asarray(X, jnp.float32)
    y = jnp.asarray(y, jnp.float32).reshape(-1)
    w = jnp.ones_like(y) if w is None else jnp.asarray(w, jnp.float32).reshape(-1)
    M, F, C = ao_sum_x.shape
    p = tuned("forest_update", backend, _shape_class_tables(M, F, C),
              tile_b=tile_b, tile_m=tile_m)
    if _is_traced(ao_y, ao_sum_x, ao_radius, ao_origin, leaf, X, y, w):
        return _forest_update_impl(ao_y, ao_sum_x, ao_radius, ao_origin,
                                   leaf, X, y, w, backend=backend,
                                   tile_b=p["tile_b"], tile_m=p["tile_m"])
    leaf, X, y, w = _pad_batch(
        leaf, X, y, w, _ladder_bucket(X.shape[0], 128, p["batch_ladder"]))
    return _jit_forest_update(backend, p["tile_b"], p["tile_m"])(
        ao_y, ao_sum_x, ao_radius, ao_origin, leaf, X, y, w)


def _forest_merge_impl(a_y, a_sum_x, b_y, b_sum_x, *, backend: str,
                       tile_r: int):
    """Backend dispatch body of :func:`forest_merge` (inputs normalized)."""
    if backend == "jnp":
        return stats.merge(a_y, b_y), a_sum_x + b_sum_x
    shape = a_sum_x.shape
    tile_r = min(tile_r, round_up(shape[0] * shape[1], 8))
    dense = qo_merge_pallas(
        pack_merge_planes(a_y, a_sum_x, tile_r=tile_r),
        pack_merge_planes(b_y, b_sum_x, tile_r=tile_r),
        tile_r=tile_r, interpret=_kernel_interpret(backend))
    return unpack_merge_planes(dense, shape)


@functools.lru_cache(maxsize=None)
def _jit_forest_merge(backend: str, tile_r: int):
    """Keyed handle for the table merge's cached jit (``cache_info()``
    is the no-fragmentation hook); delegates to :func:`_dispatch`."""
    return _dispatch(_forest_merge_impl, backend=backend, tile_r=tile_r)


def forest_merge(a_y, a_sum_x, b_y, b_sum_x, *, backend: str | None = None,
                 tile_r: int | None = None):
    """Chan-merge two same-shape QO table sets (DESIGN.md §4.1).

    a_y/b_y: Stats dicts of (N, F, C); a_sum_x/b_sum_x: (N, F, C) — N is
    any table-axis length (a tree's M, a forest's folded T·M, or a
    gathered shard stack reshaped in).  Returns the merged
    ``(ao_y, ao_sum_x)``: per-bin (n, mean, M2) through the Chan operator
    (Eqs. 4-5, empty-operand safe) and ``sum_x`` summed.  Associative +
    commutative — the write-side collective that lets D shard-local
    deltas reduce to exactly the single-stream tables; radius/origin do
    not ride through this op (shards must share the base quantization
    grid for the merge to be meaningful — the §4.1 trainer replicates
    them).

    Called with concrete arrays this dispatches through a cached jit
    (table shapes are fixed for a given forest, so the cache holds one
    program per backend); under an enclosing trace it inlines, so a
    jitted sync step fuses the whole reduction.
    """
    backend = resolve_backend(backend)
    N, F, C = a_sum_x.shape
    tile_r = tuned("forest_merge", backend, _shape_class_tables(N, F, C),
                   tile_r=tile_r)["tile_r"]
    if _is_traced(a_y, a_sum_x, b_y, b_sum_x):
        return _forest_merge_impl(a_y, a_sum_x, b_y, b_sum_x,
                                  backend=backend, tile_r=tile_r)
    return _jit_forest_merge(backend, tile_r)(a_y, a_sum_x, b_y, b_sum_x)


def _forest_query_jnp(ao_y, ao_sum_x, attempt):
    """Fused-jnp lowering of the batched query: one cumsum over stacked
    prefix payloads + cummax/cummin neighbour scans (DESIGN.md §2.4)."""
    M, F, C = ao_sum_x.shape
    n = ao_y["n"].reshape(M * F, C)
    mean = ao_y["mean"].reshape(M * F, C)
    m2 = ao_y["m2"].reshape(M * F, C)
    sum_x = ao_sum_x.reshape(M * F, C)
    occ = n > 0

    # VR is shift-invariant: center bin means on each table's grand mean so
    # SQ - SY^2/N never cancels against a large target offset (the same
    # robustness the Chan-merge paths get structurally)
    n_tab = n.sum(-1, keepdims=True)
    grand = (n * mean).sum(-1, keepdims=True) / jnp.maximum(n_tab, 1.0)
    mu = mean - grand
    sy = n * mu
    sq = m2 + sy * mu
    pref = jnp.cumsum(jnp.stack([n, sy, sq], 0), axis=-1)    # (3, M*F, C)
    Nl, SYl, SQl = pref[0], pref[1], pref[2]
    Nt, SYt, SQt = Nl[:, -1:], SYl[:, -1:], SQl[:, -1:]
    Nr, SYr, SQr = Nt - Nl, SYt - SYl, SQt - SQl

    def var(NN, SY, SQ):
        d = NN - 1.0
        m2_ = jnp.maximum(SQ - SY * SY / jnp.where(NN > 0, NN, 1.0), 0.0)
        return jnp.where(d > 0, m2_ / jnp.where(d > 0, d, 1.0), 0.0)

    s2d = var(Nt, SYt, SQt)
    ntot = jnp.maximum(Nt, 1.0)
    vr = s2d - (Nl / ntot) * var(Nl, SYl, SQl) - (Nr / ntot) * var(Nr, SYr, SQr)

    idx = jnp.arange(C)
    last = jax.lax.cummax(jnp.where(occ, idx, -1), axis=1)
    first_after = jax.lax.cummin(jnp.where(occ, idx, C), axis=1, reverse=True)
    nxt = jnp.concatenate([first_after[:, 1:], jnp.full((M * F, 1), C)], 1)
    ok = (last >= 0) & (nxt < C) & jnp.repeat(attempt, F)[:, None]
    proto = jnp.where(occ, sum_x / jnp.where(occ, n, 1.0), 0.0)
    p_l = jnp.take_along_axis(proto, jnp.maximum(last, 0), 1)
    p_r = jnp.take_along_axis(proto, jnp.minimum(nxt, C - 1), 1)
    cand = 0.5 * (p_l + p_r)
    score = jnp.where(ok, vr, -jnp.inf)
    return score, cand


QUERY_MIN_BUCKET = 8


def query_buckets(M: int, min_bucket: int = QUERY_MIN_BUCKET):
    """Static K_pad buckets for a capacity-M table axis: powers of two from
    ``min_bucket`` up, capped by a final full-scan bucket of M itself (so
    a near-full attempt set pays no gather/scatter overhead)."""
    sizes = []
    b = min_bucket
    while b < M:
        sizes.append(b)
        b *= 2
    return tuple(sizes) + (M,)


def _query_full(ao_y, ao_sum_x, ao_radius, ao_origin, attempt, *,
                backend: str, tile_m: int):
    """Uncompacted query over all M tables -> (merit, thr), both (M, F)."""
    M, F, C = ao_sum_x.shape
    if backend == "jnp":
        score, cand = _forest_query_jnp(ao_y, ao_sum_x, attempt)
    else:
        tile_m = min(tile_m, round_up(M, 8))
        dense = pack_forest(ao_y, ao_sum_x, ao_radius, ao_origin, attempt,
                            tile_m=tile_m)
        out = qo_query_batched_pallas(dense, tile_m=tile_m,
                                      interpret=_kernel_interpret(backend))
        score = jnp.transpose(out[:, 0, :M, :], (1, 0, 2)).reshape(M * F, -1)
        cand = jnp.transpose(out[:, 1, :M, :], (1, 0, 2)).reshape(M * F, -1)
    best = jnp.argmax(score, -1)
    merit = jnp.max(score, -1).reshape(M, F)
    thr = jnp.take_along_axis(cand, best[:, None], 1)[:, 0].reshape(M, F)
    return merit, thr


def _query_compact(ao_y, ao_sum_x, ao_radius, ao_origin, attempt, *,
                   kpad: int, backend: str, tile_m: int):
    """Compact-gather -> query -> scatter-back for a static K_pad bucket.

    Gathers the (at most kpad) attempting tables into a dense
    (kpad, F, C) buffer, runs the ordinary query over it — pad rows carry
    attempt=False, so masked math on jnp and ``pl.when``-skipped tiles on
    the kernel path — and scatters (merit, thr) back to (M, F) with -inf
    fill.  Per-table math is row-independent on every backend, so the
    attempting rows' results are bit-identical to the full scan's.
    """
    M, F, _ = ao_sum_x.shape
    idx = jnp.nonzero(attempt, size=kpad, fill_value=M)[0]       # (kpad,)
    safe = jnp.minimum(idx, M - 1)
    sub = lambda a: a[safe]
    merit_k, thr_k = _query_full(
        jax.tree.map(sub, ao_y), sub(ao_sum_x), sub(ao_radius),
        sub(ao_origin), idx < M, backend=backend, tile_m=tile_m)
    merit = jnp.full((M, F), -jnp.inf, jnp.float32).at[idx].set(
        merit_k, mode="drop")
    thr = jnp.zeros((M, F), jnp.float32).at[idx].set(thr_k, mode="drop")
    return merit, thr


@functools.lru_cache(maxsize=None)
def _jit_forest_query(backend: str, tile_m: int, kpad: int | None):
    """Keyed handle for one query bucket's cached jit (kpad=None: the
    full scan; ``cache_info()``/``_cache_size()`` are the regression
    hooks); delegates to the shared :func:`_dispatch`."""
    if kpad is None:
        return _dispatch(_query_full, backend=backend, tile_m=tile_m)
    return _dispatch(_query_compact, backend=backend, tile_m=tile_m,
                     kpad=kpad)


# --------------------------------------------------------------------------
# batched routing: the read-path primitive (DESIGN.md §2.6)
# --------------------------------------------------------------------------

def depth_bucket(depth: int, round_to: int = 2) -> int:
    """Ply bucket for the routing dispatch: extra plies are self-loop
    no-ops (leaves re-select themselves), so rounding the ply count up is
    free of correctness cost; rounding to the next multiple of
    ``round_to`` bounds the compile cache to max_depth/round_to programs
    per backend while wasting at most round_to - 1 plies (a power-of-two
    ladder would route a depth-9 tree with 16 plies — 7 wasted memory
    passes on the serving hot loop).  ``round_to`` is the tuned
    ``ply_round`` knob: 1 = exact plies (most programs, zero waste),
    default 2 = even plies (the historical choice)."""
    if round_to <= 1:
        return max(0, depth)
    return max(0, -(-depth // round_to) * round_to)


def _forest_route_jnp(feature, threshold, child, is_leaf, X, *, plies: int):
    """Fused-jnp lowering: a fully vectorized (T, B) transition sweep.

    Three takes per ply replace the oracle's six (feature, threshold,
    left, right, is_leaf, x): children are allocated in pairs (right =
    left + 1, see ``hoeffding._split_decision``), so feature and the
    right-child id pack into ONE int32 payload ``fc = right * Fp + f``
    (Fp = features rounded to a power of two — id extraction is two bit
    ops, and T*M*Fp stays far below 2^31 for any real forest), the
    transition becomes the branch-free

        node' = (fc >> log2(Fp)) - (x[f] <= threshold)

    and leaves self-loop with ``fc = self * Fp``, ``threshold = NaN``
    (``x <= NaN`` is False for EVERY x — including -inf, which a -inf
    sentinel would get wrong since ``-inf <= -inf`` is True — and for
    NaN itself, matching the oracle's NaN-goes-right convention
    bit-for-bit).  The X take flattens to one 1D gather
    (``row * F + f``), and the ply loop is unrolled (``plies`` is static
    and small) so XLA fuses the sweep with no ``fori_loop`` re-entry.
    """
    T, M = feature.shape
    B, F = X.shape
    N = T * M
    Fp = max(2, 1 << (F - 1).bit_length())
    shift = Fp.bit_length() - 1
    featg, thr, left, right = fold_route_tables(feature, threshold, child,
                                                is_leaf)
    self_loop = left == jnp.arange(N, dtype=jnp.int32)            # leaves
    fc = jnp.where(self_loop, left * Fp, right * Fp + featg)
    thr = jnp.where(self_loop, jnp.nan, thr)
    xf = X.reshape(-1)
    cols = jnp.tile(jnp.arange(B, dtype=jnp.int32) * F, T)        # (T*B,)
    offs = (jnp.arange(T, dtype=jnp.int32) * M)[:, None]          # (T, 1)
    node = jnp.broadcast_to(offs, (T, B)).reshape(-1)             # roots
    for _ in range(plies):
        fcv = fc[node]
        xv = xf[cols + (fcv & (Fp - 1))]
        node = (fcv >> shift) - (xv <= thr[node])
    return node.reshape(T, B) - offs


def _forest_route_impl(feature, threshold, child, is_leaf, X, *,
                       plies: int, backend: str, tile_b: int):
    """Backend dispatch body of :func:`forest_route` (inputs normalized)."""
    if backend == "jnp":
        return _forest_route_jnp(feature, threshold, child, is_leaf, X,
                                 plies=plies)
    T, M = feature.shape
    B, F = X.shape
    attrs = pack_route_attrs(feature, threshold, child, is_leaf,
                             n_pad=round_up(T * M, 128))
    tile_b = min(tile_b, round_up(B, 128))
    Bp, Fp = round_up(B, tile_b), round_up(F, 128)
    Xp = jnp.zeros((Bp, Fp), jnp.float32).at[:B, :F].set(X)
    node0 = jnp.broadcast_to(
        (jnp.arange(T, dtype=jnp.int32) * M)[:, None], (T, Bp))
    out = qo_route_pallas(node0, Xp, attrs, plies=plies, tile_b=tile_b,
                          interpret=_kernel_interpret(backend))
    return out[:, :B] - (jnp.arange(T, dtype=jnp.int32) * M)[:, None]


def _route_single_impl(feature, threshold, child, is_leaf, X, *,
                       plies: int, backend: str, tile_b: int):
    """Single-tree twin of :func:`_forest_route_impl`: the (M,) ->
    (T=1, M) axis expansion happens inside the trace (free), not as
    per-call eager reshapes on the serving hot path."""
    return _forest_route_impl(
        feature[None], threshold[None], child[None], is_leaf[None], X,
        plies=plies, backend=backend, tile_b=tile_b)[0]


@functools.lru_cache(maxsize=None)
def _jit_route(backend: str, tile_b: int, plies: int):
    """Keyed handle for one routing ply bucket's cached jit; delegates
    to the shared :func:`_dispatch`."""
    return _dispatch(_forest_route_impl, backend=backend, tile_b=tile_b,
                     plies=plies)


@functools.lru_cache(maxsize=None)
def _jit_route_single(backend: str, tile_b: int, plies: int):
    """Single-tree twin of :func:`_jit_route` (same shared factory)."""
    return _dispatch(_route_single_impl, backend=backend, tile_b=tile_b,
                     plies=plies)


def _route_params(backend: str, T: int, M: int, F: int,
                  tile_b: int | None):
    """Tuned routing schedule for one folded (T·M, F) geometry."""
    return tuned("forest_route", backend, _shape_class_route(T, M, F),
                 tile_b=tile_b)


def forest_route(feature, threshold, child, is_leaf, X, *,
                 depth: int, backend: str | None = None,
                 tile_b: int | None = None) -> jax.Array:
    """Route a batch through T trees at once — (T, B) i32 leaf ids.

    feature/threshold/is_leaf: (T, M); child: (T, M, 2) with -1 at
    leaves; X: (B, F) f32, shared by every tree; ``depth``: static upper
    bound on any leaf's depth (transition steps past a leaf self-loop, so
    any bound >= the realized depth returns bit-identical ids — callers
    with concrete states pass the *realized* depth, e.g.
    :func:`repro.core.serve.predict_snapshot`).

    Called with concrete arrays this dispatches through cached jits keyed
    on (backend, ply bucket) with the batch padded to its ladder bucket
    (pad rows route from the root and are sliced off), so serving never
    recompiles per request size.  Under an enclosing trace it inlines
    with ``plies = depth`` exactly, so a jitted training step fuses the
    whole sweep.  ``tile_b`` (None: tuned, default 256) and the tuned
    ``ply_round``/``batch_ladder`` knobs are schedule-only.
    """
    backend = resolve_backend(backend)
    feature = jnp.asarray(feature, jnp.int32)
    threshold = jnp.asarray(threshold, jnp.float32)
    child = jnp.asarray(child, jnp.int32)
    is_leaf = jnp.asarray(is_leaf, jnp.bool_)
    X = jnp.asarray(X, jnp.float32)
    T, M = feature.shape
    p = _route_params(backend, T, M, X.shape[1], tile_b)
    if _is_traced(feature, threshold, child, is_leaf, X):
        return _forest_route_impl(feature, threshold, child, is_leaf, X,
                                  plies=depth, backend=backend,
                                  tile_b=p["tile_b"])
    return dispatch_rows(
        _forest_route_impl, (feature, threshold, child, is_leaf), X,
        statics=dict(backend=backend, tile_b=p["tile_b"],
                     plies=depth_bucket(depth, p["ply_round"])),
        ladder=p["batch_ladder"])


def route(feature, threshold, child, is_leaf, X, *, depth: int,
          backend: str | None = None,
          tile_b: int | None = None) -> jax.Array:
    """Single-tree batched routing — (B,) i32 leaf ids.

    The T = 1 view of :func:`forest_route` (same bucketing, same folded
    sweep): feature/threshold/is_leaf: (M,); child: (M, 2); X: (B, F).
    The concrete dispatch keeps the tree-axis expansion inside its
    cached jit, so the serving hot path pays exactly one dispatch.
    """
    backend = resolve_backend(backend)
    feature = jnp.asarray(feature, jnp.int32)
    threshold = jnp.asarray(threshold, jnp.float32)
    child = jnp.asarray(child, jnp.int32)
    is_leaf = jnp.asarray(is_leaf, jnp.bool_)
    X = jnp.asarray(X, jnp.float32)
    p = _route_params(backend, 1, feature.shape[0], X.shape[1], tile_b)
    if _is_traced(feature, threshold, child, is_leaf, X):
        return _route_single_impl(feature, threshold, child, is_leaf, X,
                                  plies=depth, backend=backend,
                                  tile_b=p["tile_b"])
    return dispatch_rows(
        _route_single_impl, (feature, threshold, child, is_leaf), X,
        statics=dict(backend=backend, tile_b=p["tile_b"],
                     plies=depth_bucket(depth, p["ply_round"])),
        ladder=p["batch_ladder"])


_JIT_CACHES = []


def register_jit_cache(fn):
    """Register an ``lru_cache``-wrapped jit factory with the shared
    clear hook (the serving layers add theirs on import, so one call
    resets every cached dispatch in the process)."""
    _JIT_CACHES.append(fn)
    return fn


register_jit_cache(_dispatch_cached)
register_jit_cache(_jit_forest_merge)
register_jit_cache(_jit_forest_query)
register_jit_cache(_jit_route)
register_jit_cache(_jit_route_single)


def clear_jit_caches() -> None:
    """Drop the cached-jit entry points (test hook: lets a fresh trace see
    monkeypatched query/update internals and resets ``_cache_size``)."""
    for fn in _JIT_CACHES:
        fn.cache_clear()


def forest_best_splits(ao_y, ao_sum_x, ao_radius, ao_origin, attempt, *,
                       backend: str | None = None, tile_m: int | None = None,
                       compact: bool = True,
                       min_bucket: int | None = None):
    """Best split candidate of every (leaf, feature) table.

    attempt: (M,) bool — tables of leaves below their grace period are
    masked out.  Returns (merit, threshold), both (M, F); merit is -inf
    where no valid boundary exists or the leaf is not attempting (thr is
    0 there on the compacted path and unspecified on the full scan — only
    positions with finite merit are meaningful).

    With ``compact=True`` (default) the evaluation cost scales with the
    number of *attempting* leaves K, not capacity M (DESIGN.md §2.5): the
    K attempting tables gather into the smallest power-of-two bucket
    >= K (``query_buckets``), the query runs over that dense buffer, and
    results scatter back.  Called with concrete arrays, K is known and
    the bucket dispatches in Python through a cached jit — K = 0 performs
    no query at all; under an enclosing trace the bucket is selected at
    runtime by ``lax.switch``, so a jitted streaming update still only
    pays for the branch it takes.  ``compact=False`` keeps the full
    M-table scan (the reference path; attempting rows of both paths are
    bit-identical).  ``tile_m``/``min_bucket`` (None: tuned, defaults
    128/8) are schedule knobs — every legal value is bit-identical.
    """
    backend = resolve_backend(backend)
    M, F, C = ao_sum_x.shape
    p = tuned("forest_query", backend, _shape_class_tables(M, F, C),
              tile_m=tile_m, min_bucket=min_bucket)
    tile_m, min_bucket = p["tile_m"], p["min_bucket"]
    buckets = query_buckets(M, min_bucket)
    traced = _is_traced(ao_y, ao_sum_x, ao_radius, ao_origin, attempt)
    if not compact or len(buckets) == 1:
        if traced:
            return _query_full(ao_y, ao_sum_x, ao_radius, ao_origin, attempt,
                               backend=backend, tile_m=tile_m)
        return _jit_forest_query(backend, tile_m, None)(
            ao_y, ao_sum_x, ao_radius, ao_origin, attempt)

    if traced:
        K = jnp.sum(attempt, dtype=jnp.int32)
        bidx = jnp.searchsorted(jnp.asarray(buckets, jnp.int32), K)
        branches = [
            functools.partial(_query_compact, kpad=b, backend=backend,
                              tile_m=tile_m) for b in buckets[:-1]
        ] + [functools.partial(_query_full, backend=backend, tile_m=tile_m)]
        return jax.lax.switch(bidx, branches, ao_y, ao_sum_x, ao_radius,
                              ao_origin, attempt)

    K = int(jnp.sum(attempt))
    if K == 0:  # nothing attempts: no query is dispatched at all
        return (jnp.full((M, F), -jnp.inf, jnp.float32),
                jnp.zeros((M, F), jnp.float32))
    kpad = buckets[bisect.bisect_left(buckets, K)]
    return _jit_forest_query(backend, tile_m, None if kpad == M else kpad)(
        ao_y, ao_sum_x, ao_radius, ao_origin, attempt)


# --------------------------------------------------------------------------
# sketch-observer ops (DESIGN.md §2.8): O(K·F) per-leaf state for massive F·C
# --------------------------------------------------------------------------

def _sketch_compact_backend(n, mean, m2, sum_x, k_out: int, *, backend: str,
                            tile_r: int):
    """Backend body of one compaction: the prototype sort + rank-bucket
    assignment is pure jnp on EVERY backend (sort networks don't pay
    their way in a hand kernel — same reasoning as the route fold), and
    only the grouped bucket reduction dispatches to the Pallas kernel or
    its fused ``segment_sum`` twin.  ``tile_r`` tiles the flattened
    table axis on the kernel path only — schedule-only there (rows are
    independent), and the jnp lowering ignores it, so unlike the
    streaming ``tile_b`` there is NO bit-sensitive stream knob for the
    tuner to pin in this family (a compaction reduces each bucket once;
    there is no sequential Chan merge across tiles)."""
    if backend == "jnp":
        return sketch_lib.compact_planes(n, mean, m2, sum_x, k_out)
    n, mean, m2, sum_x = sketch_lib.sort_planes(n, mean, m2, sum_x)
    bucket = sketch_lib._bucket_ids(n, k_out)
    lead = n.shape[:-1]
    R = 1
    for d in lead:
        R *= d
    tile_r = min(tile_r, round_up(R, 8))
    dense = sketch_compact_pallas(
        pack_compact_planes(n, mean, m2, sum_x, bucket, tile_r=tile_r),
        k_out=k_out, tile_r=tile_r, interpret=_kernel_interpret(backend))
    return unpack_compact_planes(dense, lead, k_out)


def _cat_planes(a_y, a_sum_x, b_y, b_sum_x):
    cat = lambda a, b: jnp.concatenate([a, b], axis=-1)
    return (cat(a_y["n"], b_y["n"]), cat(a_y["mean"], b_y["mean"]),
            cat(a_y["m2"], b_y["m2"]), cat(a_sum_x, b_sum_x))


def _sketch_merge_impl(a_y, a_sum_x, b_y, b_sum_x, *, backend: str,
                       tile_r: int):
    """Backend dispatch body of :func:`sketch_merge`: concatenate the 2K
    centroids and compact back to K."""
    k = a_sum_x.shape[-1]
    n, mean, m2, sum_x = _sketch_compact_backend(
        *_cat_planes(a_y, a_sum_x, b_y, b_sum_x), k,
        backend=backend, tile_r=tile_r)
    return {"n": n, "mean": mean, "m2": m2}, sum_x


@register_jit_cache
@functools.lru_cache(maxsize=None)
def _jit_sketch_merge(backend: str, tile_r: int):
    """Keyed handle for the sketch merge's cached jit (the
    ``_cache_size`` regression hook); delegates to :func:`_dispatch`."""
    return _dispatch(_sketch_merge_impl, backend=backend, tile_r=tile_r)


def sketch_merge(a_y, a_sum_x, b_y, b_sum_x, *, backend: str | None = None,
                 tile_r: int | None = None):
    """Merge two same-shape sketch-observer table sets (DESIGN.md §2.8).

    a_y/b_y: Stats dicts of (N, F, K); a_sum_x/b_sum_x: (N, F, K) — N is
    any table-axis length (a tree's M, a forest's folded T·M, or a
    gathered shard stack reshaped in), K the sketch capacity.  Returns
    the merged ``(ao_y, ao_sum_x)``: the 2K concatenated centroids
    rank-compacted back to K (exact bucket statistics; O(1/K) rank error
    in which centroids share a bucket).  Same mergeability contract as
    :func:`forest_merge` — commutative (bitwise for distinct
    prototypes), associative within the rank bound, empty-operand exact
    — so the §4.1 DP sync and checkpointing swap this in for the Chan
    table merge with no protocol change.  The positional signature
    matches :func:`forest_merge` on purpose; the elementwise Chan merge
    would be WRONG here (slot i of two sketches covers different rank
    ranges), which is why the observer backend must select the family.

    Called with concrete arrays this dispatches through a cached jit;
    under an enclosing trace it inlines.  ``tile_r`` (None: tuned,
    default 256) is schedule-only on every backend — no stream knob
    exists in this family (see :func:`_sketch_compact_backend`).
    """
    backend = resolve_backend(backend)
    N, F, K = a_sum_x.shape
    tile_r = tuned("sketch_merge", backend, _shape_class_tables(N, F, K),
                   tile_r=tile_r)["tile_r"]
    if _is_traced(a_y, a_sum_x, b_y, b_sum_x):
        return _sketch_merge_impl(a_y, a_sum_x, b_y, b_sum_x,
                                  backend=backend, tile_r=tile_r)
    return _jit_sketch_merge(backend, tile_r)(a_y, a_sum_x, b_y, b_sum_x)


def _sketch_update_impl(ao_y, ao_sum_x, leaf, X, y, w, *, backend: str,
                        tile_r: int):
    """Backend dispatch body of :func:`sketch_update`: pre-sketch the
    routed batch into per-(leaf, feature) rank buckets (pure jnp on all
    backends — it is sorts and one segment reduction), then merge the
    batch sketch into the running state via the compaction backend."""
    M, F, K = ao_sum_x.shape
    b_n, b_mean, b_m2, b_sx = sketch_lib.from_batch_planes(leaf, X, y, w, M, K)
    return _sketch_merge_impl(
        ao_y, ao_sum_x, {"n": b_n, "mean": b_mean, "m2": b_m2}, b_sx,
        backend=backend, tile_r=tile_r)


@register_jit_cache
@functools.lru_cache(maxsize=None)
def _jit_sketch_update(backend: str, tile_r: int):
    """Keyed handle for the sketch absorb's cached jit; delegates to the
    shared :func:`_dispatch`."""
    return _dispatch(_sketch_update_impl, backend=backend, tile_r=tile_r)


def sketch_update(ao_y, ao_sum_x, leaf, X, y, w=None, *,
                  backend: str | None = None, tile_r: int | None = None):
    """Absorb a routed batch into every (leaf, feature) sketch.

    ao_y: Stats dict of (M, F, K); ao_sum_x: (M, F, K); leaf: (B,) i32
    routed leaf ids (-1 rows vanish); X: (B, F); y: (B,); w: optional
    (B,) f32 sample weights (default 1) — weight-0 rows vanish and the
    batch pad ladder is bit-identical (pad rows never touch a bucket),
    the same contract as :func:`forest_update`.  Returns the merged
    ``(ao_y, ao_sum_x)``.  One batch is ONE compaction (batch pre-sketch
    + merge) — there is no per-tile streaming, so every ``tile_r`` and
    ladder choice is bit-identical on every backend.

    Called with concrete arrays this dispatches through a cached jit
    with the batch padded to its ladder bucket; under an enclosing trace
    it inlines so the caller's jit fuses the whole absorb stage.
    """
    backend = resolve_backend(backend)
    leaf = jnp.asarray(leaf, jnp.int32).reshape(-1)
    X = jnp.asarray(X, jnp.float32)
    y = jnp.asarray(y, jnp.float32).reshape(-1)
    w = jnp.ones_like(y) if w is None else jnp.asarray(w, jnp.float32).reshape(-1)
    M, F, K = ao_sum_x.shape
    p = tuned("sketch_update", backend, _shape_class_tables(M, F, K),
              tile_r=tile_r)
    if _is_traced(ao_y, ao_sum_x, leaf, X, y, w):
        return _sketch_update_impl(ao_y, ao_sum_x, leaf, X, y, w,
                                   backend=backend, tile_r=p["tile_r"])
    leaf, X, y, w = _pad_batch(
        leaf, X, y, w, _ladder_bucket(X.shape[0], 128, p["batch_ladder"]))
    return _jit_sketch_update(backend, p["tile_r"])(
        ao_y, ao_sum_x, leaf, X, y, w)


def sketch_to_bins(ao_y, ao_sum_x):
    """Densify-at-attempt-time adapter: sketch state -> query-ready bins.

    A sketch's K centroids in ascending-prototype order ARE a valid
    sorted bin table — zero-weight slots are exact identities of the
    §2.4 prefix merge — so "densify" is a defensive stable sort along
    the slot axis (the identity on well-formed state, which
    :func:`sketch_update`/:func:`sketch_merge` keep rank-ordered by
    construction) and :func:`forest_best_splits` consumes the result
    unchanged on every backend.  Pure jnp everywhere (a sort is not a
    profitable hand kernel) and cheap enough to inline at attempt time;
    it takes no backend/tile knobs, so the observer choice can never
    reach a kernel cache key through this adapter.
    """
    n, mean, m2, sum_x = sketch_lib.sort_planes(
        ao_y["n"], ao_y["mean"], ao_y["m2"], ao_sum_x)
    return {"n": n, "mean": mean, "m2": m2}, sum_x
