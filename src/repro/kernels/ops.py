"""Public jit'd wrappers over the Pallas QO kernels.

Single-table ops (``qo_update`` / ``qo_best_split``) and the forest-scale
ops the Hoeffding tree hot path dispatches through (``forest_update`` /
``forest_best_splits``).  Every op takes a ``backend``:

* ``"pallas"``    — the compiled TPU kernel (the production path),
* ``"interpret"`` — the same kernel body under Pallas' CPU interpreter
                    (correctness validation against :mod:`repro.kernels.ref`),
* ``"jnp"``       — a fused pure-jnp lowering of the same math (XLA-fused
                    scatters + cumulative scans), the fast path off-TPU.

``backend=None`` resolves to ``"pallas"`` on TPU and ``"jnp"`` elsewhere.
The jnp lowering of the query uses prefix *sums* of (n, n*mean,
m2 + n*mean^2) rather than log-depth Chan merges — one fused ``cumsum``
instead of hundreds of tiny ops; the kernels and the
:mod:`repro.core.qo` oracle keep the fully robust merge (DESIGN.md §2.4).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import qo as qo_lib
from repro.core import stats
from repro.kernels import ref as _ref
from repro.kernels.qo_update import qo_update_pallas
from repro.kernels.qo_query import qo_query_pallas
from repro.kernels.qo_update_leaves import (
    pack_forest, unpack_forest, qo_update_leaves_pallas, round_up)
from repro.kernels.qo_query_batched import qo_query_batched_pallas

__all__ = [
    "qo_update", "qo_best_split", "default_interpret", "resolve_backend",
    "forest_bin_ids", "forest_update", "forest_best_splits",
]


def default_interpret() -> bool:
    """True off-TPU: single-table kernels run under the Pallas interpreter
    unless the caller forces compiled mode."""
    return jax.default_backend() != "tpu"


def resolve_backend(backend: str | None) -> str:
    """None/'auto' -> compiled kernels on TPU, fused jnp elsewhere."""
    if backend in (None, "auto"):
        return "pallas" if jax.default_backend() == "tpu" else "jnp"
    assert backend in ("pallas", "interpret", "jnp"), backend
    return backend


def _pad_to(arr, mult, fill=0.0):
    n = arr.shape[0]
    rem = (-n) % mult
    if rem == 0:
        return arr
    return jnp.concatenate([arr, jnp.full((rem,), fill, arr.dtype)])


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def qo_update(table: qo_lib.QOTable, x, y, w=None, *, tile: int = 1024,
              interpret: bool | None = None) -> qo_lib.QOTable:
    """Kernel-backed equivalent of :func:`repro.core.qo.update`.

    table: dict QO table (capacity C); x/y: (B,) f32 observations;
    w: optional (B,) f32 sample weights (default 1, weight-0 rows vanish);
    tile: batch tile streamed through VMEM per grid step.  Returns the
    merged table (same shapes).
    """
    interpret = default_interpret() if interpret is None else interpret
    x = jnp.asarray(x, jnp.float32).reshape(-1)
    y = jnp.asarray(y, jnp.float32).reshape(-1)
    w = jnp.ones_like(x) if w is None else jnp.asarray(w, jnp.float32).reshape(-1)
    tile = min(tile, max(128, 1 << (int(x.shape[0]) - 1).bit_length()))
    xp, yp, wp = _pad_to(x, tile), _pad_to(y, tile), _pad_to(w, tile)

    dense, scal = _ref.pack_table(table)
    dense = qo_update_pallas(dense, scal, xp, yp, wp, tile=tile,
                             interpret=interpret)
    return _ref.unpack_table(dense, scal)


@functools.partial(jax.jit, static_argnames=("interpret",))
def qo_best_split(table: qo_lib.QOTable, *,
                  interpret: bool | None = None) -> qo_lib.SplitResult:
    """Kernel-backed equivalent of :func:`repro.core.qo.best_split`.

    Returns a scalar :class:`repro.core.qo.SplitResult` (threshold, VR
    merit, validity) evaluated for all C boundaries in one pass.
    """
    interpret = default_interpret() if interpret is None else interpret
    dense, _ = _ref.pack_table(table)
    out = qo_query_pallas(dense, interpret=interpret)
    score, cand = out[0], out[1]
    best = jnp.argmax(score)
    valid = jnp.isfinite(score[best])
    return qo_lib.SplitResult(
        threshold=cand[best],
        merit=jnp.where(valid, score[best], 0.0),
        valid=valid,
    )


# --------------------------------------------------------------------------
# forest-scale ops: every (leaf, feature) table of a Hoeffding tree at once
# --------------------------------------------------------------------------

def forest_bin_ids(ao_radius, ao_origin, leaf, X, n_bins: int) -> jax.Array:
    """Quantize each routed row into its leaf's per-feature tables.

    ao_radius/ao_origin: (M, F) per-(leaf, feature) quantization; leaf:
    (B,) i32 routed leaf ids; X: (B, F) f32.  Returns (B, F) i32 bin ids
    clipped into [0, n_bins).
    """
    r = ao_radius[leaf]                     # (B, F)
    o = ao_origin[leaf]
    h = jnp.floor((X - o) / r).astype(jnp.int32) + n_bins // 2
    return jnp.clip(h, 0, n_bins - 1)


def _forest_update_jnp(ao_y, ao_sum_x, ao_radius, ao_origin, leaf, X, y, w):
    """Fused-jnp lowering: ONE stacked segment-reduction + two-pass M2."""
    M, F, C = ao_sum_x.shape
    bins = forest_bin_ids(ao_radius, ao_origin, leaf, X, C)
    seg = ((leaf[:, None] * F + jnp.arange(F)[None, :]) * C + bins).reshape(-1)
    wr = jnp.repeat(w, F)
    yr = jnp.repeat(y, F)
    xf = X.reshape(-1)
    pay = jnp.stack([wr, wr * yr, wr * xf], 1)              # (B*F, 3)
    acc = jax.ops.segment_sum(pay, seg, M * F * C)
    nb, syb, sxb = acc[:, 0], acc[:, 1], acc[:, 2]
    meanb = jnp.where(nb > 0, syb / jnp.where(nb > 0, nb, 1.0), 0.0)
    # second pass: residuals against the tile bin mean (exact within tile)
    m2b = jax.ops.segment_sum(wr * (yr - meanb[seg]) ** 2, seg, M * F * C)
    tile = {"n": nb.reshape(M, F, C), "mean": meanb.reshape(M, F, C),
            "m2": m2b.reshape(M, F, C)}
    # Chan merge (Eqs. 4-5) of the tile into the running tables
    return stats.merge(ao_y, tile), ao_sum_x + sxb.reshape(M, F, C)


def _pad_batch(leaf, X, y, w, tile_b):
    B, F = X.shape
    Bp = round_up(max(B, tile_b), tile_b)
    pad = Bp - B
    if pad:
        leaf = jnp.concatenate([leaf, jnp.full((pad,), -1, leaf.dtype)])
        X = jnp.concatenate([X, jnp.zeros((pad, F), X.dtype)])
        y = jnp.concatenate([y, jnp.zeros((pad,), y.dtype)])
        w = jnp.concatenate([w, jnp.zeros((pad,), w.dtype)])
    return leaf, X, y, w


def forest_update(ao_y, ao_sum_x, ao_radius, ao_origin, leaf, X, y, w=None, *,
                  backend: str | None = None, tile_b: int = 256,
                  tile_m: int = 128):
    """Absorb a routed batch into every (leaf, feature) QO table.

    ao_y: Stats dict of (M, F, C); ao_sum_x: (M, F, C); ao_radius/ao_origin:
    (M, F); leaf: (B,) int32 routed leaf ids; X: (B, F); y: (B,);
    w: optional (B,) f32 sample weights (default 1) — every accumulated
    statistic carries w, so weight-0 rows vanish and integer weight k
    equals k repeated unit rows (the online-bagging contract,
    property-tested in tests/test_weighted.py).
    Returns the merged (ao_y, ao_sum_x).

    Deliberately NOT jitted: the tree's ``update`` traces it inline so XLA
    fuses the whole absorb stage (a nested jit would block that); jit it
    yourself for standalone use.
    """
    backend = resolve_backend(backend)
    X = jnp.asarray(X, jnp.float32)
    y = jnp.asarray(y, jnp.float32).reshape(-1)
    w = jnp.ones_like(y) if w is None else jnp.asarray(w, jnp.float32).reshape(-1)
    if backend == "jnp":
        return _forest_update_jnp(ao_y, ao_sum_x, ao_radius, ao_origin,
                                  leaf, X, y, w)

    M, F, C = ao_sum_x.shape
    tile_m = min(tile_m, round_up(M, 8))
    tile_b = min(tile_b, round_up(X.shape[0], 128))
    leaf, X, y, w = _pad_batch(leaf, X, y, w, tile_b)
    dense = pack_forest(ao_y, ao_sum_x, ao_radius, ao_origin, tile_m=tile_m)
    dense = qo_update_leaves_pallas(
        dense, leaf[None, :], X.T, y[None, :], w[None, :], n_bins=C,
        tile_b=tile_b, tile_m=tile_m, interpret=(backend == "interpret"))
    return unpack_forest(dense, M, C)


def _forest_query_jnp(ao_y, ao_sum_x, attempt):
    """Fused-jnp lowering of the batched query: one cumsum over stacked
    prefix payloads + cummax/cummin neighbour scans (DESIGN.md §2.4)."""
    M, F, C = ao_sum_x.shape
    n = ao_y["n"].reshape(M * F, C)
    mean = ao_y["mean"].reshape(M * F, C)
    m2 = ao_y["m2"].reshape(M * F, C)
    sum_x = ao_sum_x.reshape(M * F, C)
    occ = n > 0

    # VR is shift-invariant: center bin means on each table's grand mean so
    # SQ - SY^2/N never cancels against a large target offset (the same
    # robustness the Chan-merge paths get structurally)
    n_tab = n.sum(-1, keepdims=True)
    grand = (n * mean).sum(-1, keepdims=True) / jnp.maximum(n_tab, 1.0)
    mu = mean - grand
    sy = n * mu
    sq = m2 + sy * mu
    pref = jnp.cumsum(jnp.stack([n, sy, sq], 0), axis=-1)    # (3, M*F, C)
    Nl, SYl, SQl = pref[0], pref[1], pref[2]
    Nt, SYt, SQt = Nl[:, -1:], SYl[:, -1:], SQl[:, -1:]
    Nr, SYr, SQr = Nt - Nl, SYt - SYl, SQt - SQl

    def var(NN, SY, SQ):
        d = NN - 1.0
        m2_ = jnp.maximum(SQ - SY * SY / jnp.where(NN > 0, NN, 1.0), 0.0)
        return jnp.where(d > 0, m2_ / jnp.where(d > 0, d, 1.0), 0.0)

    s2d = var(Nt, SYt, SQt)
    ntot = jnp.maximum(Nt, 1.0)
    vr = s2d - (Nl / ntot) * var(Nl, SYl, SQl) - (Nr / ntot) * var(Nr, SYr, SQr)

    idx = jnp.arange(C)
    last = jax.lax.cummax(jnp.where(occ, idx, -1), axis=1)
    first_after = jax.lax.cummin(jnp.where(occ, idx, C), axis=1, reverse=True)
    nxt = jnp.concatenate([first_after[:, 1:], jnp.full((M * F, 1), C)], 1)
    ok = (last >= 0) & (nxt < C) & jnp.repeat(attempt, F)[:, None]
    proto = jnp.where(occ, sum_x / jnp.where(occ, n, 1.0), 0.0)
    p_l = jnp.take_along_axis(proto, jnp.maximum(last, 0), 1)
    p_r = jnp.take_along_axis(proto, jnp.minimum(nxt, C - 1), 1)
    cand = 0.5 * (p_l + p_r)
    score = jnp.where(ok, vr, -jnp.inf)
    return score, cand


def forest_best_splits(ao_y, ao_sum_x, ao_radius, ao_origin, attempt, *,
                       backend: str | None = None, tile_m: int = 128):
    """Best split candidate of every (leaf, feature) table, in one pass.

    attempt: (M,) bool — tables of leaves below their grace period are
    masked out (and whole quiet tiles are skipped on the kernel path).
    Returns (merit, threshold), both (M, F); merit is -inf where no valid
    boundary exists or the leaf is not attempting.  Not jitted, same
    reason as :func:`forest_update`.
    """
    backend = resolve_backend(backend)
    M, F, C = ao_sum_x.shape
    if backend == "jnp":
        score, cand = _forest_query_jnp(ao_y, ao_sum_x, attempt)
    else:
        tile_m = min(tile_m, round_up(M, 8))
        dense = pack_forest(ao_y, ao_sum_x, ao_radius, ao_origin, attempt,
                            tile_m=tile_m)
        out = qo_query_batched_pallas(dense, tile_m=tile_m,
                                      interpret=(backend == "interpret"))
        score = jnp.transpose(out[:, 0, :M, :], (1, 0, 2)).reshape(M * F, -1)
        cand = jnp.transpose(out[:, 1, :M, :], (1, 0, 2)).reshape(M * F, -1)
    best = jnp.argmax(score, -1)
    merit = jnp.max(score, -1).reshape(M, F)
    thr = jnp.take_along_axis(cand, best[:, None], 1)[:, 0].reshape(M, F)
    return merit, thr
