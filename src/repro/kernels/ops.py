"""Public jit'd wrappers over the Pallas QO kernels.

On TPU these run the compiled kernels; elsewhere (this container) they run
the same kernel bodies under ``interpret=True`` (Pallas' CPU interpreter),
which is how correctness is validated against :mod:`repro.kernels.ref`.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import qo as qo_lib
from repro.kernels import ref as _ref
from repro.kernels.qo_update import qo_update_pallas
from repro.kernels.qo_query import qo_query_pallas

__all__ = ["qo_update", "qo_best_split", "default_interpret"]


def default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_to(arr, mult, fill=0.0):
    n = arr.shape[0]
    rem = (-n) % mult
    if rem == 0:
        return arr
    return jnp.concatenate([arr, jnp.full((rem,), fill, arr.dtype)])


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def qo_update(table: qo_lib.QOTable, x, y, w=None, *, tile: int = 1024,
              interpret: bool | None = None) -> qo_lib.QOTable:
    """Kernel-backed equivalent of :func:`repro.core.qo.update`."""
    interpret = default_interpret() if interpret is None else interpret
    x = jnp.asarray(x, jnp.float32).reshape(-1)
    y = jnp.asarray(y, jnp.float32).reshape(-1)
    w = jnp.ones_like(x) if w is None else jnp.asarray(w, jnp.float32).reshape(-1)
    tile = min(tile, max(128, 1 << (int(x.shape[0]) - 1).bit_length()))
    xp, yp, wp = _pad_to(x, tile), _pad_to(y, tile), _pad_to(w, tile)

    dense, scal = _ref.pack_table(table)
    dense = qo_update_pallas(dense, scal, xp, yp, wp, tile=tile,
                             interpret=interpret)
    return _ref.unpack_table(dense, scal)


@functools.partial(jax.jit, static_argnames=("interpret",))
def qo_best_split(table: qo_lib.QOTable, *,
                  interpret: bool | None = None) -> qo_lib.SplitResult:
    """Kernel-backed equivalent of :func:`repro.core.qo.best_split`."""
    interpret = default_interpret() if interpret is None else interpret
    dense, _ = _ref.pack_table(table)
    out = qo_query_pallas(dense, interpret=interpret)
    score, cand = out[0], out[1]
    best = jnp.argmax(score)
    valid = jnp.isfinite(score[best])
    return qo_lib.SplitResult(
        threshold=cand[best],
        merit=jnp.where(valid, score[best], 0.0),
        valid=valid,
    )
