"""Public jit'd wrappers over the Pallas QO kernels.

Single-table ops (``qo_update`` / ``qo_best_split``) and the forest-scale
ops the Hoeffding tree hot path dispatches through (``forest_update`` /
``forest_best_splits``).  Every op takes a ``backend``:

* ``"pallas"``    — the compiled TPU kernel (the production path),
* ``"interpret"`` — the same kernel body under Pallas' CPU interpreter
                    (correctness validation against :mod:`repro.kernels.ref`),
* ``"jnp"``       — a fused pure-jnp lowering of the same math (XLA-fused
                    scatters + cumulative scans), the fast path off-TPU.

``backend=None`` resolves to ``"pallas"`` on TPU and ``"jnp"`` elsewhere.
The jnp lowering of the query uses prefix *sums* of (n, n*mean,
m2 + n*mean^2) rather than log-depth Chan merges — one fused ``cumsum``
instead of hundreds of tiny ops; the kernels and the
:mod:`repro.core.qo` oracle keep the fully robust merge (DESIGN.md §2.4).

Dispatch discipline (DESIGN.md §2.5): both forest ops auto-detect whether
they are being traced.  Called with *concrete* arrays they dispatch
through cached jits keyed on (shape bucket, backend) — batch sizes round
up to power-of-two buckets and the split query compacts to the smallest
power-of-two bucket holding the K attempting tables, so the compile cache
stays bounded and two same-bucket calls never retrace.  Called under an
enclosing trace (e.g. inside ``jax.jit(hoeffding.update)``) they inline,
so the caller's jit still fuses the whole stage; the query then selects
its K bucket at *runtime* with ``lax.switch``.
"""
from __future__ import annotations

import bisect
import functools

import jax
import jax.numpy as jnp

from repro.core import qo as qo_lib
from repro.core import stats
from repro.kernels import ref as _ref
from repro.kernels.qo_update import qo_update_pallas
from repro.kernels.qo_query import qo_query_pallas
from repro.kernels.qo_update_leaves import (
    pack_forest, unpack_forest, qo_update_leaves_pallas, round_up)
from repro.kernels.qo_query_batched import qo_query_batched_pallas

__all__ = [
    "qo_update", "qo_best_split", "default_interpret", "resolve_backend",
    "forest_bin_ids", "forest_update", "forest_best_splits",
    "query_buckets", "clear_jit_caches", "QUERY_MIN_BUCKET",
]


def default_interpret() -> bool:
    """True off-TPU: single-table kernels run under the Pallas interpreter
    unless the caller forces compiled mode."""
    return jax.default_backend() != "tpu"


def resolve_backend(backend: str | None) -> str:
    """None/'auto' -> compiled kernels on TPU, fused jnp elsewhere."""
    if backend in (None, "auto"):
        return "pallas" if jax.default_backend() == "tpu" else "jnp"
    assert backend in ("pallas", "interpret", "jnp"), backend
    return backend


def _pad_to(arr, mult, fill=0.0):
    n = arr.shape[0]
    rem = (-n) % mult
    if rem == 0:
        return arr
    return jnp.concatenate([arr, jnp.full((rem,), fill, arr.dtype)])


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def qo_update(table: qo_lib.QOTable, x, y, w=None, *, tile: int = 1024,
              interpret: bool | None = None) -> qo_lib.QOTable:
    """Kernel-backed equivalent of :func:`repro.core.qo.update`.

    table: dict QO table (capacity C); x/y: (B,) f32 observations;
    w: optional (B,) f32 sample weights (default 1, weight-0 rows vanish);
    tile: batch tile streamed through VMEM per grid step.  Returns the
    merged table (same shapes).
    """
    interpret = default_interpret() if interpret is None else interpret
    x = jnp.asarray(x, jnp.float32).reshape(-1)
    y = jnp.asarray(y, jnp.float32).reshape(-1)
    w = jnp.ones_like(x) if w is None else jnp.asarray(w, jnp.float32).reshape(-1)
    tile = min(tile, max(128, 1 << (int(x.shape[0]) - 1).bit_length()))
    xp, yp, wp = _pad_to(x, tile), _pad_to(y, tile), _pad_to(w, tile)

    dense, scal = _ref.pack_table(table)
    dense = qo_update_pallas(dense, scal, xp, yp, wp, tile=tile,
                             interpret=interpret)
    return _ref.unpack_table(dense, scal)


@functools.partial(jax.jit, static_argnames=("interpret",))
def qo_best_split(table: qo_lib.QOTable, *,
                  interpret: bool | None = None) -> qo_lib.SplitResult:
    """Kernel-backed equivalent of :func:`repro.core.qo.best_split`.

    Returns a scalar :class:`repro.core.qo.SplitResult` (threshold, VR
    merit, validity) evaluated for all C boundaries in one pass.
    """
    interpret = default_interpret() if interpret is None else interpret
    dense, _ = _ref.pack_table(table)
    out = qo_query_pallas(dense, interpret=interpret)
    score, cand = out[0], out[1]
    best = jnp.argmax(score)
    valid = jnp.isfinite(score[best])
    return qo_lib.SplitResult(
        threshold=cand[best],
        merit=jnp.where(valid, score[best], 0.0),
        valid=valid,
    )


# --------------------------------------------------------------------------
# forest-scale ops: every (leaf, feature) table of a Hoeffding tree at once
# --------------------------------------------------------------------------

def _is_traced(*trees) -> bool:
    """True when any leaf of the argument pytrees is a JAX tracer — i.e.
    the caller is already inside a jit/vmap/scan trace and the op must
    inline rather than dispatch through its own cached jit."""
    return any(isinstance(leaf, jax.core.Tracer)
               for t in trees for leaf in jax.tree.leaves(t))


def _pow2_bucket(n: int, lo: int) -> int:
    """Smallest power-of-two multiple of ``lo`` holding ``n`` (``lo`` must
    itself be a power of two) — the shape-bucketing rule that bounds the
    cached-jit compile count to O(log n) entries."""
    b = lo
    while b < n:
        b *= 2
    return b


def forest_bin_ids(ao_radius, ao_origin, leaf, X, n_bins: int) -> jax.Array:
    """Quantize each routed row into its leaf's per-feature tables.

    ao_radius/ao_origin: (M, F) per-(leaf, feature) quantization; leaf:
    (B,) i32 routed leaf ids; X: (B, F) f32.  Returns (B, F) i32 bin ids
    clipped into [0, n_bins).
    """
    r = ao_radius[leaf]                     # (B, F)
    o = ao_origin[leaf]
    h = jnp.floor((X - o) / r).astype(jnp.int32) + n_bins // 2
    return jnp.clip(h, 0, n_bins - 1)


def _forest_update_jnp(ao_y, ao_sum_x, ao_radius, ao_origin, leaf, X, y, w):
    """Fused-jnp lowering: ONE stacked segment-reduction + two-pass M2."""
    M, F, C = ao_sum_x.shape
    bins = forest_bin_ids(ao_radius, ao_origin, leaf, X, C)
    seg = ((leaf[:, None] * F + jnp.arange(F)[None, :]) * C + bins).reshape(-1)
    wr = jnp.repeat(w, F)
    yr = jnp.repeat(y, F)
    xf = X.reshape(-1)
    pay = jnp.stack([wr, wr * yr, wr * xf], 1)              # (B*F, 3)
    acc = jax.ops.segment_sum(pay, seg, M * F * C)
    nb, syb, sxb = acc[:, 0], acc[:, 1], acc[:, 2]
    meanb = jnp.where(nb > 0, syb / jnp.where(nb > 0, nb, 1.0), 0.0)
    # second pass: residuals against the tile bin mean (exact within tile)
    m2b = jax.ops.segment_sum(wr * (yr - meanb[seg]) ** 2, seg, M * F * C)
    tile = {"n": nb.reshape(M, F, C), "mean": meanb.reshape(M, F, C),
            "m2": m2b.reshape(M, F, C)}
    # Chan merge (Eqs. 4-5) of the tile into the running tables
    return stats.merge(ao_y, tile), ao_sum_x + sxb.reshape(M, F, C)


def _pad_batch(leaf, X, y, w, tile_b):
    B, F = X.shape
    Bp = round_up(max(B, tile_b), tile_b)
    pad = Bp - B
    if pad:
        leaf = jnp.concatenate([leaf, jnp.full((pad,), -1, leaf.dtype)])
        X = jnp.concatenate([X, jnp.zeros((pad, F), X.dtype)])
        y = jnp.concatenate([y, jnp.zeros((pad,), y.dtype)])
        w = jnp.concatenate([w, jnp.zeros((pad,), w.dtype)])
    return leaf, X, y, w


def _forest_update_impl(ao_y, ao_sum_x, ao_radius, ao_origin, leaf, X, y, w,
                        *, backend: str, tile_b: int, tile_m: int):
    """Backend dispatch body of :func:`forest_update` (inputs normalized)."""
    if backend == "jnp":
        return _forest_update_jnp(ao_y, ao_sum_x, ao_radius, ao_origin,
                                  leaf, X, y, w)

    M, F, C = ao_sum_x.shape
    tile_m = min(tile_m, round_up(M, 8))
    tile_b = min(tile_b, round_up(X.shape[0], 128))
    leaf, X, y, w = _pad_batch(leaf, X, y, w, tile_b)
    dense = pack_forest(ao_y, ao_sum_x, ao_radius, ao_origin, tile_m=tile_m)
    dense = qo_update_leaves_pallas(
        dense, leaf[None, :], X.T, y[None, :], w[None, :], n_bins=C,
        tile_b=tile_b, tile_m=tile_m, interpret=(backend == "interpret"))
    return unpack_forest(dense, M, C)


@functools.lru_cache(maxsize=None)
def _jit_forest_update(backend: str, tile_b: int, tile_m: int):
    """Cached jit of the absorb op, keyed on backend + tiling; the inner
    jit cache is keyed on shapes, which the public wrapper buckets."""
    return jax.jit(functools.partial(_forest_update_impl, backend=backend,
                                     tile_b=tile_b, tile_m=tile_m))


def forest_update(ao_y, ao_sum_x, ao_radius, ao_origin, leaf, X, y, w=None, *,
                  backend: str | None = None, tile_b: int = 256,
                  tile_m: int = 128):
    """Absorb a routed batch into every (leaf, feature) QO table.

    ao_y: Stats dict of (M, F, C); ao_sum_x: (M, F, C); ao_radius/ao_origin:
    (M, F); leaf: (B,) int32 routed leaf ids; X: (B, F); y: (B,);
    w: optional (B,) f32 sample weights (default 1) — every accumulated
    statistic carries w, so weight-0 rows vanish and integer weight k
    equals k repeated unit rows (the online-bagging contract,
    property-tested in tests/test_weighted.py).
    Returns the merged (ao_y, ao_sum_x).

    Called with concrete arrays this dispatches through a cached jit with
    the batch padded (leaf = -1, w = 0: such rows vanish on every backend)
    to a power-of-two bucket, so ragged streaming batches reuse a bounded
    set of compiled programs.  Under an enclosing trace it inlines, so the
    caller's jit fuses the whole absorb stage.
    """
    backend = resolve_backend(backend)
    leaf = jnp.asarray(leaf, jnp.int32).reshape(-1)
    X = jnp.asarray(X, jnp.float32)
    y = jnp.asarray(y, jnp.float32).reshape(-1)
    w = jnp.ones_like(y) if w is None else jnp.asarray(w, jnp.float32).reshape(-1)
    if _is_traced(ao_y, ao_sum_x, ao_radius, ao_origin, leaf, X, y, w):
        return _forest_update_impl(ao_y, ao_sum_x, ao_radius, ao_origin,
                                   leaf, X, y, w, backend=backend,
                                   tile_b=tile_b, tile_m=tile_m)
    leaf, X, y, w = _pad_batch(leaf, X, y, w, _pow2_bucket(X.shape[0], 128))
    return _jit_forest_update(backend, tile_b, tile_m)(
        ao_y, ao_sum_x, ao_radius, ao_origin, leaf, X, y, w)


def _forest_query_jnp(ao_y, ao_sum_x, attempt):
    """Fused-jnp lowering of the batched query: one cumsum over stacked
    prefix payloads + cummax/cummin neighbour scans (DESIGN.md §2.4)."""
    M, F, C = ao_sum_x.shape
    n = ao_y["n"].reshape(M * F, C)
    mean = ao_y["mean"].reshape(M * F, C)
    m2 = ao_y["m2"].reshape(M * F, C)
    sum_x = ao_sum_x.reshape(M * F, C)
    occ = n > 0

    # VR is shift-invariant: center bin means on each table's grand mean so
    # SQ - SY^2/N never cancels against a large target offset (the same
    # robustness the Chan-merge paths get structurally)
    n_tab = n.sum(-1, keepdims=True)
    grand = (n * mean).sum(-1, keepdims=True) / jnp.maximum(n_tab, 1.0)
    mu = mean - grand
    sy = n * mu
    sq = m2 + sy * mu
    pref = jnp.cumsum(jnp.stack([n, sy, sq], 0), axis=-1)    # (3, M*F, C)
    Nl, SYl, SQl = pref[0], pref[1], pref[2]
    Nt, SYt, SQt = Nl[:, -1:], SYl[:, -1:], SQl[:, -1:]
    Nr, SYr, SQr = Nt - Nl, SYt - SYl, SQt - SQl

    def var(NN, SY, SQ):
        d = NN - 1.0
        m2_ = jnp.maximum(SQ - SY * SY / jnp.where(NN > 0, NN, 1.0), 0.0)
        return jnp.where(d > 0, m2_ / jnp.where(d > 0, d, 1.0), 0.0)

    s2d = var(Nt, SYt, SQt)
    ntot = jnp.maximum(Nt, 1.0)
    vr = s2d - (Nl / ntot) * var(Nl, SYl, SQl) - (Nr / ntot) * var(Nr, SYr, SQr)

    idx = jnp.arange(C)
    last = jax.lax.cummax(jnp.where(occ, idx, -1), axis=1)
    first_after = jax.lax.cummin(jnp.where(occ, idx, C), axis=1, reverse=True)
    nxt = jnp.concatenate([first_after[:, 1:], jnp.full((M * F, 1), C)], 1)
    ok = (last >= 0) & (nxt < C) & jnp.repeat(attempt, F)[:, None]
    proto = jnp.where(occ, sum_x / jnp.where(occ, n, 1.0), 0.0)
    p_l = jnp.take_along_axis(proto, jnp.maximum(last, 0), 1)
    p_r = jnp.take_along_axis(proto, jnp.minimum(nxt, C - 1), 1)
    cand = 0.5 * (p_l + p_r)
    score = jnp.where(ok, vr, -jnp.inf)
    return score, cand


QUERY_MIN_BUCKET = 8


def query_buckets(M: int, min_bucket: int = QUERY_MIN_BUCKET):
    """Static K_pad buckets for a capacity-M table axis: powers of two from
    ``min_bucket`` up, capped by a final full-scan bucket of M itself (so
    a near-full attempt set pays no gather/scatter overhead)."""
    sizes = []
    b = min_bucket
    while b < M:
        sizes.append(b)
        b *= 2
    return tuple(sizes) + (M,)


def _query_full(ao_y, ao_sum_x, ao_radius, ao_origin, attempt, *,
                backend: str, tile_m: int):
    """Uncompacted query over all M tables -> (merit, thr), both (M, F)."""
    M, F, C = ao_sum_x.shape
    if backend == "jnp":
        score, cand = _forest_query_jnp(ao_y, ao_sum_x, attempt)
    else:
        tile_m = min(tile_m, round_up(M, 8))
        dense = pack_forest(ao_y, ao_sum_x, ao_radius, ao_origin, attempt,
                            tile_m=tile_m)
        out = qo_query_batched_pallas(dense, tile_m=tile_m,
                                      interpret=(backend == "interpret"))
        score = jnp.transpose(out[:, 0, :M, :], (1, 0, 2)).reshape(M * F, -1)
        cand = jnp.transpose(out[:, 1, :M, :], (1, 0, 2)).reshape(M * F, -1)
    best = jnp.argmax(score, -1)
    merit = jnp.max(score, -1).reshape(M, F)
    thr = jnp.take_along_axis(cand, best[:, None], 1)[:, 0].reshape(M, F)
    return merit, thr


def _query_compact(ao_y, ao_sum_x, ao_radius, ao_origin, attempt, *,
                   kpad: int, backend: str, tile_m: int):
    """Compact-gather -> query -> scatter-back for a static K_pad bucket.

    Gathers the (at most kpad) attempting tables into a dense
    (kpad, F, C) buffer, runs the ordinary query over it — pad rows carry
    attempt=False, so masked math on jnp and ``pl.when``-skipped tiles on
    the kernel path — and scatters (merit, thr) back to (M, F) with -inf
    fill.  Per-table math is row-independent on every backend, so the
    attempting rows' results are bit-identical to the full scan's.
    """
    M, F, _ = ao_sum_x.shape
    idx = jnp.nonzero(attempt, size=kpad, fill_value=M)[0]       # (kpad,)
    safe = jnp.minimum(idx, M - 1)
    sub = lambda a: a[safe]
    merit_k, thr_k = _query_full(
        jax.tree.map(sub, ao_y), sub(ao_sum_x), sub(ao_radius),
        sub(ao_origin), idx < M, backend=backend, tile_m=tile_m)
    merit = jnp.full((M, F), -jnp.inf, jnp.float32).at[idx].set(
        merit_k, mode="drop")
    thr = jnp.zeros((M, F), jnp.float32).at[idx].set(thr_k, mode="drop")
    return merit, thr


@functools.lru_cache(maxsize=None)
def _jit_forest_query(backend: str, tile_m: int, kpad: int | None):
    """Cached jit of one query bucket (kpad=None: the full scan)."""
    fn = _query_full if kpad is None else \
        functools.partial(_query_compact, kpad=kpad)
    return jax.jit(functools.partial(fn, backend=backend, tile_m=tile_m))


def clear_jit_caches() -> None:
    """Drop the cached-jit entry points (test hook: lets a fresh trace see
    monkeypatched query/update internals and resets ``_cache_size``)."""
    _jit_forest_update.cache_clear()
    _jit_forest_query.cache_clear()


def forest_best_splits(ao_y, ao_sum_x, ao_radius, ao_origin, attempt, *,
                       backend: str | None = None, tile_m: int = 128,
                       compact: bool = True,
                       min_bucket: int = QUERY_MIN_BUCKET):
    """Best split candidate of every (leaf, feature) table.

    attempt: (M,) bool — tables of leaves below their grace period are
    masked out.  Returns (merit, threshold), both (M, F); merit is -inf
    where no valid boundary exists or the leaf is not attempting (thr is
    0 there on the compacted path and unspecified on the full scan — only
    positions with finite merit are meaningful).

    With ``compact=True`` (default) the evaluation cost scales with the
    number of *attempting* leaves K, not capacity M (DESIGN.md §2.5): the
    K attempting tables gather into the smallest power-of-two bucket
    >= K (``query_buckets``), the query runs over that dense buffer, and
    results scatter back.  Called with concrete arrays, K is known and
    the bucket dispatches in Python through a cached jit — K = 0 performs
    no query at all; under an enclosing trace the bucket is selected at
    runtime by ``lax.switch``, so a jitted streaming update still only
    pays for the branch it takes.  ``compact=False`` keeps the full
    M-table scan (the reference path; attempting rows of both paths are
    bit-identical).
    """
    backend = resolve_backend(backend)
    M, F, C = ao_sum_x.shape
    buckets = query_buckets(M, min_bucket)
    traced = _is_traced(ao_y, ao_sum_x, ao_radius, ao_origin, attempt)
    if not compact or len(buckets) == 1:
        if traced:
            return _query_full(ao_y, ao_sum_x, ao_radius, ao_origin, attempt,
                               backend=backend, tile_m=tile_m)
        return _jit_forest_query(backend, tile_m, None)(
            ao_y, ao_sum_x, ao_radius, ao_origin, attempt)

    if traced:
        K = jnp.sum(attempt, dtype=jnp.int32)
        bidx = jnp.searchsorted(jnp.asarray(buckets, jnp.int32), K)
        branches = [
            functools.partial(_query_compact, kpad=b, backend=backend,
                              tile_m=tile_m) for b in buckets[:-1]
        ] + [functools.partial(_query_full, backend=backend, tile_m=tile_m)]
        return jax.lax.switch(bidx, branches, ao_y, ao_sum_x, ao_radius,
                              ao_origin, attempt)

    K = int(jnp.sum(attempt))
    if K == 0:  # nothing attempts: no query is dispatched at all
        return (jnp.full((M, F), -jnp.inf, jnp.float32),
                jnp.zeros((M, F), jnp.float32))
    kpad = buckets[bisect.bisect_left(buckets, K)]
    return _jit_forest_query(backend, tile_m, None if kpad == M else kpad)(
        ao_y, ao_sum_x, ao_radius, ao_origin, attempt)
