"""Pure-jnp oracles for the Pallas kernels.

Single source of truth: the oracles delegate to :mod:`repro.core.qo`
(which the system tests validate against numpy), after converting between
the kernels' dense (8, C) table layout and the core dict layout.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import qo as qo_lib
from repro.kernels.qo_update import ROW_N, ROW_MEAN, ROW_M2, ROW_SUMX, TABLE_ROWS


def pack_table(t: qo_lib.QOTable) -> tuple[jax.Array, jax.Array]:
    """dict table -> ((8, C) dense table, (1, 2) [radius, origin])."""
    cap = t["sum_x"].shape[0]
    dense = jnp.zeros((TABLE_ROWS, cap), jnp.float32)
    dense = dense.at[ROW_N].set(t["y"]["n"])
    dense = dense.at[ROW_MEAN].set(t["y"]["mean"])
    dense = dense.at[ROW_M2].set(t["y"]["m2"])
    dense = dense.at[ROW_SUMX].set(t["sum_x"])
    scal = jnp.stack([t["radius"], t["origin"]]).reshape(1, 2).astype(jnp.float32)
    return dense, scal


def unpack_table(dense: jax.Array, scal: jax.Array) -> qo_lib.QOTable:
    return {
        "radius": scal[0, 0],
        "origin": scal[0, 1],
        "sum_x": dense[ROW_SUMX],
        "y": {"n": dense[ROW_N], "mean": dense[ROW_MEAN], "m2": dense[ROW_M2]},
    }


def qo_update_ref(dense, scal, x, y, w) -> jax.Array:
    """Oracle for qo_update_pallas (same dense layout in/out)."""
    t = unpack_table(dense, scal)
    t = qo_lib.update(t, x, y, w)
    return pack_table(t)[0]


def forest_update_ref(ao_y, ao_sum_x, ao_radius, ao_origin, leaf, X, y, w=None):
    """Oracle for the forest update: per-(leaf, feature) masked qo.update.

    Loops tables in Python (M*F independent single-table updates with the
    batch masked to the rows routed to that leaf) — slow, unambiguous.
    """
    M, F, C = ao_sum_x.shape
    y = jnp.asarray(y, jnp.float32).reshape(-1)
    w = jnp.ones_like(y) if w is None else jnp.asarray(w, jnp.float32)

    def one(m, f):
        t = {"radius": ao_radius[m, f], "origin": ao_origin[m, f],
             "sum_x": ao_sum_x[m, f],
             "y": jax.tree.map(lambda a: a[m, f], ao_y)}
        sel = (leaf == m).astype(jnp.float32) * w
        return qo_lib.update(t, X[:, f], y, sel)

    tables = [[one(m, f) for f in range(F)] for m in range(M)]
    stackf = lambda getter: jnp.stack(
        [jnp.stack([getter(tables[m][f]) for f in range(F)]) for m in range(M)])
    new_y = {k: stackf(lambda t, k=k: t["y"][k]) for k in ("n", "mean", "m2")}
    new_sum_x = stackf(lambda t: t["sum_x"])
    return new_y, new_sum_x


def forest_merge_ref(a_y, a_sum_x, b_y, b_sum_x):
    """Oracle for the cross-shard table merge: per-table qo.merge_tables.

    Loops the (N, F) table grid in Python and merges each pair through
    :func:`repro.core.qo.merge_tables` (the paper's Eqs. 4-5 path the
    system tests validate against numpy) — slow, unambiguous.
    """
    N, F, _ = a_sum_x.shape

    def one(n, f):
        pick = lambda ao_y, ao_sx: {
            "radius": jnp.float32(1.0), "origin": jnp.float32(0.0),
            "sum_x": ao_sx[n, f], "y": jax.tree.map(lambda a: a[n, f], ao_y)}
        return qo_lib.merge_tables(pick(a_y, a_sum_x), pick(b_y, b_sum_x))

    tables = [[one(n, f) for f in range(F)] for n in range(N)]
    stackf = lambda getter: jnp.stack(
        [jnp.stack([getter(tables[n][f]) for f in range(F)]) for n in range(N)])
    new_y = {k: stackf(lambda t, k=k: t["y"][k]) for k in ("n", "mean", "m2")}
    return new_y, stackf(lambda t: t["sum_x"])


def sketch_update_ref(ao_y, ao_sum_x, leaf, X, y, w=None):
    """Oracle for the sketch absorb: per-(leaf, feature) single-table
    :func:`repro.core.sketch.update` with the batch masked to the rows
    routed to that leaf.  Loops tables in Python and exercises the
    single-table path (no cross-leaf offset arithmetic), so it is an
    independent witness for the batched pre-sketch — slow, unambiguous.
    """
    from repro.core import sketch as sk
    M, F, K = ao_sum_x.shape
    y = jnp.asarray(y, jnp.float32).reshape(-1)
    w = jnp.ones_like(y) if w is None else jnp.asarray(w, jnp.float32)

    def one(m, f):
        t = {"sum_x": ao_sum_x[m, f],
             "y": jax.tree.map(lambda a: a[m, f], ao_y)}
        sel = (leaf == m).astype(jnp.float32) * w
        return sk.update(t, X[:, f], y, sel)

    tables = [[one(m, f) for f in range(F)] for m in range(M)]
    stackf = lambda getter: jnp.stack(
        [jnp.stack([getter(tables[m][f]) for f in range(F)]) for m in range(M)])
    new_y = {k: stackf(lambda t, k=k: t["y"][k]) for k in ("n", "mean", "m2")}
    return new_y, stackf(lambda t: t["sum_x"])


def sketch_merge_ref(a_y, a_sum_x, b_y, b_sum_x):
    """Oracle for the sketch merge: per-table single-table
    :func:`repro.core.sketch.merge` over a Python loop of the (N, F)
    grid — slow, unambiguous."""
    from repro.core import sketch as sk
    N, F, _ = a_sum_x.shape

    def one(n, f):
        pick = lambda ao_y, ao_sx: {
            "sum_x": ao_sx[n, f], "y": jax.tree.map(lambda a: a[n, f], ao_y)}
        return sk.merge(pick(a_y, a_sum_x), pick(b_y, b_sum_x))

    tables = [[one(n, f) for f in range(F)] for n in range(N)]
    stackf = lambda getter: jnp.stack(
        [jnp.stack([getter(tables[n][f]) for f in range(F)]) for n in range(N)])
    new_y = {k: stackf(lambda t, k=k: t["y"][k]) for k in ("n", "mean", "m2")}
    return new_y, stackf(lambda t: t["sum_x"])


def route_ref(feature, threshold, child, is_leaf, X, max_depth: int):
    """Oracle for the batched routing kernel: the seed's vmap-of-scalar
    ``fori_loop`` walk, preserved verbatim (per-row dependent gathers
    through the SoA node arrays).  feature/threshold/is_leaf: (M,);
    child: (M, 2); X: (B, F).  Returns (B,) i32 leaf ids."""
    def one(x):
        def body(_, node):
            f = feature[node]
            go_left = x[f] <= threshold[node]
            nxt = jnp.where(go_left, child[node, 0], child[node, 1])
            return jnp.where(is_leaf[node], node, nxt)
        return jax.lax.fori_loop(0, max_depth + 1, body, jnp.int32(0))
    return jax.vmap(one)(X)


def forest_route_ref(feature, threshold, child, is_leaf, X, max_depth: int):
    """Oracle for the fused forest route: :func:`route_ref` vmapped over
    the tree axis — T separate scalar walks.  Arrays carry a leading (T,)
    axis; returns (T, B) i32 per-tree (local) leaf ids."""
    return jax.vmap(
        lambda f, t, c, l: route_ref(f, t, c, l, X, max_depth))(
        feature, threshold, child, is_leaf)


def forest_query_ref(ao_y, ao_sum_x, attempt):
    """Oracle for the batched query: vmap(vmap(qo.best_split)) + masking."""
    M, F, C = ao_sum_x.shape
    split = jax.vmap(jax.vmap(
        lambda sx, yb: qo_lib.best_split(
            {"radius": jnp.float32(1.0), "origin": jnp.float32(0.0),
             "sum_x": sx, "y": yb})))(ao_sum_x, ao_y)
    merit = jnp.where(split.valid & attempt[:, None], split.merit, -jnp.inf)
    return merit, split.threshold


def qo_query_ref(dense) -> jax.Array:
    """Oracle for qo_query_pallas: (8, C) -> (8, C) scores/thresholds."""
    scal = jnp.array([[1.0, 0.0]], jnp.float32)  # radius/origin unused here
    t = unpack_table(dense, scal)
    ybins = t["y"]
    occ = ybins["n"] > 0
    cap = occ.shape[0]

    from repro.core import stats
    left = jax.lax.associative_scan(stats.merge, ybins)
    tot = jax.tree.map(lambda v: v[-1], left)
    right = stats.subtract(
        jax.tree.map(lambda v: jnp.broadcast_to(v, (cap,)), tot), left)
    n_tot = jnp.maximum(tot["n"], 1.0)
    vr = stats.variance(tot) \
        - (left["n"] / n_tot) * stats.variance(left) \
        - (right["n"] / n_tot) * stats.variance(right)

    proto = jnp.where(occ, t["sum_x"] / jnp.where(occ, ybins["n"], 1.0), 0.0)
    idx = jnp.arange(cap)
    last_occ = jax.lax.associative_scan(jnp.maximum, jnp.where(occ, idx, -1))
    first_occ_from = jax.lax.associative_scan(
        jnp.minimum, jnp.where(occ, idx, cap)[::-1])[::-1]
    nxt = jnp.concatenate([first_occ_from[1:], jnp.full((1,), cap)])
    ok = (last_occ >= 0) & (nxt < cap)
    cand = 0.5 * (proto[jnp.maximum(last_occ, 0)] + proto[jnp.minimum(nxt, cap - 1)])

    out = jnp.zeros((TABLE_ROWS, cap), jnp.float32)
    out = out.at[0].set(jnp.where(ok, vr, -jnp.inf))
    out = out.at[1].set(cand)
    return out
