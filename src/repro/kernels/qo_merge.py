"""Pallas TPU kernel: Chan-merge two QO table sets plane by plane.

The write-side collective of DESIGN.md §4.1: a stream sharded over D
devices learns D independent (n, mean, M2, sum_x) table sets against the
SAME quantization grid, and the sync boundary folds them together with
the paper's merge (Eqs. 4-5).  The merge is purely elementwise over the
(table, bin) plane — no contractions, no scans — so the kernel is a
single VPU pass:

    grid = (row-tiles,)
    block = (4, tile_r, Cp)        rows: n / mean / M2 / sum_x

with the (N, F, C) table axis flattened to R = N·F rows of Cp = C
rounded-to-128 lanes (``pack_merge_planes`` — a reshape + pad, no
transpose, unlike the §2.3 forest layout).  Per element:

    n    = n_a + n_b
    mean = (n_a·mean_a + n_b·mean_b) / n        (0 where n == 0)
    M2   = M2_a + M2_b + delta²·n_a·n_b / n     (delta = mean_b − mean_a)
    sum_x= sum_x_a + sum_x_b

exactly :func:`repro.core.stats.merge` — associative, commutative, and
empty-operand safe, which is what lets D shard deltas reduce in any
pairing (the sync uses a fixed log-depth order so reruns are
deterministic).  Pad rows/lanes are all-zero on both sides and merge to
zero, so no mask is needed.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.qo_update_leaves import round_up

__all__ = ["pack_merge_planes", "unpack_merge_planes", "qo_merge_pallas"]


def pack_merge_planes(ao_y, ao_sum_x, *, tile_r: int = 256) -> jax.Array:
    """(N, F, C) dict-of-arrays tables -> dense (4, Rp, Cp) merge planes.

    Row-major flatten of the (N, F) table axes (R = N·F) padded up to the
    row tile; lanes are bins padded to 128.  Cheap by construction: one
    reshape and one pad per plane, no transposes.
    """
    N, F, C = ao_sum_x.shape
    R, Cp = N * F, round_up(C, 128)
    Rp = round_up(R, tile_r)
    planes = jnp.stack([ao_y["n"], ao_y["mean"], ao_y["m2"], ao_sum_x])
    return jnp.zeros((4, Rp, Cp), jnp.float32).at[:, :R, :C].set(
        planes.reshape(4, R, C))


def unpack_merge_planes(dense: jax.Array, shape):
    """Dense (4, Rp, Cp) -> (ao_y dict, ao_sum_x) of ``shape`` = (N, F, C)."""
    N, F, C = shape
    planes = dense[:, :N * F, :C].reshape(4, N, F, C)
    return ({"n": planes[0], "mean": planes[1], "m2": planes[2]}, planes[3])


def _qo_merge_kernel(a_ref, b_ref, o_ref):
    n_a, mean_a, m2_a, sx_a = (a_ref[i] for i in range(4))
    n_b, mean_b, m2_b, sx_b = (b_ref[i] for i in range(4))
    n = n_a + n_b
    safe = jnp.where(n > 0, n, 1.0)
    delta = mean_b - mean_a
    o_ref[0] = n
    o_ref[1] = jnp.where(n > 0, (n_a * mean_a + n_b * mean_b) / safe, 0.0)
    o_ref[2] = jnp.where(
        n > 0, m2_a + m2_b + delta * delta * (n_a * n_b) / safe, 0.0)
    o_ref[3] = sx_a + sx_b


@functools.partial(jax.jit, static_argnames=("tile_r", "interpret"))
def qo_merge_pallas(a: jax.Array, b: jax.Array, *, tile_r: int = 256,
                    interpret: bool = False) -> jax.Array:
    """Merge two packed (4, Rp, Cp) table-plane stacks (Rp % tile_r == 0)."""
    rows, Rp, Cp = a.shape
    assert rows == 4 and a.shape == b.shape, (a.shape, b.shape)
    assert Rp % tile_r == 0, (Rp, tile_r)
    return pl.pallas_call(
        _qo_merge_kernel,
        grid=(Rp // tile_r,),
        in_specs=[pl.BlockSpec((4, tile_r, Cp), lambda i: (0, i, 0)),
                  pl.BlockSpec((4, tile_r, Cp), lambda i: (0, i, 0))],
        out_specs=pl.BlockSpec((4, tile_r, Cp), lambda i: (0, i, 0)),
        out_shape=jax.ShapeDtypeStruct((4, Rp, Cp), jnp.float32),
        interpret=interpret,
    )(a, b)
