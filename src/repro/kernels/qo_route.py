"""Pallas TPU kernel: level-synchronous batched routing for T trees at once.

The read path's hot loop (DESIGN.md §2.6).  The seed routed with
``vmap``-of-scalar ``fori_loop`` — per-row dependent gathers through five
separate node arrays, re-dispatched per tree by the forest layer.  Here
routing is the batch-parallel primitive (Pham et al.'s massively-parallel
traversal model, PAPERS.md): ALL B rows advance through ALL T trees one
depth ply at a time over a folded SoA node table, one ``pallas_call`` with

    grid = (T, batch-tiles)

so each grid step owns one tree's (tile_b,) slice of row states while the
(tile_b, Fp) X block is shared across the T grid dimension — the batch is
never materialized T times.  Node attributes pack into one dense plane:

    attrs : (Np, 128) f32
      lane 0: feature   lane 1: threshold   lane 2: left    lane 3: right

with the tree axis folded into global node ids (tree t's node j is row
``t*M + j`` — the same folded-axis layout as the §5.1 table kernels) and
leaves self-looped (``left = right = self``), so a settled row keeps
re-selecting its own leaf and no ``is_leaf`` test exists at all.  Per ply
the whole transition is one MXU contraction and one compare:

    oh_node : (tile_b, Np)   row r -> its current node
    a       = oh_node @ attrs                      (tile_b, 128) on the MXU
    x_r     = sum(onehot(feature_r) * X_r)         per-row feature select
    node'   = where(x_r <= threshold_r, left_r, right_r)

The one-hot matmul is exact (a single 1.0 per row), so thresholds and
integer ids round-trip bit-identically; routing therefore matches the
scalar oracle id-for-id on every backend.  ``plies`` (the ply count) is
static — any count >= the realized tree depth returns identical leaves,
which is what lets ops.py bucket it and core/serve.py trim snapshots to
the *realized* depth rather than ``cfg.max_depth``.  Batch padding rides
free: pad rows route from the root like any other and are sliced off.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.qo_update_leaves import round_up

ATTR_LANES = 128
LANE_FEATURE, LANE_THRESHOLD, LANE_LEFT, LANE_RIGHT = 0, 1, 2, 3

__all__ = [
    "ATTR_LANES", "LANE_FEATURE", "LANE_THRESHOLD", "LANE_LEFT",
    "LANE_RIGHT", "fold_route_tables", "pack_route_attrs", "qo_route_pallas",
]


def fold_route_tables(feature, threshold, child, is_leaf):
    """SoA node arrays -> folded self-looped transition tables.

    feature/threshold/is_leaf: (T, M); child: (T, M, 2) with -1 at leaves.
    Folds the tree axis into global node ids (``t*M + j``), rewrites
    children to global ids and self-loops every leaf, so one transition
    step is a no-op exactly at settled rows.  Returns
    ``(feature, threshold, left, right)``, all (T*M,) — feature/left/right
    int32, threshold f32.  Shared by every routing backend (the jnp sweep
    gathers these as one packed row; :func:`pack_route_attrs` lays them
    across MXU lanes), so the transition relation can never diverge
    between paths.
    """
    T, M = feature.shape
    N = T * M
    gids = (jnp.arange(T, dtype=jnp.int32)[:, None] * M
            + jnp.arange(M, dtype=jnp.int32)[None, :])            # (T, M)
    gchild = jnp.where(
        child >= 0,
        child + (jnp.arange(T, dtype=jnp.int32) * M)[:, None, None], -1)
    left = jnp.where(is_leaf, gids, gchild[..., 0]).reshape(N)
    right = jnp.where(is_leaf, gids, gchild[..., 1]).reshape(N)
    return (feature.reshape(N), threshold.reshape(N), left, right)


def pack_route_attrs(feature, threshold, child, is_leaf, *,
                     n_pad: int | None = None) -> jax.Array:
    """SoA node arrays (T, M) -> the dense (Np, 128) routing plane.

    Rows in [T*M, Np) self-loop, so any start node < Np routes safely.
    All-f32: node ids stay exact well past 2^24 nodes' worth of any real
    forest (one-hot contractions copy them bit-exactly).
    """
    featg, thr, left, right = fold_route_tables(feature, threshold, child,
                                                is_leaf)
    N = featg.shape[0]
    Np = round_up(max(N if n_pad is None else n_pad, 8), 8)
    selfloop = jnp.arange(Np, dtype=jnp.float32)                 # pad rows
    attrs = jnp.zeros((Np, ATTR_LANES), jnp.float32)
    attrs = attrs.at[:, LANE_FEATURE].set(
        jnp.zeros((Np,)).at[:N].set(featg.astype(jnp.float32)))
    attrs = attrs.at[:, LANE_THRESHOLD].set(
        jnp.zeros((Np,)).at[:N].set(thr))
    attrs = attrs.at[:, LANE_LEFT].set(
        selfloop.at[:N].set(left.astype(jnp.float32)))
    attrs = attrs.at[:, LANE_RIGHT].set(
        selfloop.at[:N].set(right.astype(jnp.float32)))
    return attrs


def _qo_route_kernel(node_ref, x_ref, attrs_ref, out_ref, *, plies: int):
    attrs = attrs_ref[...]                                       # (Np, 128)
    x = x_ref[...]                                               # (tile_b, Fp)
    node = node_ref[0, :].astype(jnp.float32)                    # (tile_b,)
    tile_b, Fp = x.shape
    Np = attrs.shape[0]

    slot = jax.lax.broadcasted_iota(jnp.float32, (tile_b, Np), 1)
    lane = jax.lax.broadcasted_iota(jnp.int32, (tile_b, ATTR_LANES), 1)
    lane_f = jax.lax.broadcasted_iota(jnp.float32, (tile_b, Fp), 1)
    dot = functools.partial(
        jax.lax.dot_general, dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    for _ in range(plies):
        oh = (node[:, None] == slot).astype(jnp.float32)
        a = dot(oh, attrs)                                       # (tile_b, 128)
        f = jnp.sum(jnp.where(lane == LANE_FEATURE, a, 0.0), axis=1)
        thr = jnp.sum(jnp.where(lane == LANE_THRESHOLD, a, 0.0), axis=1)
        left = jnp.sum(jnp.where(lane == LANE_LEFT, a, 0.0), axis=1)
        right = jnp.sum(jnp.where(lane == LANE_RIGHT, a, 0.0), axis=1)
        xv = jnp.sum(jnp.where(lane_f == f[:, None], x, 0.0), axis=1)
        node = jnp.where(xv <= thr, left, right)

    out_ref[0, :] = node.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("plies", "tile_b", "interpret"))
def qo_route_pallas(node0: jax.Array, x: jax.Array, attrs: jax.Array, *,
                    plies: int, tile_b: int = 256,
                    interpret: bool = False) -> jax.Array:
    """node0: (T, Bp) i32 start nodes (global ids); x: (Bp, Fp) f32;
    attrs: (Np, 128) from :func:`pack_route_attrs`.  Bp must be a multiple
    of ``tile_b`` (ops.py pads; pad rows route from the root and are
    sliced off there).  Returns (T, Bp) i32 global leaf ids after
    ``plies`` transition steps.
    """
    T, Bp = node0.shape
    Fp = x.shape[1]
    assert x.shape[0] == Bp and Bp % tile_b == 0
    assert attrs.shape[1] == ATTR_LANES
    if plies == 0:
        return node0
    grid = (T, Bp // tile_b)
    return pl.pallas_call(
        functools.partial(_qo_route_kernel, plies=plies),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, tile_b), lambda t, i: (t, i)),       # row states
            pl.BlockSpec((tile_b, Fp), lambda t, i: (i, 0)),      # shared X
            pl.BlockSpec(attrs.shape, lambda t, i: (0, 0)),       # node plane
        ],
        out_specs=pl.BlockSpec((1, tile_b), lambda t, i: (t, i)),
        out_shape=jax.ShapeDtypeStruct((T, Bp), jnp.int32),
        interpret=interpret,
    )(node0, x, attrs)
