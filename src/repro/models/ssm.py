"""State-space mixers: Mamba-1 (falcon-mamba) and Mamba-2 (zamba2).

Both are written as chunked scans: the sequence is cut into chunks; inside
a chunk the linear recurrence h_t = a_t * h_{t-1} + b_t is solved with an
associative scan, the chunk's outputs y = <h, C> are emitted immediately,
and only the carried state (B, ..., N) crosses chunk boundaries.  Peak
memory is therefore O(B * chunk * d_inner * N) rather than
O(B * S * d_inner * N) — what makes the 32k prefill and 500k decode shapes
feasible (DESIGN.md §6).

Decode is the exact recurrence: one step, O(1) per token — the reason the
SSM/hybrid archs are the ones that run ``long_500k``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import compute_dtype, cast

CONV_K = 4  # depthwise conv kernel width (mamba standard)

# mamba2 chunk solver: "scan" = associative scan over the (B,cs,nh,hd,N)
# discretized inputs (baseline); "ssd" = chunked quadratic form (the real
# mamba-2 SSD algorithm): intra-chunk outputs via (cs x cs) attention-like
# matmuls, no (B,cs,nh,hd,N) tensor ever materialized.  §Perf hillclimb.
_MAMBA2_IMPL = ["scan"]


def set_mamba2_impl(name: str):
    assert name in ("scan", "ssd"), name
    _MAMBA2_IMPL[0] = name


def mamba2_impl() -> str:
    return _MAMBA2_IMPL[0]


def _affine_compose(c1, c2):
    a1, b1 = c1
    a2, b2 = c2
    return a1 * a2, b2 + a2 * b1


def _chunk_scan(step_chunk, xs_chunks, state0):
    """lax.scan over chunks.  ``step_chunk(state, chunk_in) -> (state, y)``."""
    return jax.lax.scan(step_chunk, state0, xs_chunks)


def _solve_chunk(a, b, state):
    """Associative within-chunk solve.  a, b: (B, cs, ...); state (B, ...).
    Returns (h: (B, cs, ...), new_state)."""
    a_sw = jnp.moveaxis(a, 1, 0)
    b_sw = jnp.moveaxis(b, 1, 0)
    cum_a, cum_b = jax.lax.associative_scan(_affine_compose, (a_sw, b_sw))
    h = cum_a * state[None] + cum_b
    return jnp.moveaxis(h, 0, 1), h[-1]


# --------------------------------------------------------------------------
# depthwise causal conv (kernel CONV_K) as shifted adds
# --------------------------------------------------------------------------

def causal_conv(x, w, conv_state=None):
    """x: (B, S, c), w: (CONV_K, c). conv_state: (B, CONV_K-1, c) for decode
    continuity.  Returns (y, new_conv_state)."""
    B, S, c = x.shape
    if conv_state is None:
        conv_state = jnp.zeros((B, CONV_K - 1, c), x.dtype)
    xp = jnp.concatenate([conv_state, x], axis=1)           # (B, S+K-1, c)
    y = jnp.zeros((B, S, c), jnp.float32)
    for i in range(CONV_K):
        y = y + xp[:, i:i + S].astype(jnp.float32) * w[i]
    new_state = xp[:, -(CONV_K - 1):]
    return jax.nn.silu(y).astype(x.dtype), new_state


# --------------------------------------------------------------------------
# Mamba-1 (falcon-mamba)
# --------------------------------------------------------------------------

def mamba1_params(key, cfg):
    d, di, N = cfg.d_model, cfg.d_inner, cfg.ssm_state
    dt_rank = max(1, d // 16)
    ks = jax.random.split(key, 5)
    return {
        "in_proj": jax.random.normal(ks[0], (d, 2 * di), jnp.float32) * d ** -0.5,
        "conv_w": jax.random.normal(ks[1], (CONV_K, di), jnp.float32) * 0.5,
        "x_proj": jax.random.normal(ks[2], (di, dt_rank + 2 * N), jnp.float32) * di ** -0.5,
        "dt_proj": jax.random.normal(ks[3], (dt_rank, di), jnp.float32) * dt_rank ** -0.5,
        "dt_bias": jnp.zeros((di,), jnp.float32),
        "A_log": jnp.log(jnp.tile(jnp.arange(1, N + 1, dtype=jnp.float32), (di, 1))),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": jax.random.normal(ks[4], (di, d), jnp.float32) * di ** -0.5,
    }


def _mamba1_abc(p, x_conv):
    """x_conv (B, cs, di) -> a, b (B,cs,di,N) and C (B,cs,N)."""
    N = (p["x_proj"].shape[1] - p["dt_proj"].shape[0]) // 2
    dt_rank = p["dt_proj"].shape[0]
    proj = jnp.einsum("bsd,de->bse", cast(x_conv), cast(p["x_proj"]),
                      preferred_element_type=jnp.float32)
    dt_r, Bm, Cm = jnp.split(proj, [dt_rank, dt_rank + N], axis=-1)
    dt = jax.nn.softplus(
        jnp.einsum("bsr,rd->bsd", dt_r, p["dt_proj"],
                   preferred_element_type=jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])                                  # (di, N)
    a = jnp.exp(dt[..., None] * A[None, None])                # (B,cs,di,N)
    b = (dt * x_conv.astype(jnp.float32))[..., None] * Bm[:, :, None, :]
    return a, b, Cm


def mamba1(p, x, cfg, cache=None, chunk=128):
    """x: (B, S, d) -> (B, S, d).  cache: {"ssm","conv"} for decode."""
    B, S, d = x.shape
    di, N = cfg.d_inner, cfg.ssm_state
    xi = jnp.einsum("bsd,de->bse", cast(x), cast(p["in_proj"]),
                    preferred_element_type=jnp.float32).astype(compute_dtype())
    x_in, z = jnp.split(xi, 2, axis=-1)
    conv_state = cache["conv"] if cache is not None else None
    x_conv, new_conv = causal_conv(x_in, p["conv_w"], conv_state)

    state0 = cache["ssm"] if cache is not None else jnp.zeros((B, di, N), jnp.float32)

    if S == 1:  # decode fast path: exact single-step recurrence
        a, b, Cm = _mamba1_abc(p, x_conv)
        h = a[:, 0] * state0 + b[:, 0]                        # (B, di, N)
        y = jnp.einsum("bdn,bn->bd", h, Cm[:, 0],
                       preferred_element_type=jnp.float32)[:, None]
        new_state = h
    else:
        cs = min(chunk, S)
        while S % cs:  # largest divisor of S <= requested chunk
            cs -= 1
        nc = S // cs
        xc = jnp.moveaxis(x_conv.reshape(B, nc, cs, di), 1, 0)

        def step(state, x_chunk):
            a, b, Cm = _mamba1_abc(p, x_chunk)
            h, new_state = _solve_chunk(a, b, state)          # (B,cs,di,N)
            y = jnp.einsum("bsdn,bsn->bsd", h, Cm,
                           preferred_element_type=jnp.float32)
            return new_state, y

        new_state, ys = _chunk_scan(step, xc, state0)
        y = jnp.moveaxis(ys, 0, 1).reshape(B, S, di)

    y = y + p["D"] * x_conv.astype(jnp.float32)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    out = jnp.einsum("bse,ed->bsd", y.astype(compute_dtype()), cast(p["out_proj"]),
                     preferred_element_type=jnp.float32).astype(x.dtype)
    return out, {"ssm": new_state, "conv": new_conv}


# --------------------------------------------------------------------------
# Mamba-2 (zamba2) — scalar decay per head, state (B, nh, hd, N)
# --------------------------------------------------------------------------

def mamba2_params(key, cfg):
    """Separate projections per component (z / x / B / C / dt) so each can
    carry its own PartitionSpec — the fused (d, 2di+2N+nh) projection has
    shard-misaligned split points on a 16-way model axis (DESIGN.md §7)."""
    d, di, N = cfg.d_model, cfg.d_inner, cfg.ssm_state
    nh = di // cfg.ssm_head_dim
    ks = jax.random.split(key, 7)
    s = d ** -0.5
    return {
        "in_z": jax.random.normal(ks[0], (d, di), jnp.float32) * s,
        "in_x": jax.random.normal(ks[1], (d, di), jnp.float32) * s,
        "in_B": jax.random.normal(ks[2], (d, N), jnp.float32) * s,
        "in_C": jax.random.normal(ks[3], (d, N), jnp.float32) * s,
        "in_dt": jax.random.normal(ks[4], (d, nh), jnp.float32) * s,
        "conv_x": jax.random.normal(ks[5], (CONV_K, di), jnp.float32) * 0.5,
        "conv_B": jnp.ones((CONV_K, N), jnp.float32) * 0.25,
        "conv_C": jnp.ones((CONV_K, N), jnp.float32) * 0.25,
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "A_log": jnp.zeros((nh,), jnp.float32),
        "D": jnp.ones((nh,), jnp.float32),
        "norm_scale": jnp.ones((di,), jnp.float32),
        "out_proj": jax.random.normal(ks[6], (di, d), jnp.float32) * di ** -0.5,
    }


def mamba2(p, x, cfg, cache=None, chunk=64):
    B, S, d = x.shape
    di, N = cfg.d_inner, cfg.ssm_state
    hd = cfg.ssm_head_dim
    nh = di // hd

    def proj(w):
        return jnp.einsum("bsd,de->bse", cast(x), cast(w),
                          preferred_element_type=jnp.float32).astype(compute_dtype())

    z, x_raw, B_raw, C_raw, dt_in = (proj(p["in_z"]), proj(p["in_x"]),
                                     proj(p["in_B"]), proj(p["in_C"]),
                                     proj(p["in_dt"]))
    cs_prev = cache["conv"] if cache is not None else None
    # depthwise conv applies per channel, so convolve components separately
    x_in, ncx = causal_conv(x_raw, p["conv_x"],
                            None if cs_prev is None else cs_prev["x"])
    Bm, ncb = causal_conv(B_raw, p["conv_B"],
                          None if cs_prev is None else cs_prev["B"])
    Cm, ncc = causal_conv(C_raw, p["conv_C"],
                          None if cs_prev is None else cs_prev["C"])
    new_conv = {"x": ncx, "B": ncb, "C": ncc}
    dt = jax.nn.softplus(dt_in.astype(jnp.float32) + p["dt_bias"])   # (B,S,nh)
    A = -jnp.exp(p["A_log"])                                          # (nh,)
    xh = x_in.reshape(B, S, nh, hd)

    state0 = cache["ssm"] if cache is not None else jnp.zeros((B, nh, hd, N), jnp.float32)

    def ab_of(dt_c, xh_c, B_c):
        a = jnp.exp(dt_c * A)[..., None, None]               # (B,cs,nh,1,1)
        b = (dt_c[..., None] * xh_c.astype(jnp.float32))[..., None] \
            * B_c[:, :, None, None, :].astype(jnp.float32)   # (B,cs,nh,hd,N)
        return a, b

    if S == 1:
        a, b = ab_of(dt, xh, Bm)
        h = a[:, 0] * state0 + b[:, 0]
        y = jnp.einsum("bhdn,bn->bhd", h, Cm[:, 0].astype(jnp.float32),
                       preferred_element_type=jnp.float32)[:, None]
        new_state = h
    else:
        cs = min(chunk, S)
        while S % cs:  # largest divisor of S <= requested chunk
            cs -= 1
        nc = S // cs

        def to_chunks(t):
            return jnp.moveaxis(t.reshape((B, nc, cs) + t.shape[2:]), 1, 0)

        def step_scan(state, chunk_in):
            dt_c, xh_c, B_c, C_c = chunk_in
            a, b = ab_of(dt_c, xh_c, B_c)
            # broadcast scalar decay to the full state shape for the scan
            a = jnp.broadcast_to(a, b.shape)
            h, new_state = _solve_chunk(a, b, state)          # (B,cs,nh,hd,N)
            y = jnp.einsum("bshdn,bsn->bshd", h, C_c.astype(jnp.float32),
                           preferred_element_type=jnp.float32)
            return new_state, y

        def step_ssd(state, chunk_in):
            """SSD quadratic form (the real mamba-2 algorithm): intra-chunk
            outputs via (cs x cs) attention-like matmuls; the
            (B,cs,nh,hd,N) discretized tensor is never materialized."""
            dt_c, xh_c, B_c, C_c = chunk_in
            dt32 = dt_c.astype(jnp.float32)                   # (B,cs,nh)
            xh32 = xh_c.astype(jnp.float32)                   # (B,cs,nh,hd)
            la = jnp.cumsum(dt32 * A, axis=1)                 # log-decay prefix
            cb = jnp.einsum("btn,bsn->bts", C_c.astype(jnp.float32),
                            B_c.astype(jnp.float32),
                            preferred_element_type=jnp.float32)
            ddec = la[:, :, None, :] - la[:, None, :, :]      # (B,t,s,nh)
            causal = jnp.tril(jnp.ones((cs, cs), bool))
            w = jnp.where(causal[None, :, :, None],
                          jnp.exp(jnp.minimum(ddec, 0.0)), 0.0)
            scores = cb[..., None] * w * dt32[:, None, :, :]  # (B,t,s,nh)
            y_intra = jnp.einsum("btsh,bshd->bthd", scores, xh32,
                                 preferred_element_type=jnp.float32)
            # carry-in state read through C_t with decay e^{la_t}
            y_inter = jnp.einsum("btn,bhdn,bth->bthd",
                                 C_c.astype(jnp.float32), state, jnp.exp(la),
                                 preferred_element_type=jnp.float32)
            # state: decay to chunk end + decayed outer products
            w_end = jnp.exp(la[:, -1:, :] - la) * dt32        # (B,cs,nh)
            new_state = jnp.exp(la[:, -1])[:, :, None, None] * state \
                + jnp.einsum("bsh,bshd,bsn->bhdn", w_end, xh32,
                             B_c.astype(jnp.float32),
                             preferred_element_type=jnp.float32)
            return new_state, y_intra + y_inter

        step = step_ssd if mamba2_impl() == "ssd" else step_scan

        new_state, ys = _chunk_scan(
            step, (to_chunks(dt), to_chunks(xh), to_chunks(Bm), to_chunks(Cm)),
            state0)
        y = jnp.moveaxis(ys, 0, 1).reshape(B, S, nh, hd)

    if S == 1:
        y = y.reshape(B, 1, nh, hd)
    y = y + p["D"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(B, S, di)
    # gated RMSNorm (mamba2 standard)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(y * y, axis=-1, keepdims=True)
    y = y * jax.lax.rsqrt(var + cfg.norm_eps) * p["norm_scale"]
    out = jnp.einsum("bse,ed->bsd", y.astype(compute_dtype()), cast(p["out_proj"]),
                     preferred_element_type=jnp.float32).astype(x.dtype)
    return out, {"ssm": new_state, "conv": new_conv}
