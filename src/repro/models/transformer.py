"""Model family assembly: decoder-only, MoE, SSM, hybrid, encoder-decoder.

Layer stacks are ``lax.scan`` over stacked (L, ...) parameter pytrees —
one layer body in the HLO regardless of depth, which keeps the 512-device
SPMD compile tractable for 64-layer models.  Decode caches are stacked the
same way and threaded through the scan as xs/ys.

Families (cfg.family):
  dense | moe | vlm : decoder-only LM (vlm = early-fusion token stream)
  ssm               : mamba1 stack (attention-free)
  hybrid            : mamba2 stack + one weight-shared attention block
                      applied every cfg.hybrid_period layers (zamba2)
  encdec            : whisper-style encoder + causal decoder w/ cross-attn
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import ssm as S
from repro.models.layers import compute_dtype, cast, rms_norm


# --------------------------------------------------------------------------
# parameter init
# --------------------------------------------------------------------------

def _stacked(init_fn, key, n):
    return jax.vmap(init_fn)(jax.random.split(key, n))


def _dense_block_params(key, cfg):
    k1, k2 = jax.random.split(key)
    p = {
        "ln1": jnp.ones((cfg.d_model,), jnp.float32),
        "ln2": jnp.ones((cfg.d_model,), jnp.float32),
        "attn": L.attention_params(k1, cfg),
    }
    if cfg.is_moe:
        p["moe"] = L.moe_params(k2, cfg)
    else:
        p["mlp"] = L.swiglu_params(k2, cfg)
    return p


def _encdec_dec_block_params(key, cfg):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "ln1": jnp.ones((cfg.d_model,), jnp.float32),
        "ln_x": jnp.ones((cfg.d_model,), jnp.float32),
        "ln2": jnp.ones((cfg.d_model,), jnp.float32),
        "attn": L.attention_params(k1, cfg),
        "xattn": L.attention_params(k2, cfg),
        "mlp": L.swiglu_params(k3, cfg),
    }


def _ssm_block_params(key, cfg):
    fn = S.mamba1_params if cfg.ssm_version == 1 else S.mamba2_params
    return {"ln": jnp.ones((cfg.d_model,), jnp.float32), "mixer": fn(key, cfg)}


def init_params(key, cfg) -> Dict[str, Any]:
    keys = jax.random.split(key, 8)
    params: Dict[str, Any] = {
        "embed": jax.random.normal(keys[0], (cfg.vocab, cfg.d_model),
                                   jnp.float32) * cfg.d_model ** -0.5,
        "final_norm": jnp.ones((cfg.d_model,), jnp.float32),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = jax.random.normal(
            keys[1], (cfg.d_model, cfg.vocab), jnp.float32) * cfg.d_model ** -0.5

    if cfg.family in ("dense", "moe", "vlm"):
        params["layers"] = _stacked(
            lambda k: _dense_block_params(k, cfg), keys[2], cfg.n_layers)
    elif cfg.family == "ssm":
        params["layers"] = _stacked(
            lambda k: _ssm_block_params(k, cfg), keys[2], cfg.n_layers)
    elif cfg.family == "hybrid":
        params["layers"] = _stacked(
            lambda k: _ssm_block_params(k, cfg), keys[2], cfg.n_layers)
        params["shared_attn"] = _dense_block_params(keys[3], cfg)
    elif cfg.family == "encdec":
        params["enc_layers"] = _stacked(
            lambda k: _dense_block_params(k, cfg), keys[2], cfg.n_enc_layers)
        params["enc_norm"] = jnp.ones((cfg.d_model,), jnp.float32)
        params["layers"] = _stacked(
            lambda k: _encdec_dec_block_params(k, cfg), keys[3], cfg.n_layers)
    else:
        raise ValueError(cfg.family)
    return params


# --------------------------------------------------------------------------
# decode caches
# --------------------------------------------------------------------------

def init_cache(cfg, batch: int, max_seq: int) -> Dict[str, Any]:
    """Abstract-safe cache init (pure shapes, works under eval_shape)."""
    Hkv, hd = cfg.n_kv_heads, cfg.hd
    Ld = cfg.n_layers

    def attn_cache(n, seq):
        c = {
            "k": jnp.zeros((n, batch, seq, Hkv, hd), compute_dtype()),
            "v": jnp.zeros((n, batch, seq, Hkv, hd), compute_dtype()),
        }
        if cfg.swa_window and cfg.swa_window < max_seq:
            c["pos"] = jnp.full((n, seq), -1, jnp.int32)  # ring-slot abs pos
        return c

    def ssm_cache(n):
        K = S.CONV_K - 1
        if cfg.ssm_version == 1:
            st = jnp.zeros((n, batch, cfg.d_inner, cfg.ssm_state), jnp.float32)
            conv = jnp.zeros((n, batch, K, cfg.d_inner), compute_dtype())
        else:
            nh = cfg.d_inner // cfg.ssm_head_dim
            st = jnp.zeros((n, batch, nh, cfg.ssm_head_dim, cfg.ssm_state),
                           jnp.float32)
            conv = {
                "x": jnp.zeros((n, batch, K, cfg.d_inner), compute_dtype()),
                "B": jnp.zeros((n, batch, K, cfg.ssm_state), compute_dtype()),
                "C": jnp.zeros((n, batch, K, cfg.ssm_state), compute_dtype()),
            }
        return {"ssm": st, "conv": conv}

    if cfg.family in ("dense", "moe", "vlm"):
        seq = min(max_seq, cfg.swa_window) if cfg.swa_window else max_seq
        return {"attn": attn_cache(Ld, seq)}
    if cfg.family == "ssm":
        return {"ssm": ssm_cache(Ld)}
    if cfg.family == "hybrid":
        n_shared = cfg.n_layers // cfg.hybrid_period
        return {"ssm": ssm_cache(Ld), "attn": attn_cache(n_shared, max_seq)}
    if cfg.family == "encdec":
        return {
            "attn": attn_cache(Ld, max_seq),
            "cross_k": jnp.zeros((Ld, batch, cfg.enc_seq, Hkv, hd), compute_dtype()),
            "cross_v": jnp.zeros((Ld, batch, cfg.enc_seq, Hkv, hd), compute_dtype()),
        }
    raise ValueError(cfg.family)


# --------------------------------------------------------------------------
# ring-buffer windowed KV (SWA decode) helpers
# --------------------------------------------------------------------------

def _swa_decode_attn(p, cfg, x, cache_k, cache_v, cache_slot_pos, cache_pos):
    """Single-token attention against a ring-buffer window cache.

    cache_k/v: (B, W, Hkv, hd); cache_slot_pos: (W,) absolute positions.
    """
    B = x.shape[0]
    W = cache_k.shape[1]
    H, hd = p["wq"].shape[1:]
    Hkv = p["wk"].shape[1]
    pos_b = jnp.full((B, 1), 0) + cache_pos
    xq = jnp.einsum("bsd,dnh->bsnh", cast(x), cast(p["wq"]),
                    preferred_element_type=jnp.float32).astype(compute_dtype())
    xk = jnp.einsum("bsd,dkh->bskh", cast(x), cast(p["wk"]),
                    preferred_element_type=jnp.float32).astype(compute_dtype())
    xv = jnp.einsum("bsd,dkh->bskh", cast(x), cast(p["wv"]),
                    preferred_element_type=jnp.float32).astype(compute_dtype())
    if cfg.qk_norm:
        xq = rms_norm(xq, p["q_norm"], cfg.norm_eps)
        xk = rms_norm(xk, p["k_norm"], cfg.norm_eps)
    xq = L.rope(xq, pos_b, cfg.rope_theta)
    xk = L.rope(xk, pos_b, cfg.rope_theta)

    slot = jnp.mod(cache_pos, W)
    k_all = jax.lax.dynamic_update_slice_in_dim(cache_k, xk, slot, 1)
    v_all = jax.lax.dynamic_update_slice_in_dim(cache_v, xv, slot, 1)
    slot_pos = cache_slot_pos.at[slot].set(cache_pos)

    k_rep = L.repeat_kv(k_all, H // Hkv)
    v_rep = L.repeat_kv(v_all, H // Hkv)
    logits = jnp.einsum("bsnh,bwnh->bsnw", xq, k_rep,
                        preferred_element_type=jnp.float32) / (hd ** 0.5)
    valid = (slot_pos >= 0) & (slot_pos <= cache_pos) \
        & (slot_pos > cache_pos - cfg.swa_window)
    logits = jnp.where(valid[None, None, None, :], logits, -jnp.inf)
    prob = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bsnw,bwnh->bsnh", prob.astype(v_rep.dtype), v_rep,
                     preferred_element_type=jnp.float32).astype(compute_dtype())
    proj = jnp.einsum("bsnh,nhd->bsd", out, cast(p["wo"]),
                      preferred_element_type=jnp.float32).astype(x.dtype)
    return proj, k_all, v_all, slot_pos


# --------------------------------------------------------------------------
# blocks
# --------------------------------------------------------------------------

def _write_prefill_cache(cache, kv, cfg):
    """Write a prompt's post-rope k/v (B, S, Hkv, hd) into a decode cache."""
    S = kv["k"].shape[1]
    if "pos" in cache:  # ring buffer (SWA): keep the last min(S, W) tokens
        W = cache["k"].shape[1]
        keep = min(S, W)
        pos = jnp.arange(S - keep, S)
        slots = jnp.mod(pos, W)
        k = cache["k"].at[:, slots].set(kv["k"][:, -keep:])
        v = cache["v"].at[:, slots].set(kv["v"][:, -keep:])
        sp = cache["pos"].at[slots].set(pos)
        return {"k": k, "v": v, "pos": sp}
    k = jax.lax.dynamic_update_slice_in_dim(cache["k"], kv["k"], 0, 1)
    v = jax.lax.dynamic_update_slice_in_dim(cache["v"], kv["v"], 0, 1)
    return {"k": k, "v": v}


def _dense_block(p, x, cfg, positions, cache, cache_pos, kv_chunk):
    aux = jnp.float32(0.0)
    S = x.shape[1]
    h_in = rms_norm(x, p["ln1"], cfg.norm_eps)
    if cache is not None and S == 1 and "pos" in cache:
        h, k, v, sp = _swa_decode_attn(
            p["attn"], cfg, h_in, cache["k"], cache["v"], cache["pos"], cache_pos)
        new_cache = {"k": k, "v": v, "pos": sp}
    elif cache is not None and S == 1:
        h, nc = L.attention(p["attn"], h_in, cfg=cfg, positions=positions,
                            kv_cache={"k": cache["k"], "v": cache["v"]},
                            cache_pos=cache_pos, kv_chunk=kv_chunk)
        new_cache = nc
    elif cache is not None:
        # prefill: chunked self-attention + one-shot cache write
        h, kv = L.attention(p["attn"], h_in, cfg=cfg, positions=positions,
                            kv_chunk=kv_chunk)
        new_cache = _write_prefill_cache(cache, kv, cfg)
    else:
        h, _ = L.attention(p["attn"], h_in, cfg=cfg, positions=positions,
                           kv_chunk=kv_chunk)
        new_cache = None
    x = x + h
    h_in = rms_norm(x, p["ln2"], cfg.norm_eps)
    if "moe" in p:
        h, aux = L.moe(p["moe"], h_in, cfg)
    else:
        h = L.swiglu(p["mlp"], h_in)
    return x + h, new_cache, aux


def _ssm_block(p, x, cfg, cache):
    h, new_cache = (S.mamba1 if cfg.ssm_version == 1 else S.mamba2)(
        p["mixer"], rms_norm(x, p["ln"], cfg.norm_eps), cfg, cache)
    return x + h, new_cache


# --------------------------------------------------------------------------
# family forwards.  All return (hidden, new_caches, aux_loss).
# --------------------------------------------------------------------------

def _scan_layers(body, x, stacked_params, stacked_cache, remat, act_spec=None):
    """scan over stacked layer params (+ optional stacked caches)."""
    def step(carry, xs):
        x, aux = carry
        x = L.constrain(x, act_spec)
        p, c = xs
        if remat:
            fn = jax.checkpoint(lambda p_, x_, c_: body(p_, x_, c_),
                                prevent_cse=False)
            x, nc, a = fn(p, x, c)
        else:
            x, nc, a = body(p, x, c)
        return (x, aux + a), nc

    (x, aux), new_caches = jax.lax.scan(
        step, (x, jnp.float32(0.0)), (stacked_params, stacked_cache))
    return x, new_caches, aux


def forward(params, cfg, x, positions, caches=None, cache_pos=None,
            enc_out=None, remat=False, kv_chunk=512, act_spec=None):
    """Run the layer stack.  x: (B, S, d) hidden states (already embedded).

    caches: stacked decode caches (None for train/prefill-from-scratch...
    prefill DOES pass caches to fill them).  Returns (hidden, caches, aux).
    """
    fam = cfg.family

    if fam in ("dense", "moe", "vlm"):
        c = caches["attn"] if caches is not None else None

        def body(p, x, cache):
            return _dense_block(p, x, cfg, positions, cache, cache_pos, kv_chunk)

        x, nc, aux = _scan_layers(body, x, params["layers"], c, remat, act_spec)
        new_caches = {"attn": nc} if caches is not None else None
        return x, new_caches, aux

    if fam == "ssm":
        c = caches["ssm"] if caches is not None else None

        def body(p, x, cache):
            x, nc = _ssm_block(p, x, cfg, cache)
            return x, nc, jnp.float32(0.0)

        x, nc, aux = _scan_layers(body, x, params["layers"], c, remat, act_spec)
        new_caches = {"ssm": nc} if caches is not None else None
        return x, new_caches, aux

    if fam == "hybrid":
        period = cfg.hybrid_period
        n_groups = cfg.n_layers // period
        ssm_c = caches["ssm"] if caches is not None else None
        attn_c = caches["attn"] if caches is not None else None
        new_ssm, new_attn = [], []
        aux = jnp.float32(0.0)

        def body(p, x, cache):
            x, nc = _ssm_block(p, x, cfg, cache)
            return x, nc, jnp.float32(0.0)

        for g in range(n_groups):
            sl = lambda t: jax.tree.map(
                lambda a: jax.lax.slice_in_dim(a, g * period, (g + 1) * period, axis=0), t)
            grp_params = sl(params["layers"])
            grp_cache = sl(ssm_c) if ssm_c is not None else None
            x, nc, _ = _scan_layers(body, x, grp_params, grp_cache, remat,
                                    act_spec)
            if ssm_c is not None:
                new_ssm.append(nc)
            ac = jax.tree.map(lambda a: a[g], attn_c) if attn_c is not None else None
            x = L.constrain(x, act_spec)
            x, nac, a = _dense_block(params["shared_attn"], x, cfg, positions,
                                     ac, cache_pos, kv_chunk)
            aux = aux + a
            if attn_c is not None:
                new_attn.append(nac)
        new_caches = None
        if caches is not None:
            new_caches = {
                "ssm": jax.tree.map(lambda *xs: jnp.concatenate(xs, 0), *new_ssm),
                "attn": jax.tree.map(lambda *xs: jnp.stack(xs, 0), *new_attn),
            }
        return x, new_caches, aux

    if fam == "encdec":
        # decoder over x with cross attention on enc_out (B, Senc, d) or
        # precomputed cross k/v in caches
        self_c = caches["attn"] if caches is not None else None

        if caches is not None and enc_out is None:
            cross_k, cross_v = caches["cross_k"], caches["cross_v"]
        else:
            # compute cross k/v from encoder output per layer inside scan
            cross_k = cross_v = None

        def body(p, x, xs):
            cache, ck, cv = xs
            aux = jnp.float32(0.0)
            Scur = x.shape[1]
            h_in = rms_norm(x, p["ln1"], cfg.norm_eps)
            if cache is not None and Scur == 1:
                h, nc = L.attention(p["attn"], h_in, cfg=cfg, positions=positions,
                                    kv_cache={"k": cache["k"], "v": cache["v"]},
                                    cache_pos=cache_pos, kv_chunk=kv_chunk)
            elif cache is not None:
                h, kv = L.attention(p["attn"], h_in, cfg=cfg, positions=positions,
                                    kv_chunk=kv_chunk)
                nc = _write_prefill_cache(cache, kv, cfg)
            else:
                h, nc = L.attention(p["attn"], h_in, cfg=cfg, positions=positions,
                                    kv_chunk=kv_chunk)
            x = x + h
            # cross attention
            if ck is None:
                ck = jnp.einsum("bsd,dkh->bskh", cast(enc_out), cast(p["xattn"]["wk"]),
                                preferred_element_type=jnp.float32).astype(compute_dtype())
                cv = jnp.einsum("bsd,dkh->bskh", cast(enc_out), cast(p["xattn"]["wv"]),
                                preferred_element_type=jnp.float32).astype(compute_dtype())
            h_in = rms_norm(x, p["ln_x"], cfg.norm_eps)
            h, _ = L.attention(p["xattn"], h_in, cfg=cfg, positions=positions,
                               cross_kv=(ck, cv), kv_chunk=kv_chunk)
            x = x + h
            h = L.swiglu(p["mlp"], rms_norm(x, p["ln2"], cfg.norm_eps))
            return x + h, (nc, ck, cv), aux

        def step(carry, xs):
            x, aux = carry
            x = L.constrain(x, act_spec)
            p, cache, ck, cv = xs
            if remat:
                fn = jax.checkpoint(
                    lambda p_, x_, c_, k_, v_: body(p_, x_, (c_, k_, v_)),
                    prevent_cse=False)
                x, out, a = fn(p, x, cache, ck, cv)
            else:
                x, out, a = body(p, x, (cache, ck, cv))
            return (x, aux + a), out

        (x, aux), outs = jax.lax.scan(
            step, (x, jnp.float32(0.0)),
            (params["layers"], self_c, cross_k, cross_v))
        new_caches = None
        if caches is not None:
            nc, ck, cv = outs
            new_caches = {"attn": nc, "cross_k": ck, "cross_v": cv}
        return x, new_caches, aux

    raise ValueError(fam)


def encode(params, cfg, enc_in, remat=False, kv_chunk=512, act_spec=None):
    """Encoder stack (whisper): enc_in (B, Senc, d) stub frame embeddings."""
    positions = jnp.arange(enc_in.shape[1])

    def body(p, x, cache):
        h, _ = L.attention(p["attn"], rms_norm(x, p["ln1"], cfg.norm_eps),
                           cfg=cfg, positions=positions, causal=False,
                           kv_chunk=kv_chunk)
        x = x + h
        h = L.swiglu(p["mlp"], rms_norm(x, p["ln2"], cfg.norm_eps))
        return x + h, None, jnp.float32(0.0)

    x, _, _ = _scan_layers(body, enc_in, params["enc_layers"], None, remat,
                           act_spec)
    return rms_norm(x, params["enc_norm"], cfg.norm_eps)
