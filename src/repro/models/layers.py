"""Transformer building blocks, written pjit-first.

Everything here is a pure function over param pytrees.  Design points that
matter at 512+ chips (DESIGN.md §7):

* attention is **chunked** over the KV axis with an online-softmax scan, so
  the S x S logits tensor is never materialized (required for the 32k
  prefill and 500k decode shapes to fit HBM);
* GQA is computed in grouped layout (B, S, Hkv, G, hd) so the partitioner
  shards the *kv-head* axis and query groups follow for free;
* MoE uses grouped capacity dispatch (GShard-style, first-come keep) with
  gather/scatter instead of (T, E, C) one-hot tensors, so the dispatch
  memory is O(tokens * top_k * capacity_factor * d) and expert weights can
  shard either over the expert axis (EP, when E divides the model axis) or
  over d_ff (TP fallback, e.g. grok's 8 experts on a 16-way axis);
* all matmuls run in bf16 with f32 accumulation (`preferred_element_type`).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

_COMPUTE_DTYPE = [jnp.bfloat16]


def set_compute_dtype(dtype):
    """bf16 for TPU lowering/dry-run; f32 for CPU smoke tests (the CPU
    backend cannot execute bf16 dots)."""
    _COMPUTE_DTYPE[0] = dtype


def compute_dtype():
    return _COMPUTE_DTYPE[0]


def cast(x):
    return x.astype(_COMPUTE_DTYPE[0])


def constrain(x, spec):
    """Pin a PartitionSpec on an activation (no-op when spec is None).

    Applied to the residual stream at every layer boundary: GSPMD
    propagates input shardings poorly through while-loop carries (a scan
    over layers can silently replicate the batch axis 16x), so the carry
    is re-pinned each iteration."""
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(x, spec)


# --------------------------------------------------------------------------
# norms / rope
# --------------------------------------------------------------------------

# lean mode: avoid materializing f32 copies of residual-sized tensors in
# norms and attention probabilities (the variance reduction stays f32 —
# it is fusion-internal).  §Perf hillclimb; off by default (baseline).
_LEAN_INTERNALS = [False]


def set_lean_internals(on: bool):
    _LEAN_INTERNALS[0] = bool(on)


def rms_norm(x, scale, eps=1e-5):
    if _LEAN_INTERNALS[0]:
        var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1,
                       keepdims=True)
        inv = jax.lax.rsqrt(var + eps).astype(x.dtype)
        return x * inv * scale.astype(x.dtype)
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps) * scale).astype(x.dtype)


def rope(x, positions, theta=1e4):
    """x: (B, S, *head_axes, hd); positions: (S,) or (B, S)."""
    hd = x.shape[-1]
    half = hd // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq  # (..., S, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    n_head_axes = x.ndim - 3  # axes between S and hd (e.g. Hkv, G)
    for _ in range(n_head_axes):
        cos = cos[..., None, :]
        sin = sin[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# attention
# --------------------------------------------------------------------------

def repeat_kv(k, n_rep):
    """(B, S, Hkv, hd) -> (B, S, Hkv*n_rep, hd).  A broadcast-gather; done
    per KV chunk so the expanded tensor never exceeds one chunk."""
    if n_rep == 1:
        return k
    return jnp.repeat(k, n_rep, axis=2)


def _online_softmax_scan(q, k, v, q_pos, kv_pos, *, causal, window, kv_chunk,
                         n_rep=1):
    """Chunked attention: scan over KV chunks with running (m, l, acc).

    q: (B, S, H, hd)   k, v: (B, Skv, Hkv, hd) with H = Hkv * n_rep
    q_pos: (S,), kv_pos: (Skv,) absolute positions for masking.
    Returns (B, S, H, hd).
    """
    B, S, H, hd = q.shape
    Skv = k.shape[1]
    kv_chunk = min(kv_chunk, Skv)
    while Skv % kv_chunk:  # largest divisor of Skv <= requested chunk
        kv_chunk -= 1
    n_chunks = Skv // kv_chunk
    scale = 1.0 / (hd ** 0.5)

    kc = k.reshape(B, n_chunks, kv_chunk, -1, hd)
    vc = v.reshape(B, n_chunks, kv_chunk, -1, hd)
    pc = kv_pos.reshape(n_chunks, kv_chunk)

    def step(carry, xs):
        m, l, acc = carry
        kj, vj, pj = xs  # (B, kv_chunk, Hkv, hd), (kv_chunk,)
        kj = repeat_kv(kj, n_rep)
        vj = repeat_kv(vj, n_rep)
        logits = jnp.einsum("bshd,bchd->bshc", q, kj,
                            preferred_element_type=jnp.float32) * scale
        mask = jnp.ones((S, kv_chunk), jnp.bool_)
        if causal:
            mask &= q_pos[:, None] >= pj[None, :]
        if window > 0:
            mask &= q_pos[:, None] - pj[None, :] < window
        logits = jnp.where(mask[None, :, None, :], logits, -jnp.inf)
        m_new = jnp.maximum(m, logits.max(axis=-1))
        # guard fully-masked rows
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(logits - m_safe[..., None])
        p = jnp.where(jnp.isfinite(logits), p, 0.0)
        corr = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
        if _LEAN_INTERNALS[0]:
            # materialize the probability tensor once, in bf16 — the l sum
            # and the pv matmul both read the narrow copy
            p = p.astype(vj.dtype)
        l_new = l * corr + p.astype(jnp.float32).sum(axis=-1)
        pv = jnp.einsum("bshc,bchd->bshd", p.astype(vj.dtype), vj,
                        preferred_element_type=jnp.float32)
        acc_new = acc * corr[..., None] + pv
        return (m_safe, l_new, acc_new), None

    m0 = jnp.full((B, S, H), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, S, H), jnp.float32)
    acc0 = jnp.zeros((B, S, H, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        step, (m0, l0, acc0),
        (jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0), pc))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.astype(q.dtype)


def attention(params, x, *, cfg, positions, kv_cache=None, cache_pos=None,
              cross_kv=None, causal=True, kv_chunk=512):
    """Multi-head attention with GQA, optional SWA window, qk-norm, RoPE.

    Flat-head layout: every assigned arch has n_heads % 16 == 0, so the
    query-head axis shards exactly over the 16-way model axis; KV heads
    shard when divisible and replicate otherwise (Megatron GQA convention).

    params: {wq (d, H, hd), wk (d, Hkv, hd), wv, wo (H, hd, d),
             [q_norm, k_norm (hd,)]}
    modes:
      * train/prefill: kv_cache None -> self attention over x
      * decode: kv_cache = dict(k, v) (B, Smax, Hkv, hd), cache_pos scalar
      * cross:  cross_kv = (k, v) precomputed encoder keys/values
    Returns (out, new_cache).
    """
    B, S, d = x.shape
    H, hd = params["wq"].shape[1:]
    Hkv = params["wk"].shape[1]
    n_rep = H // Hkv
    xq = jnp.einsum("bsd,dnh->bsnh", cast(x), cast(params["wq"]),
                    preferred_element_type=jnp.float32).astype(compute_dtype())
    if cross_kv is None:
        xk = jnp.einsum("bsd,dkh->bskh", cast(x), cast(params["wk"]),
                        preferred_element_type=jnp.float32).astype(compute_dtype())
        xv = jnp.einsum("bsd,dkh->bskh", cast(x), cast(params["wv"]),
                        preferred_element_type=jnp.float32).astype(compute_dtype())
    else:
        xk, xv = cross_kv

    if cfg.qk_norm:
        xq = rms_norm(xq, params["q_norm"], cfg.norm_eps)
        if cross_kv is None:
            xk = rms_norm(xk, params["k_norm"], cfg.norm_eps)

    if cross_kv is None:
        xq = rope(xq, positions, cfg.rope_theta)
        xk = rope(xk, positions, cfg.rope_theta)

    new_cache = None
    if kv_cache is not None:
        # decode (S == 1): append this step's k/v at cache_pos and attend
        # against the whole cache (chunked, so the repeated-KV tensor and
        # the logits stay O(kv_chunk))
        k_all = jax.lax.dynamic_update_slice_in_dim(kv_cache["k"], xk, cache_pos, 1)
        v_all = jax.lax.dynamic_update_slice_in_dim(kv_cache["v"], xv, cache_pos, 1)
        new_cache = {"k": k_all, "v": v_all}
        Smax = k_all.shape[1]
        q_pos = jnp.broadcast_to(cache_pos, (1,))
        kv_pos = jnp.arange(Smax)
        out = _online_softmax_scan(
            xq, k_all, v_all, q_pos, kv_pos,
            causal=True, window=cfg.swa_window, kv_chunk=kv_chunk,
            n_rep=n_rep)
    elif cross_kv is not None:
        out = _online_softmax_scan(
            xq, xk, xv, positions, jnp.arange(xk.shape[1]),
            causal=False, window=0, kv_chunk=kv_chunk, n_rep=n_rep)
    else:
        out = _online_softmax_scan(
            xq, xk, xv, positions, positions,
            causal=causal, window=cfg.swa_window, kv_chunk=kv_chunk,
            n_rep=n_rep)
        # expose post-rope k/v so prefill can write them into a decode cache
        new_cache = {"k": xk, "v": xv}

    proj = jnp.einsum("bsnh,nhd->bsd", cast(out), cast(params["wo"]),
                      preferred_element_type=jnp.float32).astype(x.dtype)
    return proj, new_cache


def attention_params(key, cfg, d=None):
    d = d or cfg.d_model
    H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s = d ** -0.5
    p = {
        "wq": jax.random.normal(k1, (d, H, hd), jnp.float32) * s,
        "wk": jax.random.normal(k2, (d, Hkv, hd), jnp.float32) * s,
        "wv": jax.random.normal(k3, (d, Hkv, hd), jnp.float32) * s,
        "wo": jax.random.normal(k4, (H, hd, d), jnp.float32) * (H * hd) ** -0.5,
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), jnp.float32)
        p["k_norm"] = jnp.ones((hd,), jnp.float32)
    return p


# --------------------------------------------------------------------------
# dense MLP
# --------------------------------------------------------------------------

def swiglu(params, x):
    h = jnp.einsum("bsd,df->bsf", cast(x), cast(params["w_gate"]),
                   preferred_element_type=jnp.float32)
    u = jnp.einsum("bsd,df->bsf", cast(x), cast(params["w_up"]),
                   preferred_element_type=jnp.float32)
    h = jax.nn.silu(h) * u
    return jnp.einsum("bsf,fd->bsd", h.astype(compute_dtype()), cast(params["w_down"]),
                      preferred_element_type=jnp.float32).astype(x.dtype)


def swiglu_params(key, cfg, d=None, f=None):
    d = d or cfg.d_model
    f = f or cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": jax.random.normal(k1, (d, f), jnp.float32) * d ** -0.5,
        "w_up": jax.random.normal(k2, (d, f), jnp.float32) * d ** -0.5,
        "w_down": jax.random.normal(k3, (f, d), jnp.float32) * f ** -0.5,
    }


# --------------------------------------------------------------------------
# mixture of experts — grouped capacity dispatch
# --------------------------------------------------------------------------

# dtype of the MoE combine buffer.  The combine's scatter-add output is the
# all-reduce payload under pjit (one (tokens, d) tensor per layer per pass);
# bf16 halves that wire traffic (§Perf hillclimb).  f32 default.
_MOE_COMBINE_DTYPE = [jnp.float32]


def set_moe_combine_dtype(dtype):
    _MOE_COMBINE_DTYPE[0] = dtype

def moe(params, x, cfg, group_size: int = 4096):
    """Top-k MoE with GShard-style first-come capacity and gather dispatch.

    x: (B, S, d).  Tokens are flattened and regrouped into groups of
    ``group_size`` so the per-expert capacity is group-local (keeps the
    top_k selection and gathers local to a data shard under pjit).
    Returns (out, aux_loss).
    """
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    T = B * S
    Sg = min(group_size, T)
    Gn = T // Sg
    assert T % Sg == 0, (T, Sg)
    xt = x.reshape(Gn, Sg, d)

    logits = jnp.einsum("gsd,de->gse", cast(xt), cast(params["router"]),
                        preferred_element_type=jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_vals, top_idx = jax.lax.top_k(probs, k)            # (G, Sg, k)
    top_vals = top_vals / jnp.maximum(top_vals.sum(-1, keepdims=True), 1e-9)

    # gate (G, Sg, E): normalized prob where selected, else 0
    gate = jnp.zeros((Gn, Sg, E), jnp.float32)
    for i in range(k):
        gate = gate + jax.nn.one_hot(top_idx[..., i], E) * top_vals[..., i:i + 1]
    assigned = gate > 0

    # aux load-balance loss (Switch-style)
    me = assigned.mean(axis=(0, 1))
    pe = probs.mean(axis=(0, 1))
    aux = E * jnp.sum(me * pe)

    cap = max(1, int(Sg * k / E * cfg.moe_capacity_factor))
    cap = min(cap, Sg)
    # first-come keep: rank tokens by arrival within each expert
    pos = jnp.cumsum(assigned.astype(jnp.int32), axis=1) - 1   # (G, Sg, E)
    score = jnp.where(assigned, -pos.astype(jnp.float32), -jnp.inf)
    # top `cap` earliest tokens per (group, expert)
    sel_score, sel_idx = jax.lax.top_k(jnp.swapaxes(score, 1, 2), cap)  # (G, E, cap)
    sel_valid = jnp.isfinite(sel_score)

    # dispatch: gather tokens   xe: (G, E, cap, d)
    xe = jnp.take_along_axis(xt[:, None], sel_idx[..., None], axis=2)
    xe = jnp.where(sel_valid[..., None], xe, 0.0)

    # in lean mode the up-projection outputs accumulate in bf16: they are
    # the all-reduce payloads when the contraction dim is FSDP-sharded
    # (grok: 5.1 TB/step of f32 otherwise — §Perf)
    acc_dt = compute_dtype() if _LEAN_INTERNALS[0] else jnp.float32
    h = jnp.einsum("gecd,edf->gecf", cast(xe), cast(params["w_gate"]),
                   preferred_element_type=acc_dt)
    u = jnp.einsum("gecd,edf->gecf", cast(xe), cast(params["w_up"]),
                   preferred_element_type=acc_dt)
    h = jax.nn.silu(h.astype(jnp.float32)) * u.astype(jnp.float32)
    ye = jnp.einsum("gecf,efd->gecd", h.astype(compute_dtype()), cast(params["w_down"]),
                    preferred_element_type=jnp.float32)     # (G, E, cap, d)

    # combine: weight by gate prob of the token for THIS expert and scatter
    w_tok = jnp.take_along_axis(jnp.swapaxes(gate, 1, 2), sel_idx, axis=2)
    ye = ye * jnp.where(sel_valid, w_tok, 0.0)[..., None]
    cdt = _MOE_COMBINE_DTYPE[0]
    out = jnp.zeros((Gn, Sg, d), cdt)
    out = jax.vmap(
        lambda o, idx, y: o.at[idx.reshape(-1)].add(y.reshape(-1, d)))(
        out, sel_idx, ye.astype(cdt))
    return out.reshape(B, S, d).astype(x.dtype), aux


def moe_params(key, cfg):
    E, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff
    k0, k1, k2, k3 = jax.random.split(key, 4)
    return {
        "router": jax.random.normal(k0, (d, E), jnp.float32) * d ** -0.5,
        "w_gate": jax.random.normal(k1, (E, d, f), jnp.float32) * d ** -0.5,
        "w_up": jax.random.normal(k2, (E, d, f), jnp.float32) * d ** -0.5,
        "w_down": jax.random.normal(k3, (E, f, d), jnp.float32) * f ** -0.5,
    }
