"""Public model API: init / loss / prefill / decode for every family.

``lm_loss`` computes chunked cross-entropy (the (B, S, V) logits tensor is
never fully materialized; V is model-sharded, S is chunked) — required to
fit 150k+ vocabularies at 1M-token global batches.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import transformer as T
from repro.models.layers import compute_dtype, cast

init_params = T.init_params
init_cache = T.init_cache


def _embed(params, cfg, batch):
    """Token ids -> (B, S, d); modality-stub archs feed embeddings directly."""
    if cfg.frontend_stub and "embeds" in batch:
        return batch["embeds"].astype(compute_dtype())
    return params["embed"][batch["tokens"]].astype(compute_dtype())


def _lm_head(params, cfg):
    if cfg.tie_embeddings:
        return params["embed"].T
    return params["lm_head"]


def _xent_chunk(hidden, head, labels, mask):
    """hidden (B, C, d), head (d, V), labels (B, C) -> (sum_loss, sum_mask)."""
    logits = jnp.einsum("bcd,dv->bcv", cast(hidden), cast(head),
                        preferred_element_type=jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    tgt = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = (lse - tgt) * mask
    return nll.sum(), mask.sum()


def lm_loss(params, cfg, batch, *, remat=True, kv_chunk=512,
            loss_chunk=512, aux_weight=0.01, act_spec=None):
    """batch: tokens (B,S) int32, labels (B,S) int32, [loss_mask (B,S)],
    [embeds (B,S,d) for frontend stubs], [enc_in (B,Senc,d) for encdec]."""
    x = _embed(params, cfg, batch)
    B, Seq = x.shape[:2]
    positions = jnp.arange(Seq)

    enc_out = None
    if cfg.family == "encdec":
        enc_out = T.encode(params, cfg, batch["enc_in"].astype(compute_dtype()),
                           remat=remat, kv_chunk=kv_chunk, act_spec=act_spec)

    hidden, _, aux = T.forward(params, cfg, x, positions, enc_out=enc_out,
                               remat=remat, kv_chunk=kv_chunk,
                               act_spec=act_spec)
    hidden = T.rms_norm(hidden, params["final_norm"], cfg.norm_eps)

    head = _lm_head(params, cfg)
    labels = batch["labels"]
    mask = batch.get("loss_mask", jnp.ones(labels.shape, jnp.float32))

    C = min(loss_chunk, Seq)
    nc = Seq // C
    assert Seq % C == 0

    def step(carry, xs):
        h_c, l_c, m_c = xs
        s, n = _xent_chunk(h_c, head, l_c, m_c)
        return (carry[0] + s, carry[1] + n), None

    resh = lambda t: jnp.moveaxis(
        t.reshape((B, nc, C) + t.shape[2:]), 1, 0)
    (tot, cnt), _ = jax.lax.scan(
        step, (jnp.float32(0.0), jnp.float32(0.0)),
        (resh(hidden), resh(labels), resh(mask)))
    loss = tot / jnp.maximum(cnt, 1.0)
    return loss + aux_weight * aux, {"xent": loss, "aux": aux}


def prefill(params, cfg, batch, cache, *, kv_chunk=512, act_spec=None):
    """Fill the decode cache from a prompt; returns (cache, last_logits).

    For attention families the cache k/v are produced by running the stack
    with a cache whose max_seq >= prompt length and cache_pos=0 writes...
    here we instead run the train-style forward and write k/v in one shot.
    """
    x = _embed(params, cfg, batch)
    B, Seq = x.shape[:2]
    positions = jnp.arange(Seq)

    enc_out = None
    if cfg.family == "encdec":
        enc_out = T.encode(params, cfg, batch["enc_in"].astype(compute_dtype()),
                           kv_chunk=kv_chunk, act_spec=act_spec)

    hidden, new_caches, _ = T.forward(
        params, cfg, x, positions, caches=cache, cache_pos=jnp.int32(0),
        enc_out=enc_out, kv_chunk=kv_chunk, act_spec=act_spec)
    hidden = T.rms_norm(hidden[:, -1:], params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bcd,dv->bcv", cast(hidden), cast(_lm_head(params, cfg)),
                        preferred_element_type=jnp.float32)
    return new_caches, logits[:, 0]


def decode_step(params, cfg, token, cache, pos, *, kv_chunk=512, act_spec=None):
    """One decode step: token (B,) int32 (or (B,d) embeds for stubs),
    pos scalar int32.  Returns (logits (B,V), new_cache)."""
    if cfg.frontend_stub and token.ndim == 2:
        x = token[:, None].astype(compute_dtype())
    else:
        x = params["embed"][token][:, None].astype(compute_dtype())
    positions = pos + jnp.arange(1)
    hidden, new_cache, _ = T.forward(params, cfg, x, positions, caches=cache,
                                     cache_pos=pos, kv_chunk=kv_chunk,
                                     act_spec=act_spec)
    hidden = T.rms_norm(hidden, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bcd,dv->bcv", cast(hidden), cast(_lm_head(params, cfg)),
                        preferred_element_type=jnp.float32)
    return logits[:, 0], new_cache
