"""Deterministic synthetic token stream for LM training.

Stateless index-based sampling: batch ``i`` is a pure function of
(seed, i), so restart-after-preemption resumes the stream exactly by
skipping to the checkpointed step — no data-loader state to snapshot
(DESIGN.md §7, fault tolerance).

The stream is a Zipf-ish unigram mixture with a Markov flavour so that a
model can actually reduce loss on it (used by the e2e training example).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class TokenStream:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0

    def batch(self, step: int):
        """Returns {tokens, labels} of shape (global_batch, seq_len)."""
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), step)
        k1, k2 = jax.random.split(key)
        B, S, V = self.global_batch, self.seq_len, self.vocab
        # zipf-ish unigram draws
        u = jax.random.uniform(k1, (B, S + 1), minval=1e-6)
        ranks = jnp.floor((u ** -1.2 - 1.0)).astype(jnp.int32)
        base = jnp.clip(ranks, 0, V - 1)
        # markov flavour: with p=0.5 the next token is prev+1 (mod V)
        coin = jax.random.bernoulli(k2, 0.5, (B, S + 1))
        rolled = jnp.roll(base, 1, axis=1)
        toks = jnp.where(coin, jnp.mod(rolled + 1, V), base)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def host_batch(self, step: int):
        return jax.tree.map(np.asarray, self.batch(step))
