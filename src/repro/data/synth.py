"""Paper §5.1 synthetic data protocol (Table 1).

Generators for the AO benchmarks: sampling distribution (uniform / normal /
bimodal, three parameterizations each), target function (linear / cubic),
and optional noise on 10% of instances.  Deterministic per (seed, config).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

import numpy as np

SAMPLE_SIZES = [50, 100, 200, 400, 500, 750, 1000, 2500, 5000, 7000, 10000,
                15000, 25000, 50000, 75000, 100000, 200000, 500000, 1000000]

DISTRIBUTIONS = {
    # name -> list of parameterizations
    "normal": [(0.0, 1.0), (0.0, 0.1), (0.0, 7.0)],
    "uniform": [(-1.0, 1.0), (-0.1, 0.1), (-7.0, 7.0)],
    "bimodal": [((-1.0, 1.0), (1.0, 1.0)),
                ((-0.1, 0.1), (0.1, 0.1)),
                ((-7.0, 7.0), (7.0, 0.1))],   # asymmetric third variant
}

TASKS = ("lin", "cub")


@dataclass(frozen=True)
class SynthConfig:
    dist: str = "normal"     # normal | uniform | bimodal
    variant: int = 0         # parameterization index (0..2)
    task: str = "lin"        # lin | cub
    noise_frac: float = 0.0  # 0.0 or 0.1 (paper)
    n: int = 10000
    seed: int = 0


def sample_x(cfg: SynthConfig, rng: np.random.Generator) -> np.ndarray:
    p = DISTRIBUTIONS[cfg.dist][cfg.variant]
    if cfg.dist == "normal":
        return rng.normal(p[0], p[1], cfg.n).astype(np.float32)
    if cfg.dist == "uniform":
        return rng.uniform(p[0], p[1], cfg.n).astype(np.float32)
    # bimodal: equal-probability mixture of two normals
    (m1, s1), (m2, s2) = p
    pick = rng.random(cfg.n) < 0.5
    a = rng.normal(m1, s1, cfg.n)
    b = rng.normal(m2, s2, cfg.n)
    return np.where(pick, a, b).astype(np.float32)


def generate(cfg: SynthConfig) -> Tuple[np.ndarray, np.ndarray]:
    """Returns (x, y) float32 arrays of length cfg.n."""
    rng = np.random.default_rng(cfg.seed)
    x = sample_x(cfg, rng)
    # random target coefficients, re-drawn per seed (paper: 10 repetitions
    # varying the random initialization)
    if cfg.task == "lin":
        a, b = rng.normal(0, 1, 2)
        y = a * x + b
    elif cfg.task == "cub":
        a, b, c, d = rng.normal(0, 1, 4)
        y = a * x ** 3 + b * x ** 2 + c * x + d
    else:
        raise ValueError(cfg.task)
    if cfg.noise_frac > 0:
        # paper: sigma matched to the dispersion of the generating dist
        scale = 0.01 if cfg.variant == 1 else 0.1
        mask = rng.random(cfg.n) < cfg.noise_frac
        y = y + mask * rng.normal(0, scale, cfg.n)
    return x.astype(np.float32), y.astype(np.float32)


def piecewise_target(X: np.ndarray, shift=0.0) -> np.ndarray:
    """The shared piecewise-constant tree target; ``shift`` moves the root
    split point (the concept-drift knob used by the forest benchmark and
    the streaming examples — ONE definition so they stay in lockstep)."""
    F = X.shape[1]
    return np.where(X[:, 0] <= shift,
                    np.where(X[:, 1 % F] <= 0.5, 1.0, 5.0),
                    np.where(X[:, 2 % F] <= -0.2, 9.0, 13.0))


def piecewise_regression(n: int, n_features: int = 4, seed: int = 0,
                         noise: float = 0.1):
    """Multivariate piecewise-constant target for tree e2e tests."""
    rng = np.random.default_rng(seed)
    X = rng.normal(0, 1, (n, n_features)).astype(np.float32)
    y = (piecewise_target(X) + noise * rng.normal(0, 1, n)).astype(np.float32)
    return X, y
