"""train_step / serve_step builders with explicit shardings.

``build_train_step`` returns a jitted function

    (params, opt_state, batch, monitor) -> (params, opt_state, metrics, monitor)

with in/out shardings derived from :mod:`repro.train.sharding`, donated
params/opt buffers, optional microbatch gradient accumulation (lax.scan so
weights stay resident and grads reduce once), and QO telemetry folded in.

``build_serve_steps`` returns (prefill_fn, decode_fn) for serving shapes.

All builders also return the lowered-input ShapeDtypeStructs so the
dry-run can ``.lower().compile()`` without touching real data.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models import model as M
from repro.models.layers import compute_dtype
from repro.optim import adamw
from repro.train import sharding as SH
from repro.train import monitor as MON


def _ensure_sharding_invariant_rng():
    """Initializing params under different meshes must produce identical
    weights; older jax defaults partitionable threefry off, making the same
    PRNGKey yield different bits per out_sharding.  Set when a sharded step
    is built rather than at import (global config mutation stays tied to an
    explicit API call)."""
    jax.config.update("jax_threefry_partitionable", True)


def input_specs(cfg, shape, *, abstract=True):
    """ShapeDtypeStruct stand-ins for every model input of a shape config.

    For train: {tokens, labels}; encdec adds enc_in; vlm adds loss_mask.
    For decode: (token, pos); prefill like train without labels.
    """
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    f32 = compute_dtype()
    out: Dict[str, Any] = {}
    if shape.kind in ("train", "prefill"):
        out["tokens"] = jax.ShapeDtypeStruct((B, S), i32)
        if shape.kind == "train":
            out["labels"] = jax.ShapeDtypeStruct((B, S), i32)
            if cfg.family == "vlm":
                # early fusion: image-token positions are masked from the loss
                out["loss_mask"] = jax.ShapeDtypeStruct((B, S), jnp.float32)
        if cfg.family == "encdec":
            out["enc_in"] = jax.ShapeDtypeStruct((B, cfg.enc_seq, cfg.d_model), f32)
    else:  # decode
        out["token"] = jax.ShapeDtypeStruct((B,), i32)
    return out


def abstract_params(cfg):
    return jax.eval_shape(
        lambda k: M.init_params(k, cfg), jax.random.PRNGKey(0))


def abstract_state(cfg, opt_cfg: adamw.AdamWConfig):
    pshapes = abstract_params(cfg)
    oshapes = jax.eval_shape(adamw.init_state, pshapes)
    return pshapes, oshapes


def build_train_step(cfg, shape, mesh, opt_cfg=None, *, microbatch: int = 0,
                     remat=True, kv_chunk=512, with_monitor=True,
                     donate=True, seq_parallel=False,
                     sharding_style="contraction"):
    """Returns (step_fn, in_shardings, out_shardings, arg_shapes).

    Side effect: enables ``jax_threefry_partitionable`` process-wide so
    param init under any mesh yields identical weights (see
    :func:`_ensure_sharding_invariant_rng`) — jax.random bits drawn after
    the first builder call differ from a process that never built a step.

    seq_parallel: pin the residual stream sequence-sharded over the model
    axis (Megatron-SP).  Row-parallel all-reduces of (tokens, d) outputs
    become reduce-scatter + all-gather pairs — ~TP-fold fewer collective
    bytes on the residual (§Perf hillclimb)."""
    _ensure_sharding_invariant_rng()
    opt_cfg = opt_cfg or adamw.AdamWConfig()
    pshapes = abstract_params(cfg)
    pspecs = SH.param_specs(cfg, pshapes, mesh, style=sharding_style)
    ospecs = SH.opt_specs(pspecs)
    bfield = SH.batch_specs(cfg, shape.kind, shape.global_batch, mesh)
    batch_shapes = input_specs(cfg, shape)
    bspecs = {k: bfield(k) for k in batch_shapes}
    mon_specs = MON.monitor_specs() if with_monitor else None
    fsdp, tp = SH.mesh_axes(mesh)
    seq_ax = tp if (seq_parallel and shape.seq_len % mesh.shape[tp] == 0) else None
    act_spec = P(fsdp, seq_ax, None)  # (batch, seq, d) residual pin

    def loss_fn(params, batch):
        loss, metrics = M.lm_loss(params, cfg, batch, remat=remat,
                                  kv_chunk=kv_chunk, act_spec=act_spec)
        return loss, metrics

    def step(params, opt_state, batch, monitor):
        if microbatch and microbatch > 1:
            nm = microbatch
            B = batch["tokens"].shape[0]
            assert B % nm == 0

            def mb(carry, mbatch):
                gacc, lacc = carry
                (l, _), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mbatch)
                return (jax.tree.map(jnp.add, gacc, g), lacc + l), None

            resh = jax.tree.map(
                lambda t: jnp.moveaxis(
                    t.reshape((nm, B // nm) + t.shape[1:]), 0, 0), batch)
            zero_g = jax.tree.map(jnp.zeros_like, params)
            (grads, loss), _ = jax.lax.scan(mb, (zero_g, jnp.float32(0.0)), resh)
            grads = jax.tree.map(lambda g: g / nm, grads)
            loss = loss / nm
            metrics = {"xent": loss, "aux": jnp.float32(0.0)}
        else:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)

        params2, opt_state2, opt_metrics = adamw.apply(
            opt_cfg, params, opt_state, grads)
        # NaN-step skip: keep old params if the update is not finite
        finite = jnp.isfinite(loss) & jnp.isfinite(opt_metrics["grad_norm"])
        params2 = jax.tree.map(
            lambda new, old: jnp.where(finite, new, old), params2, params)
        opt_state2 = jax.tree.map(
            lambda new, old: jnp.where(finite, new, old), opt_state2, opt_state)

        metrics = dict(metrics, **opt_metrics, loss=loss,
                       skipped=(~finite).astype(jnp.float32))
        if monitor is not None:
            monitor = MON.observe(monitor, loss=loss,
                                  grad_norm=opt_metrics["grad_norm"])
        return params2, opt_state2, metrics, monitor

    in_sh = (SH.to_shardings(mesh, pspecs), SH.to_shardings(mesh, ospecs),
             SH.to_shardings(mesh, bspecs),
             SH.to_shardings(mesh, mon_specs) if with_monitor else None)
    out_sh = (in_sh[0], in_sh[1], None, in_sh[3])
    donate_args = (0, 1) if donate else ()
    fn = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh,
                 donate_argnums=donate_args)
    oshapes = jax.eval_shape(adamw.init_state, pshapes)
    mshape = jax.eval_shape(MON.init_monitor) if with_monitor else None
    return fn, in_sh, out_sh, (pshapes, oshapes, batch_shapes, mshape)


def build_serve_steps(cfg, shape, mesh, *, kv_chunk=512):
    """Returns (prefill_fn, decode_fn, shapes) with explicit shardings.

    decode shapes lower ``serve_step`` = one token against a seq_len cache.
    """
    _ensure_sharding_invariant_rng()
    pshapes = abstract_params(cfg)
    pspecs = SH.param_specs(cfg, pshapes, mesh)
    B, S = shape.global_batch, shape.seq_len
    cache_shapes = jax.eval_shape(lambda: M.init_cache(cfg, B, S))
    cspecs = SH.cache_specs(cfg, B, mesh, cache_shapes)
    bfield = SH.batch_specs(cfg, shape.kind, B, mesh)

    p_sh = SH.to_shardings(mesh, pspecs)
    c_sh = SH.to_shardings(mesh, cspecs)
    fsdp, _ = SH.mesh_axes(mesh)
    fsdp_n = 1
    for a in fsdp:
        fsdp_n *= mesh.shape[a]
    bshard = fsdp if B % fsdp_n == 0 else None
    act_spec = P(bshard, None, None)

    # ---- prefill over the full prompt ----
    prefill_shapes = input_specs(
        cfg, type(shape)(shape.name, S, B, "prefill"))
    pf_bspecs = {k: bfield(k) if k != "enc_in" else P(None, None, None)
                 for k in prefill_shapes}
    pf_bspecs = {k: bfield(k) for k in prefill_shapes}

    def prefill_fn(params, batch, cache):
        return M.prefill(params, cfg, batch, cache, kv_chunk=kv_chunk,
                         act_spec=act_spec)

    prefill_jit = jax.jit(
        prefill_fn,
        in_shardings=(p_sh, SH.to_shardings(mesh, pf_bspecs), c_sh),
        out_shardings=(c_sh, None),
        donate_argnums=(2,))

    # ---- single-token decode ----
    def decode_fn(params, token, cache, pos):
        return M.decode_step(params, cfg, token, cache, pos,
                             kv_chunk=kv_chunk, act_spec=act_spec)

    # token sharding left to the partitioner (it follows the cache batch
    # axis); pinning it would reject host-produced argmax tokens in tests
    decode_jit = jax.jit(
        decode_fn,
        in_shardings=(p_sh, None, c_sh, None),
        out_shardings=(None, c_sh),
        donate_argnums=(2,))

    decode_shapes = {
        "token": jax.ShapeDtypeStruct((B,), jnp.int32),
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
    }
    return prefill_jit, decode_jit, (pshapes, cache_shapes,
                                     prefill_shapes, decode_shapes)
