"""Fault-tolerant training driver.

Features (DESIGN.md §7):
  * auto-resume from the latest checkpoint (atomic LATEST pointer);
  * periodic async checkpointing (serialization overlaps training);
  * preemption handling: SIGTERM/SIGINT triggers a final blocking save;
  * deterministic data skip-ahead (stateless stream indexed by step);
  * NaN-step skipping inside the jitted step (see steps.py);
  * straggler + loss-spike detection via the QO step-time/loss sketches —
    the paper's observer watching the trainer itself;
  * elastic restart: if the mesh changed between runs, restored leaves are
    re-placed via checkpoint.reshard onto the new sharding tree.
"""
from __future__ import annotations

import signal
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.ckpt import Checkpointer, reshard
from repro.models import model as M
from repro.optim import adamw
from repro.train import monitor as MON
from repro.train import steps as ST


@dataclass
class LoopConfig:
    total_steps: int = 200
    ckpt_every: int = 50
    log_every: int = 10
    ckpt_dir: str = "/tmp/repro_ckpt"
    microbatch: int = 0
    remat: bool = True
    kv_chunk: int = 512
    seed: int = 0


class Trainer:
    def __init__(self, cfg, shape, mesh, data, loop_cfg: LoopConfig,
                 opt_cfg: Optional[adamw.AdamWConfig] = None):
        self.cfg, self.shape, self.mesh = cfg, shape, mesh
        self.data = data
        self.lc = loop_cfg
        self.opt_cfg = opt_cfg or adamw.AdamWConfig(
            total_steps=loop_cfg.total_steps)
        self.ckpt = Checkpointer(loop_cfg.ckpt_dir)
        self._preempted = False
        (self.step_fn, self.in_sh, _, shapes) = ST.build_train_step(
            cfg, shape, mesh, self.opt_cfg, microbatch=loop_cfg.microbatch,
            remat=loop_cfg.remat, kv_chunk=loop_cfg.kv_chunk)
        self.pshapes, self.oshapes, self.bshapes, self.mshape = shapes

    # -- state ------------------------------------------------------------

    def init_or_restore(self):
        start = self.ckpt.latest_step()
        if start is not None:
            host = self.ckpt.restore(
                start, {"params": self.pshapes, "opt": self.oshapes})
            params = reshard(host["params"], self.in_sh[0])
            opt = reshard(host["opt"], self.in_sh[1])
            mon = MON.init_monitor()
            return params, opt, mon, start
        with self.mesh:
            params = jax.jit(
                lambda k: M.init_params(k, self.cfg),
                out_shardings=self.in_sh[0])(jax.random.PRNGKey(self.lc.seed))
            opt = jax.jit(adamw.init_state,
                          out_shardings=self.in_sh[1])(params)
        return params, opt, MON.init_monitor(), 0

    # -- preemption -------------------------------------------------------

    def _install_signals(self):
        def handler(sig, frame):
            self._preempted = True
        for s in (signal.SIGTERM, signal.SIGINT):
            try:
                signal.signal(s, handler)
            except ValueError:
                pass  # not on main thread (tests)

    # -- run --------------------------------------------------------------

    def run(self, log_fn: Callable[[Dict[str, Any]], None] = print,
            publish_fn: Optional[Callable[[int, Any], None]] = None):
        """Train to ``total_steps``; ``publish_fn(step, params)`` is the
        LM loop's **publish boundary** (DESIGN.md §5.6) — fired right
        after every checkpoint save (periodic, preemption and final),
        the same train→serve handoff cadence the streaming engine's
        ``freeze``+publish follows, so a serving frontend can hot-swap
        the newest params without ever touching the training thread's
        state mid-step.  Exceptions out of ``publish_fn`` are deliberately
        NOT caught here: the publisher owns its own degradation."""
        self._install_signals()
        params, opt, mon, start = self.init_or_restore()
        history = []
        with self.mesh:
            for step in range(start, self.lc.total_steps):
                batch = self.data.batch(step)  # deterministic skip-ahead
                t0 = time.perf_counter()
                params, opt, metrics, mon = self.step_fn(params, opt, batch, mon)
                jax.block_until_ready(metrics["loss"])
                dt = time.perf_counter() - t0
                mon = MON.observe(mon, step_time=jnp.float32(dt))

                if step % self.lc.log_every == 0 or step == self.lc.total_steps - 1:
                    rec = {
                        "step": step,
                        "loss": float(metrics["loss"]),
                        "grad_norm": float(metrics["grad_norm"]),
                        "lr": float(metrics["lr"]),
                        "skipped": float(metrics["skipped"]),
                        "sec_per_step": dt,
                        "straggler": bool(MON.is_straggler(mon, jnp.float32(dt))),
                        "loss_spike": bool(MON.loss_spike(mon, metrics["loss"])),
                    }
                    history.append(rec)
                    log_fn(rec)

                if (step + 1) % self.lc.ckpt_every == 0:
                    self.ckpt.save(step + 1, {"params": params, "opt": opt})
                    if publish_fn is not None:
                        publish_fn(step + 1, params)

                if self._preempted:
                    log_fn({"step": step, "event": "preempted — final save"})
                    self.ckpt.save(step + 1, {"params": params, "opt": opt},
                                   blocking=True)
                    if publish_fn is not None:
                        publish_fn(step + 1, params)
                    return params, opt, mon, history
            self.ckpt.save(self.lc.total_steps, {"params": params, "opt": opt},
                           blocking=True)
            if publish_fn is not None:
                publish_fn(self.lc.total_steps, params)
        return params, opt, mon, history
