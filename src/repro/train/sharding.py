"""PartitionSpec rules for params, optimizer state, batches, caches — and
the tree-axis sharding of the QO Hoeffding forest (DESIGN.md §5).

Strategy (DESIGN.md §7): TP over the 16-way "model" axis + FSDP over the
data axes ("pod","data") — required for grok-1-314b, whose optimizer state
would otherwise need 235 GB/chip.  Rules are name+shape based over the
param pytree; every rule falls back to replication when a dimension does
not divide the mesh axis (e.g. whisper's 51865 vocab, 8-way KV heads).

Logical mapping:
  d_model / d_inner rows  ->  fsdp axes      (all-gathered for the matmul)
  heads / d_ff / vocab    ->  "model" (TP)
  experts                 ->  "model" when E % tp == 0 (EP), else d_ff TP
  batch                   ->  fsdp axes
  decode KV cache         ->  batch over fsdp; heads over model when
                              divisible, else sequence over model
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def mesh_axes(mesh: Mesh) -> Tuple[Tuple[str, ...], str]:
    """Returns (fsdp_axes, tp_axis)."""
    names = mesh.axis_names
    tp = "model"
    fsdp = tuple(n for n in names if n != tp)
    return fsdp, tp


def _div(n: int, size: int) -> bool:
    return n > 0 and n % size == 0


def param_specs(cfg, params_shapes, mesh: Mesh, style: str = "contraction"):
    """Pytree of PartitionSpec matching the params pytree.

    ``params_shapes``: pytree of ShapeDtypeStruct (from jax.eval_shape).

    style:
      "contraction" (baseline): FSDP shards the contraction (d_model) dim of
        weights.  XLA then often SPLITS the contraction instead of gathering
        the weight, all-reducing full activation tensors over the data axes
        — measured catastrophic for MoE (§Perf: grok 7.8 TB/step).
      "gather": FSDP co-shards the weight's OUTPUT dim with TP
        (2D sharding).  The output dim cannot be data-sharded twice (tokens
        already are), so the partitioner must ALL-GATHER the weight shards —
        the ZeRO-3 pattern: collective bytes scale with weights, not
        activations.
    """
    fsdp, tp = mesh_axes(mesh)
    tp_n = mesh.shape[tp]
    fsdp_n = 1
    for a in fsdp:
        fsdp_n *= mesh.shape[a]
    d = cfg.d_model
    gather = style == "gather"

    def fs(dim):  # fsdp-shard a dimension if it divides
        return fsdp if _div(dim, fsdp_n) else None

    def tps(dim):
        return tp if _div(dim, tp_n) else None

    def tp_fs(dim):
        """2D shard over (tp, fsdp...) when divisible, else best effort."""
        if _div(dim, tp_n * fsdp_n):
            return (tp,) + fsdp
        return tps(dim)

    def rule(path, leaf):
        keys = [str(getattr(p, "key", getattr(p, "idx", p))) for p in path]
        name = keys[-1] if keys else ""
        shp = leaf.shape
        nd = len(shp)
        # strip the stacked-layer leading axis for rule matching
        core = shp[1:] if (keys and keys[0] in ("layers", "enc_layers")
                           and nd >= 1) else shp

        def spec(*core_spec):
            pad = (None,) * (nd - len(core_spec))
            return P(*pad, *core_spec)

        if name == "embed":
            if _div(shp[0], tp_n):
                return P(tp, fs(shp[1]))
            return P(None, tps(shp[1]))
        if name == "lm_head":
            if gather:
                return P(None, tp_fs(shp[1]))
            return P(fs(shp[0]), tps(shp[1]))
        if name in ("wq", "wo"):
            # (d, H, hd) / (H, hd, d): heads over TP
            if name == "wq":
                if gather:  # output dims (H, hd) 2D-sharded -> weight gather
                    return spec(None, tps(core[1]), fs(core[2]))
                return spec(fs(core[0]), tps(core[1]), None)
            if gather:
                return spec(tps(core[0]), None, fs(core[2]))
            return spec(tps(core[0]), None, fs(core[2]))
        if name in ("wk", "wv"):
            if gather:
                return spec(None, tps(core[1]), fs(core[2]))
            return spec(fs(core[0]), tps(core[1]), None)
        if name in ("w_gate", "w_up", "w_down", "router"):
            if len(core) == 3:  # MoE (E, d, f) / (E, f, d)
                E = core[0]
                if gather:
                    # contraction dim NEVER data-sharded; FSDP rides the
                    # output dim (core[2]) -> partitioner gathers weights
                    if _div(E, tp_n):  # EP: experts over tp
                        return spec(tp, None, fs(core[2]))
                    if name == "w_down":  # (E, f, d): f row-parallel
                        return spec(None, tps(core[1]), fs(core[2]))
                    return spec(None, None, tp_fs(core[2]))  # (E, d, f)
                if _div(E, tp_n):  # EP
                    return spec(tp, fs(core[1]) if name != "w_down" else None,
                                None)
                if name == "w_down":
                    return spec(None, tps(core[1]), fs(core[2]))
                return spec(None, fs(core[1]), tps(core[2]))
            if name == "router":
                return spec(fs(core[0]) if not gather else None, None)
            if name == "w_down":
                return spec(tps(core[0]), fs(core[1]))
            if gather:
                return spec(None, tp_fs(core[1]))
            return spec(fs(core[0]), tps(core[1]))
        if name in ("in_proj",):  # mamba1 (d, 2di)
            if gather:
                return spec(None, tp_fs(core[1]))
            return spec(fs(core[0]), tps(core[1]))
        if name in ("in_z", "in_x"):
            if gather:
                return spec(None, tp_fs(core[1]))
            return spec(fs(core[0]), tps(core[1]))
        if name in ("in_B", "in_C", "in_dt", "x_proj"):
            return spec(None if gather else fs(core[0]), None)
        if name == "dt_proj":  # (dt_rank, di)
            return spec(None, tps(core[1]))
        if name == "out_proj":  # (di, d)
            return spec(tps(core[0]), fs(core[1]))
        if name in ("A_log", "D", "dt_bias") and len(core) >= 1:
            return spec(*([tps(core[0])] + [None] * (len(core) - 1)))
        if name in ("conv_w", "conv_x"):
            return spec(None, tps(core[1]))
        if name in ("conv_B", "conv_C"):
            return spec(None, None)
        if name == "norm_scale":
            return spec(tps(core[0]))
        # norms, biases, small tables: replicate
        return P(*([None] * nd))

    flat, tdef = jax.tree_util.tree_flatten_with_path(params_shapes)
    return jax.tree_util.tree_unflatten(tdef, [rule(p, l) for p, l in flat])


def batch_specs(cfg, shape_kind: str, global_batch: int, mesh: Mesh):
    """PartitionSpec for data batches by field name."""
    fsdp, tp = mesh_axes(mesh)
    fsdp_n = 1
    for a in fsdp:
        fsdp_n *= mesh.shape[a]
    bspec = fsdp if _div(global_batch, fsdp_n) else None

    def field(name):
        if name in ("tokens", "labels", "loss_mask"):
            return P(bspec, None)
        if name == "embeds":
            return P(bspec, None, None)
        if name == "enc_in":
            return P(bspec, None, None)
        if name == "token":     # decode: (B,) or (B, d)
            return P(bspec)
        raise KeyError(name)

    return field


def cache_specs(cfg, batch: int, mesh: Mesh, cache_shapes):
    """Specs for the decode-cache pytree (stacked layer leading axis)."""
    fsdp, tp = mesh_axes(mesh)
    tp_n = mesh.shape[tp]
    fsdp_n = 1
    for a in fsdp:
        fsdp_n *= mesh.shape[a]
    bspec = fsdp if _div(batch, fsdp_n) else None

    def rule(path, leaf):
        keys = [str(getattr(p, "key", getattr(p, "idx", p))) for p in path]
        name = keys[-1]
        shp = leaf.shape
        if name in ("k", "v"):
            # (L, B, S, Hkv, hd): heads over TP if divisible, else seq
            if _div(shp[3], tp_n):
                return P(None, bspec, None, tp, None)
            if _div(shp[2], tp_n):
                return P(None, bspec, tp, None, None)
            return P(None, bspec, None, None, None)
        if name == "pos":
            return P(*([None] * len(shp)))
        if name == "ssm":
            # mamba1 (L,B,di,N): di over TP; mamba2 (L,B,nh,hd,N): nh over TP
            if len(shp) == 4:
                return P(None, bspec, tp if _div(shp[2], tp_n) else None, None)
            return P(None, bspec, tp if _div(shp[2], tp_n) else None, None, None)
        if name == "conv" or (len(keys) >= 2 and keys[-2] == "conv"):
            ch = shp[-1]
            return P(*([None, bspec, None] + [tp if _div(ch, tp_n) else None]))
        if name in ("cross_k", "cross_v"):
            if _div(shp[3], tp_n):
                return P(None, bspec, None, tp, None)
            return P(None, bspec, None, None, None)
        return P(*([None] * len(shp)))

    flat, tdef = jax.tree_util.tree_flatten_with_path(cache_shapes)
    return jax.tree_util.tree_unflatten(tdef, [rule(p, l) for p, l in flat])


def opt_specs(pspecs):
    """Optimizer state shards exactly like params (m, v) + scalar step."""
    return {"m": pspecs, "v": pspecs, "step": P()}


# --------------------------------------------------------------------------
# Hoeffding-forest tree-axis sharding (DESIGN.md §5)
# --------------------------------------------------------------------------

def forest_state_specs(state, axis="data"):
    """PartitionSpec pytree sharding the forest over its tree axis.

    Every leaf of a :mod:`repro.core.forest` state carries the tree axis
    first (the module's layout invariant), so the rule is uniform:
    ``P(axis, None, ...)`` — new per-leaf state rides along automatically
    (e.g. the §2.5 ``seen_since_attempt`` grace counters shard as
    ``P(axis, None)`` like every other (T, M) member field, keeping the
    attempt mask — and therefore the compacted split query's K bucket —
    a purely shard-local decision).  ``state`` may be a real pytree or
    the ``jax.eval_shape`` abstraction of one.
    """
    return jax.tree.map(
        lambda a: P(axis, *([None] * (a.ndim - 1))), state)


def build_sharded_forest(fcfg, mesh: Mesh, axis: str = "data"):
    """jit'd ``(update_fn, predict_fn)`` with T trees spread over ``axis``.

    ``update_fn(state, X, y) -> (state, aux)`` and
    ``predict_fn(state, X) -> (B,)`` are ``shard_map`` wrappers around
    :func:`repro.core.forest.update` / ``predict``: each device owns
    ``T / mesh.shape[axis]`` member trees (T must divide) and runs the
    identical vmapped member program on its shard; the ONLY cross-device
    traffic is the two-scalar psum pair of the prediction vote reduce
    (``axis_name=axis`` inside the mapped body).  Batches are replicated —
    every member sees the whole stream, exactly like the single-host
    forest, so sharded and unsharded training produce identical forests
    while no drift swap fires (tests pin this).  The one intentional
    divergence: the worst-signalling-member swap is resolved per SHARD,
    so under simultaneous drift a D-way sharded forest may reset up to D
    members per batch where the single-host forest resets one.
    """
    from jax.experimental.shard_map import shard_map

    from repro.core import forest as fr

    assert fcfg.n_trees % mesh.shape[axis] == 0, \
        (fcfg.n_trees, mesh.shape[axis])
    abstract = jax.eval_shape(
        lambda: fr.init_forest(fcfg, jax.random.PRNGKey(0)))
    sspec = forest_state_specs(abstract, axis)
    aux_spec = {"member_mse": P(axis), "forest_mse": P(),
                "drift": P(axis)}

    # check_rep=False: the member update routes with fori_loop (lowered
    # to `while`, which has no replication rule in this jax); the P()
    # outputs are replicated by construction (psum)
    upd = shard_map(
        lambda s, X, y: fr.update(fcfg, s, X, y, axis_name=axis),
        mesh=mesh, in_specs=(sspec, P(None, None), P(None)),
        out_specs=(sspec, aux_spec), check_rep=False)
    prd = shard_map(
        lambda s, X: fr.predict(fcfg, s, X, axis_name=axis),
        mesh=mesh, in_specs=(sspec, P(None, None)), out_specs=P(None),
        check_rep=False)
    return jax.jit(upd), jax.jit(prd)


def build_sharded_serving(snap, mesh: Mesh, axis: str = "data"):
    """jit'd ``predict_fn(snap, X) -> (B,)`` with X split over ``axis``.

    The read-side complement of :func:`build_sharded_forest`: training
    shards the TREE axis (every device owns T/D members and sees the
    whole batch); serving shards the BATCH axis (every device owns B/D
    request rows and sees the whole — replicated — snapshot, which the
    §5.5 realized trim keeps small).  Each device runs the identical
    fused routing sweep on its rows; there are NO collectives at all —
    the per-row vote reduces over the local (replicated) tree axis.
    B must divide the mesh axis.  ``snap``: a
    :class:`repro.core.serve.Snapshot` (passed per call, so a refreshed
    snapshot of the SAME model needs no recompile while shapes keep
    their bucket; the ply budget is baked in at build, so a refreshed
    snapshot that grew DEEPER than the build-time ply bucket is rejected
    loudly — rebuild then — rather than silently under-routed).
    """
    from functools import partial

    from jax.experimental.shard_map import shard_map

    from repro.core import serve as sv

    plies = sv.kops.depth_bucket(snap.depth)
    body = partial(sv._predict_impl, plies=plies,
                   backend=sv.kops.resolve_backend(None), single=snap.single)
    arrays = (snap.feature, snap.threshold, snap.child, snap.is_leaf,
              snap.leaf_mean, snap.vote_w)
    # the snapshot ships as its six array leaves, NOT as the Snapshot
    # pytree: its (depth, single) aux rides in the treedef, and baking it
    # into in_specs would reject every refreshed snapshot whose realized
    # depth merely CHANGED (shallower included) with a treedef mismatch
    # instead of serving it
    specs = tuple(P(*([None] * a.ndim)) for a in arrays)
    # check_rep off: the routing sweep's gathers have no replication rule
    fn = jax.jit(shard_map(
        body, mesh=mesh, in_specs=specs + (P(axis, None),),
        out_specs=P(axis), check_rep=False))

    def predict_fn(s, X):
        if s.single != snap.single or s.depth > plies:
            raise ValueError(
                f"snapshot (single={s.single}, depth={s.depth}) does not "
                f"fit this serving build (single={snap.single}, ply "
                f"budget {plies}): rebuild build_sharded_serving")
        return fn(s.feature, s.threshold, s.child, s.is_leaf, s.leaf_mean,
                  s.vote_w, X)

    return predict_fn


def to_shardings(mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


# --------------------------------------------------------------------------
# Batch-axis (data-parallel) stream scale-out: cross-shard QO merge training
# (DESIGN.md §4.1) — the write-side complement of build_sharded_serving
# --------------------------------------------------------------------------

def _dp_init_delta(fcfg, n_shards: int):
    """Zeroed shard-local accumulator pytree, every leaf (D, ...)-leading.

    ``ystats``: per-(tree, leaf) target Stats absorbed since the last
    sync (its ``n`` is also the grace mass); ``ao_y``/``ao_sum_x``: the
    QO bin deltas; ``err``: per-member prequential squared-error Stats.
    All start at the merge identity (n = 0), so a sync after zero local
    steps is a no-op.
    """
    from repro.core import stats

    t = fcfg.tree
    D, T, M, F = n_shards, fcfg.n_trees, t.max_nodes, t.n_features
    C = t.observer_bins()
    return {
        "ystats": stats.init((D, T, M)),
        "ao_y": stats.init((D, T, M, F, C)),
        "ao_sum_x": jnp.zeros((D, T, M, F, C), jnp.float32),
        "err": stats.init((D, T)),
    }


def init_data_parallel(fcfg, key, n_shards: int):
    """Fresh data-parallel trainer state (host-layout; placement is the
    builders' job).

    ``forest``: a replicated :func:`repro.core.forest.init_forest` state
    — the shared tree topology, quantization grids and merged
    statistics every shard routes against;
    ``delta``: the shard-local accumulators (:func:`_dp_init_delta`);
    ``keys``: (D, T, 2) u32 per-(shard, member) bagging PRNG keys —
    Poisson draws stay independent across shards AND members;
    ``step``: python int batch counter driving the sync cadence.
    """
    from repro.core import forest as fr

    kf, kd = jax.random.split(key)
    return {
        "forest": fr.init_forest(fcfg, kf),
        "delta": _dp_init_delta(fcfg, n_shards),
        "keys": jax.random.split(kd, n_shards * fcfg.n_trees).reshape(
            n_shards, fcfg.n_trees, 2),
        "step": 0,
    }


def _dp_local_shard(fcfg, forest, delta, keys, X, y):
    """ONE shard's local step: route/absorb into the delta, NO attempts.

    The monitor half of the §4.1 protocol, per device: draw Poisson
    bagging weights from the shard's member keys, route the local rows
    through the REPLICATED trees, accumulate prequential member errors
    (test-then-train) and the batch's leaf/bin statistics into the
    shard-local delta.  The forest itself — topology, quantization
    grids, merged stats — is read-only here, which is what keeps the
    shards' deltas mergeable (identical bins) and the attempt stage a
    sync-boundary-only, globally-identical decision.

    delta/keys: this shard's slices (no leading D axis).
    Returns ``(delta', keys')``.
    """
    from repro.core import forest as fr
    from repro.core import stats

    trees = forest["trees"]
    B = y.shape[0]
    split = jax.vmap(functools.partial(jax.random.split, num=2))(keys)
    keys2, wkeys = split[:, 0], split[:, 1]
    cdf = jnp.asarray(fr._poisson_cdf(fcfg.lam), jnp.float32)
    w = jax.vmap(lambda k: fr._poisson_weights(k, cdf, (B,)))(wkeys)  # (T, B)

    gl, leaf, batch_leaf = fr._fused_route_stats(fcfg, trees, X, y, w)
    # prequential member errors on the raw local rows, pre-absorb
    yhat = jnp.take_along_axis(trees["ystats"]["mean"], leaf, axis=1)
    err = stats.from_batch((yhat - y[None, :]) ** 2, axis=1)      # (T,)

    ao_y, ao_sum_x = fr._fused_absorb_tables(
        fcfg, delta["ao_y"], delta["ao_sum_x"], trees, gl, X, y, w)
    return {
        "ystats": stats.merge(delta["ystats"], batch_leaf),
        "ao_y": ao_y,
        "ao_sum_x": ao_sum_x,
        "err": stats.merge(delta["err"], err),
    }, keys2


def _dp_local_window(fcfg, forest, delta, keys, Xw, yw):
    """Scan a whole sync window of local steps in ONE dispatch.

    Xw: (S, B_local, F); yw: (S, B_local) — S consecutive local batches
    folded into the shard delta with no host round-trip in between (the
    deployment shape of §4.1: between sync boundaries a shard is fully
    autonomous).  Same per-step body as :func:`_dp_local_shard`, so the
    scanned window is bit-identical to S single-step calls.
    """
    def body(carry, xy):
        d, k = _dp_local_shard(fcfg, forest, carry[0], carry[1],
                               xy[0], xy[1])
        return (d, k), None

    (delta, keys), _ = jax.lax.scan(body, (delta, keys), (Xw, yw))
    return delta, keys


def _dp_reduce_deltas(fcfg, delta):
    """(D, ...) stacked shard deltas -> ONE merged delta (log-depth).

    The same pairwise-halving schedule as
    :func:`repro.core.stats.tree_reduce_merge` — the order a real
    all-reduce combines partials in, and FIXED, so the reduction is
    deterministic and the sharded trainer can be pinned bitwise against
    its single-device reference.  The QO planes go through
    :func:`repro.kernels.ops.forest_merge` (the kernel-backed §4.1
    collective) with the (live, T·M) table axis folded; the small
    per-leaf/per-member Stats go through the same Chan operator.
    """
    from repro.core import stats
    from repro.kernels import ops as kops

    backend = fcfg.tree.split_backend
    F, C = fcfg.tree.n_features, fcfg.tree.observer_bins()
    # the sketch's rank-bucket merge replaces the elementwise Chan merge
    # (slot i of two sketches covers different rank ranges); the protocol
    # — fold, pairwise-halve, unfold — is identical (§2.8)
    table_merge = kops.sketch_merge \
        if fcfg.tree.observer_backend == "sketch" else kops.forest_merge

    def merge_pair(a, b):
        h = a["ao_sum_x"].shape[0] * a["ao_sum_x"].shape[1]
        fold = lambda x: x.reshape((h * fcfg.tree.max_nodes, F, C))
        ao_y, ao_sum_x = table_merge(
            jax.tree.map(fold, a["ao_y"]), fold(a["ao_sum_x"]),
            jax.tree.map(fold, b["ao_y"]), fold(b["ao_sum_x"]),
            backend=backend)
        unfold = lambda x: x.reshape(a["ao_sum_x"].shape)
        return {
            "ystats": stats.merge(a["ystats"], b["ystats"]),
            "ao_y": jax.tree.map(unfold, ao_y),
            "ao_sum_x": unfold(ao_sum_x),
            "err": stats.merge(a["err"], b["err"]),
        }

    while delta["ao_sum_x"].shape[0] > 1:
        k = delta["ao_sum_x"].shape[0]
        half = k // 2
        a = jax.tree.map(lambda x: x[:half], delta)
        b = jax.tree.map(lambda x: x[half:2 * half], delta)
        m = merge_pair(a, b)
        if k % 2:
            delta = jax.tree.map(
                lambda x, t: jnp.concatenate([x, t[-1:]], 0), m, delta)
        else:
            delta = m
    return jax.tree.map(lambda x: x[0], delta)


def _dp_apply_sync(fcfg, forest, merged):
    """Fold ONE merged delta into the replicated forest + attempt splits.

    The global half of the §4.1 protocol, identical on every device:
    leaf predictors and grace mass advance by the merged batch
    statistics, the QO tables fold through
    :func:`repro.kernels.ops.forest_merge`, and the §2.5 attempt stage
    runs on the MERGED tables — so every shard derives the same splits
    and the topology stays replicated without ever shipping it.  The
    prequential error windows merge into ``err_win`` and refresh
    ``vote_w`` (in DP the short EWMA window degenerates to the merged
    running mean: per-shard EWMAs are not order-mergeable, and the DP
    trainer has no drift-swap — membership is frozen between syncs).
    Returns ``(forest', aux)``.
    """
    from repro.core import forest as fr
    from repro.core import stats
    from repro.kernels import ops as kops

    T, M = fcfg.n_trees, fcfg.tree.max_nodes
    F, C = fcfg.tree.n_features, fcfg.tree.observer_bins()
    table_merge = kops.sketch_merge \
        if fcfg.tree.observer_backend == "sketch" else kops.forest_merge
    trees = forest["trees"]
    trees = dict(trees,
                 ystats=stats.merge(trees["ystats"], merged["ystats"]),
                 seen_since_attempt=trees["seen_since_attempt"]
                 + merged["ystats"]["n"])
    fold = lambda x: x.reshape((T * M, F, C))
    ao_y, ao_sum_x = table_merge(
        jax.tree.map(fold, trees["ao_y"]), fold(trees["ao_sum_x"]),
        jax.tree.map(fold, merged["ao_y"]), fold(merged["ao_sum_x"]),
        backend=fcfg.tree.split_backend)
    unfold = lambda x: x.reshape((T, M) + x.shape[1:])
    trees = dict(trees, ao_y=jax.tree.map(unfold, ao_y),
                 ao_sum_x=unfold(ao_sum_x))
    trees = fr._fused_member_attempt(fcfg, trees, forest["feat_mask"])

    err_win = stats.merge(forest["err_win"], merged["err"])
    state = dict(forest, trees=trees, err_win=err_win,
                 err_ewma=jnp.where(err_win["n"] > 0, err_win["mean"], 0.0))
    state["vote_w"] = fr.vote_weights(fcfg, state)
    aux = {"mass": merged["ystats"]["n"].sum(),
           "member_mse": state["err_ewma"],
           "n_nodes": trees["n_nodes"]}
    return state, aux


@functools.lru_cache(maxsize=None)
def _dp_sync_jit(fcfg):
    """ONE cached jit of reduce + apply per config — shared by the
    sharded trainer and the single-device reference, so the sync math of
    the two paths is literally the same compiled program (the §4.1
    bit-identity pin)."""
    return jax.jit(lambda forest, delta: _dp_apply_sync(
        fcfg, forest, _dp_reduce_deltas(fcfg, delta)))


@functools.lru_cache(maxsize=None)
def _dp_apply_jit(fcfg):
    """Cached jit of the apply half alone (the int8-compressed sync path
    hands it an already-psum-merged delta)."""
    return jax.jit(functools.partial(_dp_apply_sync, fcfg))


def _register_dp_caches():
    """Hook the DP sync jits into the shared ``ops.clear_jit_caches``
    registry, so the one-call-resets-everything contract keeps holding
    (function-scoped import to match the module's import discipline —
    no cycle: the kernel stack never imports train.sharding)."""
    from repro.kernels import ops as kops

    kops.register_jit_cache(_dp_sync_jit)
    kops.register_jit_cache(_dp_apply_jit)


_register_dp_caches()


def _stats_linear(s):
    """Stats -> psum-able linear encoding (n, n·mean, M2 + n·mean²)."""
    s1 = s["n"] * s["mean"]
    return {"n": s["n"], "s1": s1, "s2": s["m2"] + s1 * s["mean"]}


def _stats_delinear(p):
    """Inverse of :func:`_stats_linear` after the sum — the
    cancellation-prone form the robust paths avoid (§3); acceptable here
    because it is the explicitly lossy cheap-shipping mode."""
    n = p["n"]
    mean = jnp.where(n > 0, p["s1"] / jnp.where(n > 0, n, 1.0), 0.0)
    m2 = jnp.maximum(p["s2"] - p["s1"] * mean, 0.0)
    return {"n": n, "mean": mean, "m2": jnp.where(n > 0, m2, 0.0)}


def _dp_gather_int8(fcfg, delta, axis: str):
    """Shard-local delta -> merged delta via int8-quantized psum (§4.2).

    The cheap-shipping path: every shipped plane is linear (Stats ride
    the (n, n·mean, M2-corrected) encoding), int8-quantized per leaf
    with one f32 scale (4x wire traffic cut,
    :func:`repro.optim.compress.quantized_psum`), summed across the
    mesh axis, and decoded back.  Lossy by design — quantization error
    ~ max|plane|/127 per element — so it trades the §4.1 bit-exactness
    for bandwidth; use it when the sync payload, not the math, is the
    bottleneck.
    """
    from repro.optim import compress

    linear = {
        "ystats": _stats_linear(delta["ystats"]),
        "ao_y": _stats_linear(delta["ao_y"]),
        "ao_sum_x": delta["ao_sum_x"],
        "err": _stats_linear(delta["err"]),
    }
    summed = compress.quantized_psum(linear, axis)
    return {
        "ystats": _stats_delinear(summed["ystats"]),
        "ao_y": _stats_delinear(summed["ao_y"]),
        "ao_sum_x": summed["ao_sum_x"],
        "err": _stats_delinear(summed["err"]),
    }


class DataParallelForest(NamedTuple):
    """The §4.1 trainer's entry points (both builders return one):

    ``init(key) -> dpstate``; ``update(dpstate, X, y) -> (dpstate,
    aux | None)`` — one global batch, sync when the ``sync_every``
    cadence fires; ``update_window(dpstate, Xw, yw) -> (dpstate, aux)``
    — a whole (S, B, F) window of local batches in ONE dispatch followed
    by an unconditional sync (the deployment shape: shards run
    autonomously between boundaries); ``predict(dpstate, X) -> (B,)``.
    """
    init: Any
    update: Any
    update_window: Any
    predict: Any


def build_data_parallel_forest(fcfg, mesh: Mesh, axis: str = "data",
                               sync_every: int = 1,
                               compress: str | None = None,
                               on_sync=None):
    """Data-parallel stream scale-out (DESIGN.md §4.1).

    The third and last sharding axis: :func:`build_sharded_forest`
    spreads the TREE axis (PR 2), :func:`build_sharded_serving` the
    request batch (PR 4) — this one shards the TRAINING STREAM itself
    over ``D = mesh.shape[axis]`` devices.  Every device owns a
    replicated copy of the forest (topology + quantization grids +
    merged stats) and a private delta; a local step is route/absorb
    only, and every ``sync_every`` batches the deltas all-reduce with
    the Chan-merge collective (:func:`repro.kernels.ops.forest_merge`)
    and the split attempts execute on the merged statistics — identical
    on every device, so the D-shard forest is bit-identical to the
    single-device execution of the same protocol at every sync boundary
    (pinned by tests against :func:`build_data_parallel_reference`).

    ``sync_every`` trades collective traffic for split latency: between
    syncs no leaf can split (statistics keep absorbing; nothing is
    lost — the QO algebra is order-free), so the effective grace period
    is at least ``sync_every`` global batches.  ``compress="int8"``
    ships the deltas int8-quantized over a psum instead of exactly
    (§4.2; lossy, ~4x less wire traffic).  Requires a kernel-capable
    ``split_backend`` (not ``"oracle"``).

    Returns a :class:`DataParallelForest` named tuple:

    * ``init(key) -> dpstate`` — device-placed trainer state;
    * ``update(dpstate, X, y) -> (dpstate, aux | None)`` — learn one
      global batch of B rows (D must divide B; rows shard
      contiguously).  ``aux`` is None between syncs and
      ``{"mass", "member_mse", "n_nodes"}`` at a boundary;
    * ``update_window(dpstate, Xw, yw) -> (dpstate, aux)`` — a whole
      (S, B, F) window of local batches in ONE dispatch, then an
      unconditional sync;
    * ``predict(dpstate, X) -> (B,)`` — request-sharded vote over the
      replicated forest (no collectives; D must divide B).

    ``on_sync``: optional ``on_sync(forest_state, step, aux)`` callback
    fired at every sync boundary with the freshly merged (replicated)
    forest — the **publish boundary** of the continuous-serving engine
    (DESIGN.md §5.6): a
    :class:`repro.core.engine.ServingEngine`'s publisher hooks here
    (``freeze`` + validated atomic swap), so serving freshness rides the
    ``sync_every`` cadence directly.  Exceptions out of ``on_sync`` are
    the CALLER's (a publish failure must not poison training).
    """
    from jax.experimental.shard_map import shard_map

    from repro.core import forest as fr

    assert fcfg.tree.split_backend != "oracle", \
        "data-parallel training needs a fused backend (oracle is per-row)"
    assert compress in (None, "int8"), compress
    D = mesh.shape[axis]

    abstract = jax.eval_shape(
        lambda: init_data_parallel(fcfg, jax.random.PRNGKey(0), D))
    repl = lambda t: jax.tree.map(lambda a: P(*([None] * a.ndim)), t)
    shardy = lambda t: jax.tree.map(
        lambda a: P(axis, *([None] * (a.ndim - 1))), t)
    fspec = repl(abstract["forest"])
    dspec = shardy(abstract["delta"])
    kspec = P(axis, None, None)
    forest_repl = to_shardings(mesh, fspec)
    delta_shard = to_shardings(mesh, dspec)
    delta_repl = to_shardings(mesh, repl(abstract["delta"]))

    def local_body(forest, delta, keys, X, y):
        d, k = jax.tree.map(lambda a: a[0], (delta, keys))
        d, k = _dp_local_shard(fcfg, forest, d, k, X, y)
        return jax.tree.map(lambda a: a[None], (d, k))

    # check_rep off: routing/absorb gathers have no replication rule
    local = jax.jit(shard_map(
        local_body, mesh=mesh,
        in_specs=(fspec, dspec, kspec, P(axis, None), P(axis)),
        out_specs=(dspec, kspec), check_rep=False))

    def window_body(forest, delta, keys, Xw, yw):
        d, k = jax.tree.map(lambda a: a[0], (delta, keys))
        d, k = _dp_local_window(fcfg, forest, d, k, Xw, yw)
        return jax.tree.map(lambda a: a[None], (d, k))

    window = jax.jit(shard_map(
        window_body, mesh=mesh,
        in_specs=(fspec, dspec, kspec, P(None, axis, None), P(None, axis)),
        out_specs=(dspec, kspec), check_rep=False))

    if compress == "int8":
        gather = jax.jit(shard_map(
            lambda delta: _dp_gather_int8(
                fcfg, jax.tree.map(lambda a: a[0], delta), axis),
            mesh=mesh, in_specs=(dspec,),
            out_specs=repl(jax.eval_shape(
                lambda d: jax.tree.map(lambda a: a[0], d),
                abstract["delta"])), check_rep=False))
        sync = lambda forest, delta: _dp_apply_jit(fcfg)(
            forest, gather(delta))
    else:
        # the all-gather is the collective; reduce + apply then run
        # replicated through the SAME jit as the reference
        sync = lambda forest, delta: _dp_sync_jit(fcfg)(
            forest, jax.device_put(delta, delta_repl))

    zero_delta = jax.device_put(_dp_init_delta(fcfg, D), delta_shard)

    def init_fn(key):
        st = init_data_parallel(fcfg, key, D)
        return {
            "forest": jax.device_put(st["forest"], forest_repl),
            "delta": jax.device_put(st["delta"], delta_shard),
            "keys": jax.device_put(st["keys"],
                                   NamedSharding(mesh, kspec)),
            "step": 0,
        }

    def _synced(dpstate, delta, keys, step):
        forest, aux = sync(dpstate["forest"], delta)
        forest = jax.device_put(forest, forest_repl)
        if on_sync is not None:
            on_sync(forest, step, aux)        # the publish boundary
        return {"forest": forest,
                "delta": zero_delta, "keys": keys, "step": step}, aux

    def update_fn(dpstate, X, y):
        delta, keys = local(dpstate["forest"], dpstate["delta"],
                            dpstate["keys"], X, y)
        step = dpstate["step"] + 1
        if step % sync_every:
            return dict(dpstate, delta=delta, keys=keys, step=step), None
        return _synced(dpstate, delta, keys, step)

    def update_window_fn(dpstate, Xw, yw):
        delta, keys = window(dpstate["forest"], dpstate["delta"],
                             dpstate["keys"], Xw, yw)
        return _synced(dpstate, delta, keys,
                       dpstate["step"] + Xw.shape[0])

    prd = jax.jit(shard_map(
        lambda forest, X: fr.predict(fcfg, forest, X),
        mesh=mesh, in_specs=(fspec, P(axis, None)), out_specs=P(axis),
        check_rep=False))

    return DataParallelForest(init_fn, update_fn, update_window_fn,
                              lambda dpstate, X: prd(dpstate["forest"], X))


def build_data_parallel_reference(fcfg, n_shards: int, sync_every: int = 1,
                                  on_sync=None):
    """Single-device oracle of :func:`build_data_parallel_forest`.

    The SAME protocol with the shard axis as a local ``vmap`` instead of
    a mesh axis — every local step runs the identical per-shard body on
    the identical slices, and the sync boundary goes through the very
    same cached jit (:func:`_dp_sync_jit`).  The sharded trainer is
    pinned bitwise against this at every sync boundary
    (tests/test_dp.py): the mesh placement is an execution choice, not
    a semantics change.
    """
    from repro.core import forest as fr

    assert fcfg.tree.split_backend != "oracle"

    local = jax.jit(jax.vmap(
        functools.partial(_dp_local_shard, fcfg),
        in_axes=(None, 0, 0, 0, 0)))
    window = jax.jit(jax.vmap(
        functools.partial(_dp_local_window, fcfg),
        in_axes=(None, 0, 0, 1, 1)))

    def init_fn(key):
        return init_data_parallel(fcfg, key, n_shards)

    def _shardwise(X, y):
        B = y.shape[-1] if y.ndim > 1 else y.shape[0]
        assert B % n_shards == 0, (B, n_shards)
        shp = X.shape[:-2] + (n_shards, B // n_shards)
        return X.reshape(shp + X.shape[-1:]), y.reshape(shp)

    def _synced(dpstate, delta, keys, step):
        forest, aux = _dp_sync_jit(fcfg)(dpstate["forest"], delta)
        if on_sync is not None:
            on_sync(forest, step, aux)        # the same publish boundary
        return {"forest": forest,
                "delta": _dp_init_delta(fcfg, n_shards),
                "keys": keys, "step": step}, aux

    def update_fn(dpstate, X, y):
        Xs, ys = _shardwise(X, y)
        delta, keys = local(dpstate["forest"], dpstate["delta"],
                            dpstate["keys"], Xs, ys)
        step = dpstate["step"] + 1
        if step % sync_every:
            return dict(dpstate, delta=delta, keys=keys, step=step), None
        return _synced(dpstate, delta, keys, step)

    def update_window_fn(dpstate, Xw, yw):
        Xs, ys = _shardwise(Xw, yw)                  # (S, D, B/D, ...)
        delta, keys = window(dpstate["forest"], dpstate["delta"],
                             dpstate["keys"], Xs, ys)
        return _synced(dpstate, delta, keys,
                       dpstate["step"] + Xw.shape[0])

    def predict_fn(dpstate, X):
        return fr.predict(fcfg, dpstate["forest"], X)

    return DataParallelForest(init_fn, update_fn, update_window_fn,
                              predict_fn)
