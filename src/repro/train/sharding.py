"""PartitionSpec rules for params, optimizer state, batches, caches — and
the tree-axis sharding of the QO Hoeffding forest (DESIGN.md §5).

Strategy (DESIGN.md §7): TP over the 16-way "model" axis + FSDP over the
data axes ("pod","data") — required for grok-1-314b, whose optimizer state
would otherwise need 235 GB/chip.  Rules are name+shape based over the
param pytree; every rule falls back to replication when a dimension does
not divide the mesh axis (e.g. whisper's 51865 vocab, 8-way KV heads).

Logical mapping:
  d_model / d_inner rows  ->  fsdp axes      (all-gathered for the matmul)
  heads / d_ff / vocab    ->  "model" (TP)
  experts                 ->  "model" when E % tp == 0 (EP), else d_ff TP
  batch                   ->  fsdp axes
  decode KV cache         ->  batch over fsdp; heads over model when
                              divisible, else sequence over model
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def mesh_axes(mesh: Mesh) -> Tuple[Tuple[str, ...], str]:
    """Returns (fsdp_axes, tp_axis)."""
    names = mesh.axis_names
    tp = "model"
    fsdp = tuple(n for n in names if n != tp)
    return fsdp, tp


def _div(n: int, size: int) -> bool:
    return n > 0 and n % size == 0


def param_specs(cfg, params_shapes, mesh: Mesh, style: str = "contraction"):
    """Pytree of PartitionSpec matching the params pytree.

    ``params_shapes``: pytree of ShapeDtypeStruct (from jax.eval_shape).

    style:
      "contraction" (baseline): FSDP shards the contraction (d_model) dim of
        weights.  XLA then often SPLITS the contraction instead of gathering
        the weight, all-reducing full activation tensors over the data axes
        — measured catastrophic for MoE (§Perf: grok 7.8 TB/step).
      "gather": FSDP co-shards the weight's OUTPUT dim with TP
        (2D sharding).  The output dim cannot be data-sharded twice (tokens
        already are), so the partitioner must ALL-GATHER the weight shards —
        the ZeRO-3 pattern: collective bytes scale with weights, not
        activations.
    """
    fsdp, tp = mesh_axes(mesh)
    tp_n = mesh.shape[tp]
    fsdp_n = 1
    for a in fsdp:
        fsdp_n *= mesh.shape[a]
    d = cfg.d_model
    gather = style == "gather"

    def fs(dim):  # fsdp-shard a dimension if it divides
        return fsdp if _div(dim, fsdp_n) else None

    def tps(dim):
        return tp if _div(dim, tp_n) else None

    def tp_fs(dim):
        """2D shard over (tp, fsdp...) when divisible, else best effort."""
        if _div(dim, tp_n * fsdp_n):
            return (tp,) + fsdp
        return tps(dim)

    def rule(path, leaf):
        keys = [str(getattr(p, "key", getattr(p, "idx", p))) for p in path]
        name = keys[-1] if keys else ""
        shp = leaf.shape
        nd = len(shp)
        # strip the stacked-layer leading axis for rule matching
        core = shp[1:] if (keys and keys[0] in ("layers", "enc_layers")
                           and nd >= 1) else shp

        def spec(*core_spec):
            pad = (None,) * (nd - len(core_spec))
            return P(*pad, *core_spec)

        if name == "embed":
            if _div(shp[0], tp_n):
                return P(tp, fs(shp[1]))
            return P(None, tps(shp[1]))
        if name == "lm_head":
            if gather:
                return P(None, tp_fs(shp[1]))
            return P(fs(shp[0]), tps(shp[1]))
        if name in ("wq", "wo"):
            # (d, H, hd) / (H, hd, d): heads over TP
            if name == "wq":
                if gather:  # output dims (H, hd) 2D-sharded -> weight gather
                    return spec(None, tps(core[1]), fs(core[2]))
                return spec(fs(core[0]), tps(core[1]), None)
            if gather:
                return spec(tps(core[0]), None, fs(core[2]))
            return spec(tps(core[0]), None, fs(core[2]))
        if name in ("wk", "wv"):
            if gather:
                return spec(None, tps(core[1]), fs(core[2]))
            return spec(fs(core[0]), tps(core[1]), None)
        if name in ("w_gate", "w_up", "w_down", "router"):
            if len(core) == 3:  # MoE (E, d, f) / (E, f, d)
                E = core[0]
                if gather:
                    # contraction dim NEVER data-sharded; FSDP rides the
                    # output dim (core[2]) -> partitioner gathers weights
                    if _div(E, tp_n):  # EP: experts over tp
                        return spec(tp, None, fs(core[2]))
                    if name == "w_down":  # (E, f, d): f row-parallel
                        return spec(None, tps(core[1]), fs(core[2]))
                    return spec(None, None, tp_fs(core[2]))  # (E, d, f)
                if _div(E, tp_n):  # EP
                    return spec(tp, fs(core[1]) if name != "w_down" else None,
                                None)
                if name == "w_down":
                    return spec(None, tps(core[1]), fs(core[2]))
                return spec(None, fs(core[1]), tps(core[2]))
            if name == "router":
                return spec(fs(core[0]) if not gather else None, None)
            if name == "w_down":
                return spec(tps(core[0]), fs(core[1]))
            if gather:
                return spec(None, tp_fs(core[1]))
            return spec(fs(core[0]), tps(core[1]))
        if name in ("in_proj",):  # mamba1 (d, 2di)
            if gather:
                return spec(None, tp_fs(core[1]))
            return spec(fs(core[0]), tps(core[1]))
        if name in ("in_z", "in_x"):
            if gather:
                return spec(None, tp_fs(core[1]))
            return spec(fs(core[0]), tps(core[1]))
        if name in ("in_B", "in_C", "in_dt", "x_proj"):
            return spec(None if gather else fs(core[0]), None)
        if name == "dt_proj":  # (dt_rank, di)
            return spec(None, tps(core[1]))
        if name == "out_proj":  # (di, d)
            return spec(tps(core[0]), fs(core[1]))
        if name in ("A_log", "D", "dt_bias") and len(core) >= 1:
            return spec(*([tps(core[0])] + [None] * (len(core) - 1)))
        if name in ("conv_w", "conv_x"):
            return spec(None, tps(core[1]))
        if name in ("conv_B", "conv_C"):
            return spec(None, None)
        if name == "norm_scale":
            return spec(tps(core[0]))
        # norms, biases, small tables: replicate
        return P(*([None] * nd))

    flat, tdef = jax.tree_util.tree_flatten_with_path(params_shapes)
    return jax.tree_util.tree_unflatten(tdef, [rule(p, l) for p, l in flat])


def batch_specs(cfg, shape_kind: str, global_batch: int, mesh: Mesh):
    """PartitionSpec for data batches by field name."""
    fsdp, tp = mesh_axes(mesh)
    fsdp_n = 1
    for a in fsdp:
        fsdp_n *= mesh.shape[a]
    bspec = fsdp if _div(global_batch, fsdp_n) else None

    def field(name):
        if name in ("tokens", "labels", "loss_mask"):
            return P(bspec, None)
        if name == "embeds":
            return P(bspec, None, None)
        if name == "enc_in":
            return P(bspec, None, None)
        if name == "token":     # decode: (B,) or (B, d)
            return P(bspec)
        raise KeyError(name)

    return field


def cache_specs(cfg, batch: int, mesh: Mesh, cache_shapes):
    """Specs for the decode-cache pytree (stacked layer leading axis)."""
    fsdp, tp = mesh_axes(mesh)
    tp_n = mesh.shape[tp]
    fsdp_n = 1
    for a in fsdp:
        fsdp_n *= mesh.shape[a]
    bspec = fsdp if _div(batch, fsdp_n) else None

    def rule(path, leaf):
        keys = [str(getattr(p, "key", getattr(p, "idx", p))) for p in path]
        name = keys[-1]
        shp = leaf.shape
        if name in ("k", "v"):
            # (L, B, S, Hkv, hd): heads over TP if divisible, else seq
            if _div(shp[3], tp_n):
                return P(None, bspec, None, tp, None)
            if _div(shp[2], tp_n):
                return P(None, bspec, tp, None, None)
            return P(None, bspec, None, None, None)
        if name == "pos":
            return P(*([None] * len(shp)))
        if name == "ssm":
            # mamba1 (L,B,di,N): di over TP; mamba2 (L,B,nh,hd,N): nh over TP
            if len(shp) == 4:
                return P(None, bspec, tp if _div(shp[2], tp_n) else None, None)
            return P(None, bspec, tp if _div(shp[2], tp_n) else None, None, None)
        if name == "conv" or (len(keys) >= 2 and keys[-2] == "conv"):
            ch = shp[-1]
            return P(*([None, bspec, None] + [tp if _div(ch, tp_n) else None]))
        if name in ("cross_k", "cross_v"):
            if _div(shp[3], tp_n):
                return P(None, bspec, None, tp, None)
            return P(None, bspec, None, None, None)
        return P(*([None] * len(shp)))

    flat, tdef = jax.tree_util.tree_flatten_with_path(cache_shapes)
    return jax.tree_util.tree_unflatten(tdef, [rule(p, l) for p, l in flat])


def opt_specs(pspecs):
    """Optimizer state shards exactly like params (m, v) + scalar step."""
    return {"m": pspecs, "v": pspecs, "step": P()}


# --------------------------------------------------------------------------
# Hoeffding-forest tree-axis sharding (DESIGN.md §5)
# --------------------------------------------------------------------------

def forest_state_specs(state, axis="data"):
    """PartitionSpec pytree sharding the forest over its tree axis.

    Every leaf of a :mod:`repro.core.forest` state carries the tree axis
    first (the module's layout invariant), so the rule is uniform:
    ``P(axis, None, ...)`` — new per-leaf state rides along automatically
    (e.g. the §2.5 ``seen_since_attempt`` grace counters shard as
    ``P(axis, None)`` like every other (T, M) member field, keeping the
    attempt mask — and therefore the compacted split query's K bucket —
    a purely shard-local decision).  ``state`` may be a real pytree or
    the ``jax.eval_shape`` abstraction of one.
    """
    return jax.tree.map(
        lambda a: P(axis, *([None] * (a.ndim - 1))), state)


def build_sharded_forest(fcfg, mesh: Mesh, axis: str = "data"):
    """jit'd ``(update_fn, predict_fn)`` with T trees spread over ``axis``.

    ``update_fn(state, X, y) -> (state, aux)`` and
    ``predict_fn(state, X) -> (B,)`` are ``shard_map`` wrappers around
    :func:`repro.core.forest.update` / ``predict``: each device owns
    ``T / mesh.shape[axis]`` member trees (T must divide) and runs the
    identical vmapped member program on its shard; the ONLY cross-device
    traffic is the two-scalar psum pair of the prediction vote reduce
    (``axis_name=axis`` inside the mapped body).  Batches are replicated —
    every member sees the whole stream, exactly like the single-host
    forest, so sharded and unsharded training produce identical forests
    while no drift swap fires (tests pin this).  The one intentional
    divergence: the worst-signalling-member swap is resolved per SHARD,
    so under simultaneous drift a D-way sharded forest may reset up to D
    members per batch where the single-host forest resets one.
    """
    from jax.experimental.shard_map import shard_map

    from repro.core import forest as fr

    assert fcfg.n_trees % mesh.shape[axis] == 0, \
        (fcfg.n_trees, mesh.shape[axis])
    abstract = jax.eval_shape(
        lambda: fr.init_forest(fcfg, jax.random.PRNGKey(0)))
    sspec = forest_state_specs(abstract, axis)
    aux_spec = {"member_mse": P(axis), "forest_mse": P(),
                "drift": P(axis)}

    # check_rep=False: the member update routes with fori_loop (lowered
    # to `while`, which has no replication rule in this jax); the P()
    # outputs are replicated by construction (psum)
    upd = shard_map(
        lambda s, X, y: fr.update(fcfg, s, X, y, axis_name=axis),
        mesh=mesh, in_specs=(sspec, P(None, None), P(None)),
        out_specs=(sspec, aux_spec), check_rep=False)
    prd = shard_map(
        lambda s, X: fr.predict(fcfg, s, X, axis_name=axis),
        mesh=mesh, in_specs=(sspec, P(None, None)), out_specs=P(None),
        check_rep=False)
    return jax.jit(upd), jax.jit(prd)


def build_sharded_serving(snap, mesh: Mesh, axis: str = "data"):
    """jit'd ``predict_fn(snap, X) -> (B,)`` with X split over ``axis``.

    The read-side complement of :func:`build_sharded_forest`: training
    shards the TREE axis (every device owns T/D members and sees the
    whole batch); serving shards the BATCH axis (every device owns B/D
    request rows and sees the whole — replicated — snapshot, which the
    §5.5 realized trim keeps small).  Each device runs the identical
    fused routing sweep on its rows; there are NO collectives at all —
    the per-row vote reduces over the local (replicated) tree axis.
    B must divide the mesh axis.  ``snap``: a
    :class:`repro.core.serve.Snapshot` (passed per call, so a refreshed
    snapshot of the SAME model needs no recompile while shapes keep
    their bucket; the ply budget is baked in at build, so a refreshed
    snapshot that grew DEEPER than the build-time ply bucket is rejected
    loudly — rebuild then — rather than silently under-routed).
    """
    from functools import partial

    from jax.experimental.shard_map import shard_map

    from repro.core import serve as sv

    plies = sv.kops.depth_bucket(snap.depth)
    body = partial(sv._predict_impl, plies=plies,
                   backend=sv.kops.resolve_backend(None), single=snap.single)
    arrays = (snap.feature, snap.threshold, snap.child, snap.is_leaf,
              snap.leaf_mean, snap.vote_w)
    # the snapshot ships as its six array leaves, NOT as the Snapshot
    # pytree: its (depth, single) aux rides in the treedef, and baking it
    # into in_specs would reject every refreshed snapshot whose realized
    # depth merely CHANGED (shallower included) with a treedef mismatch
    # instead of serving it
    specs = tuple(P(*([None] * a.ndim)) for a in arrays)
    # check_rep off: the routing sweep's gathers have no replication rule
    fn = jax.jit(shard_map(
        body, mesh=mesh, in_specs=specs + (P(axis, None),),
        out_specs=P(axis), check_rep=False))

    def predict_fn(s, X):
        if s.single != snap.single or s.depth > plies:
            raise ValueError(
                f"snapshot (single={s.single}, depth={s.depth}) does not "
                f"fit this serving build (single={snap.single}, ply "
                f"budget {plies}): rebuild build_sharded_serving")
        return fn(s.feature, s.threshold, s.child, s.is_leaf, s.leaf_mean,
                  s.vote_w, X)

    return predict_fn


def to_shardings(mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))
