"""QO telemetry — the paper's observer as a first-class runtime feature.

A monitor is a dict of QO tables (one per tracked signal).  Each train
step folds the step's scalars into the tables with the O(1) quantized
update (paper Algorithm 1); quantiles/variances are read with the
sub-linear query (Algorithm 2 / sketch.quantile).  The tables are a few
KB regardless of how long training runs or how many chips participate —
the paper's memory argument applied to telemetry.

Used by the fault-tolerant loop for:
  * straggler detection: a step time above the p99 of the step-time sketch
    flags the step (would trigger re-slicing in a real deployment);
  * loss-spike / divergence detection: loss above mean + 6 sigma of the
    loss sketch is reported (the NaN-skip in the step handles the acute
    case, the sketch catches slow drift).
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import qo as qo_lib
from repro.core import sketch, stats

BINS = 128
SIGNALS = ("loss", "grad_norm", "step_time")


def init_monitor() -> Dict[str, qo_lib.QOTable]:
    return {
        # cold-start fixed radii (paper §5.2); loss/grad live on ~1e-2..1e2
        "loss": qo_lib.init(BINS, radius=0.1, origin=5.0),
        "grad_norm": qo_lib.init(BINS, radius=0.05, origin=1.0),
        "step_time": qo_lib.init(BINS, radius=0.05, origin=1.0),
    }


def monitor_specs():
    """Monitor tables are tiny: replicate."""
    m = jax.eval_shape(init_monitor)
    return jax.tree.map(lambda _: P(), m)


def observe(mon, *, loss=None, grad_norm=None, step_time=None):
    new = dict(mon)
    for name, val in (("loss", loss), ("grad_norm", grad_norm),
                      ("step_time", step_time)):
        if val is not None:
            v = jnp.reshape(val.astype(jnp.float32), (1,))
            new[name] = qo_lib.update(mon[name], v, v)
    return new


def is_straggler(mon, step_time, q=0.99, min_n=32):
    t = mon["step_time"]
    tot = qo_lib.total_stats(t)
    thr = sketch.quantile(t, jnp.asarray(q))
    return (tot["n"] >= min_n) & (step_time > thr)


def loss_spike(mon, loss, n_sigma=6.0, min_n=32):
    tot = qo_lib.total_stats(mon["loss"])
    sd = stats.stddev(tot)
    return (tot["n"] >= min_n) & (loss > tot["mean"] + n_sigma * sd)


def summaries(mon):
    return {k: sketch.summary(v) for k, v in mon.items()}
