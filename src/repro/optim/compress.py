"""Gradient compression for cross-pod (DCN) traffic reduction.

Two composable mechanisms (DESIGN.md §4.2):

1. **QO-thresholded top-k sparsification with error feedback.**  Picking
   the k-th magnitude quantile of a 10^9-element gradient normally costs a
   sort (O(n log n)) or a top_k.  We instead feed |g| into a QO sketch
   (O(1)/element, O(bins) memory) and read the (1 - k/n) quantile — the
   paper's sub-linear split query repurposed as a compression threshold.
   Error feedback accumulates the residual locally so the compression is
   unbiased over time (Karimireddy et al. style).

2. **int8 quantized all-reduce.**  Per-leaf symmetric int8 quantization
   before the data-axis psum, dequantize after.  4x wire traffic cut; the
   scale factors travel as f32 scalars.

Both are optional flags on the train step; the §Perf log records the
collective-bytes deltas measured from the compiled HLO.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core import qo as qo_lib
from repro.core import sketch


def init_error_state(params):
    return jax.tree.map(jnp.zeros_like, params)


def sparsify_with_sketch(grads, error, keep_frac=0.05, bins=256):
    """Top-|keep_frac| sparsification via QO-sketch quantile threshold.

    Returns (sparse_grads, new_error, metrics).  Applied per-leaf; the
    threshold is estimated from a sketch of |g| rather than a sort.
    """
    def one(g, e):
        g = g + e  # error feedback: compress the accumulated signal
        flat = jnp.abs(g).reshape(-1)
        # dynamic radius: sigma/2 of a warmup slice (paper's r = sigma/k)
        sig = jnp.maximum(jnp.std(flat), 1e-12)
        table = qo_lib.init(bins, radius=1.0, origin=0.0)
        table = dict(table, radius=sig / 2.0,
                     origin=jnp.mean(flat))
        table = qo_lib.update(table, flat, flat)
        thr = sketch.quantile(table, jnp.asarray(1.0 - keep_frac))
        mask = jnp.abs(g) >= thr
        sparse = jnp.where(mask, g, 0.0)
        new_e = g - sparse
        return sparse, new_e, mask.mean()

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(error)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    sparse = jax.tree.unflatten(tdef, [o[0] for o in outs])
    new_err = jax.tree.unflatten(tdef, [o[1] for o in outs])
    density = jnp.mean(jnp.stack([o[2] for o in outs]))
    return sparse, new_err, {"density": density}


def int8_encode(g):
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def int8_decode(q, scale):
    return q.astype(jnp.float32) * scale


def quantized_psum(grads, axis_name):
    """int8 all-reduce: quantize -> psum(int32) -> dequantize.

    The scale must be consistent across the axis, so we psum-max it first
    (one scalar per leaf — negligible traffic vs the 4x tensor savings).
    """
    def one(g):
        scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
        scale = jax.lax.pmax(scale, axis_name)
        q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
        acc = jax.lax.psum(q.astype(jnp.int32), axis_name)
        return acc.astype(jnp.float32) * scale

    return jax.tree.map(one, grads)
