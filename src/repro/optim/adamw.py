"""AdamW + cosine schedule + global-norm clipping, as pure pytree ops.

Optimizer state shards exactly like the parameters (same PartitionSpec
tree), which is what makes the FSDP-style ("pod","data") parameter
sharding carry over to m/v for the 314B-parameter configs.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    betas: Tuple[float, float] = (0.9, 0.95)
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1


def init_state(params) -> Dict[str, Any]:
    zeros = lambda: jax.tree.map(jnp.zeros_like, params)
    return {"m": zeros(), "v": zeros(), "step": jnp.int32(0)}


def schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1.0 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def global_norm(tree):
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(l.astype(jnp.float32) ** 2) for l in leaves))


def clip_by_global_norm(grads, max_norm):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), norm


def apply(cfg: AdamWConfig, params, state, grads):
    """One AdamW step.  Returns (new_params, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    step = state["step"] + 1
    lr = schedule(cfg, step)
    b1, b2 = cfg.betas
    m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"], grads)
    v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, state["v"], grads)
    t = step.astype(jnp.float32)
    mc = 1 - b1 ** t
    vc = 1 - b2 ** t

    def upd(p, m_, v_):
        u = (m_ / mc) / (jnp.sqrt(v_ / vc) + cfg.eps)
        return p - lr * (u + cfg.weight_decay * p)

    new_params = jax.tree.map(upd, params, m, v)
    return new_params, {"m": m, "v": v, "step": step}, \
        {"grad_norm": gnorm, "lr": lr}
