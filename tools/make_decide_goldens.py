"""Regenerate tests/goldens/decide_goldens.npz — the pre-PR-7 bit-identity pin.

Trains a deterministic single tree, a forest, a data-parallel reference
forest and a frozen snapshot with the DEFAULT (Hoeffding) decision
backend and saves every topology/predictor array.  tests/test_decide.py
asserts the default backend still reproduces these arrays bitwise, so
the decision-stage refactor (core/decide.py) can never silently change
the trees it ships.

Run from the repo root: ``PYTHONPATH=src python tools/make_decide_goldens.py``
Only regenerate when an INTENTIONAL behavior change is being made (and
say so in the commit).
"""
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import forest as fr
from repro.core import hoeffding as ht
from repro.core import serve
from repro.data import synth
from repro.train import sharding

OUT = os.path.join(os.path.dirname(__file__), os.pardir,
                   "tests", "goldens", "decide_goldens.npz")

GOLDEN_KEYS = ("feature", "threshold", "child", "is_leaf", "depth",
               "n_nodes", "seen_since_attempt")


def tree_cfg(**kw):
    base = dict(n_features=3, max_nodes=31, n_bins=32, grace_period=200,
                max_depth=6, r0=0.3, split_backend="jnp")
    base.update(kw)
    return ht.HTRConfig(**base)


def collect(prefix, trees, out):
    for k in GOLDEN_KEYS:
        out[f"{prefix}_{k}"] = np.asarray(trees[k])
    out[f"{prefix}_leaf_mean"] = np.asarray(trees["ystats"]["mean"])
    out[f"{prefix}_leaf_n"] = np.asarray(trees["ystats"]["n"])


def main():
    out = {}
    X, y = synth.piecewise_regression(6000, n_features=3, seed=9)
    X, y = jnp.array(X), jnp.array(y)

    # --- single tree, grace + eager schedules ----------------------------
    for sched in ("grace", "eager"):
        cfg = tree_cfg(attempt_schedule=sched)
        s = ht.update_stream(cfg, ht.init_state(cfg), X, y, batch_size=256)
        collect(f"tree_{sched}", s, out)

    # --- forest ----------------------------------------------------------
    fcfg = fr.ForestConfig(tree=tree_cfg(max_nodes=15, max_depth=4),
                           n_trees=4, subspace=0.99)
    fstate, _ = fr.update_stream(fcfg, fr.init_forest(
        fcfg, jax.random.PRNGKey(3)), X[:3000], y[:3000], batch_size=256)
    collect("forest", fstate["trees"], out)
    out["forest_vote_w"] = np.asarray(fstate["vote_w"])

    # --- data-parallel reference (2 shards, sync_every=2) ----------------
    dp = sharding.build_data_parallel_reference(fcfg, n_shards=2,
                                                sync_every=2)
    dst = dp.init(jax.random.PRNGKey(5))
    for i in range(8):
        dst, _ = dp.update(dst, X[i * 256:(i + 1) * 256],
                           y[i * 256:(i + 1) * 256])
    collect("dp", dst["forest"]["trees"], out)

    # --- frozen snapshot of the forest -----------------------------------
    snap = serve.freeze(fstate, version=1, step=11)
    for k in ("feature", "threshold", "child", "is_leaf", "leaf_mean",
              "vote_w"):
        out[f"snap_{k}"] = np.asarray(getattr(snap, k))
    out["snap_depth"] = np.asarray(snap.depth)

    os.makedirs(os.path.dirname(OUT), exist_ok=True)
    np.savez_compressed(OUT, **out)
    print(f"wrote {os.path.normpath(OUT)} ({len(out)} arrays)")


if __name__ == "__main__":
    main()
