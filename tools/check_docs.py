"""Docs gate: ``PYTHONPATH=src python tools/check_docs.py``.

Keeps the documentation layer from rotting silently (CI job ``docs``):

* **link check** — every markdown link in README.md, DESIGN.md and
  docs/*.md must resolve: relative paths must exist in the repo, and
  in-repo anchors must match a heading slug of the target file
  (GitHub's slug rules, close enough: lowercase, punctuation stripped,
  spaces to dashes).  External http(s) links are syntax-checked only —
  CI must not flake on the network.
* **quickstart smoke** — every ```python fenced block in README.md runs
  top to bottom in ONE shared namespace (so later blocks may build on
  earlier imports/variables).  The blocks are written self-contained;
  if a README edit breaks that, this gate fails before a reader does.

Exit code 1 on any failure, with a per-item report.
"""
from __future__ import annotations

import os
import re
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DOC_FILES = ["README.md", "DESIGN.md"]
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
FENCE_RE = re.compile(r"```python\n(.*?)```", re.S)
HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$", re.M)


def _docs():
    files = list(DOC_FILES)
    ddir = os.path.join(REPO, "docs")
    files += sorted(os.path.join("docs", f) for f in os.listdir(ddir)
                    if f.endswith(".md"))
    return files


def _slug(heading: str) -> str:
    """GitHub-style anchor slug of a heading line."""
    h = re.sub(r"[`*_]", "", heading.strip().lower())
    h = re.sub(r"[^\w\- ]", "", h, flags=re.UNICODE)
    return h.replace(" ", "-")


def _anchors(path: str) -> set:
    with open(path) as f:
        text = f.read()
    return {_slug(m) for m in HEADING_RE.findall(text)}


def check_links() -> list:
    failures = []
    for rel in _docs():
        path = os.path.join(REPO, rel)
        base = os.path.dirname(path)
        for target in LINK_RE.findall(open(path).read()):
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            frag = None
            if "#" in target:
                target, frag = target.split("#", 1)
            dest = path if not target else os.path.normpath(
                os.path.join(base, target))
            if target and not os.path.exists(dest):
                failures.append(f"{rel}: broken link -> {target}")
                continue
            if frag and dest.endswith(".md") and _slug(frag) not in _anchors(dest):
                failures.append(f"{rel}: dead anchor -> {target}#{frag}")
    return failures


def run_readme_blocks() -> list:
    blocks = FENCE_RE.findall(open(os.path.join(REPO, "README.md")).read())
    ns: dict = {}
    for i, code in enumerate(blocks):
        try:
            exec(compile(code, f"README.md[python #{i}]", "exec"), ns)
        except Exception as e:  # noqa: BLE001 — report, don't crash the gate
            return [f"README.md python block #{i} failed: {type(e).__name__}: {e}"]
    return [] if blocks else ["README.md has no ```python quickstart block"]


def main() -> int:
    failures = check_links()
    print(f"link check: {len(failures)} failure(s) over {len(_docs())} files")
    failures += run_readme_blocks()
    print("README quickstart blocks: ran" if len(failures) == 0
          else "README quickstart blocks: FAILED")
    if failures:
        for f in failures:
            print(f"  {f}", file=sys.stderr)
        return 1
    print("docs gate: all links resolve, quickstart runs")
    return 0


if __name__ == "__main__":
    sys.exit(main())
