"""Train an online forest on a stream, then serve it from a frozen snapshot.

    PYTHONPATH=src python examples/serve_forest.py

The write path and the read path are different programs (DESIGN.md §5.5):
``forest.update_stream`` learns the whole stream in one dispatch; at the
train/serve boundary ``serve.freeze`` packs the live forest into a
breadth-first snapshot trimmed to the *realized* tree depth with leaf
means and vote weights pre-gathered; ``serve.predict_snapshot`` then
answers request batches of any size through donated cached jits — no
recompiles across the request loop, predictions bit-identical to the
live forest's.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import forest as fr
from repro.core import hoeffding as ht
from repro.core import serve as sv
from repro.data.synth import piecewise_target

rng = np.random.default_rng(0)
F, T, N = 4, 8, 16384
tree_cfg = ht.HTRConfig(n_features=F, max_nodes=63, n_bins=48,
                        grace_period=250, max_depth=12, r0=0.3)
cfg = fr.ForestConfig(tree=tree_cfg, n_trees=T)

# --- train: one dispatch over the whole stream ---------------------------
X = rng.normal(0, 1, (N, F)).astype(np.float32)
y = (piecewise_target(X) + 0.1 * rng.normal(0, 1, N)).astype(np.float32)
state = fr.init_forest(cfg, jax.random.PRNGKey(0))
state, trace = fr.update_stream(cfg, state, jnp.array(X), jnp.array(y))
print(f"trained: {T} trees, "
      f"{int(np.asarray(fr.n_leaves_per_tree(state)).sum())} leaves, "
      f"final prequential mse={float(np.asarray(trace['forest_mse'])[-1]):.3f}")

# --- freeze: the train/serve boundary ------------------------------------
snap = sv.freeze(state)
live_nodes = tree_cfg.max_nodes
from repro.kernels import ops as kops  # noqa: E402

print(f"snapshot: {snap.feature.shape[1]} nodes/tree "
      f"(live capacity {live_nodes}), realized depth {snap.depth} "
      f"(cfg.max_depth {tree_cfg.max_depth}) — routing sweeps "
      f"{kops.depth_bucket(snap.depth)} plies, not the seed's "
      f"{tree_cfg.max_depth + 1}")

# --- serve: ragged request sizes, one warm compiled program per bucket ---
pred_live = fr.predict(cfg, state, jnp.array(X[:2048]))
pred_snap = sv.predict_snapshot(snap, jnp.array(X[:2048]))
assert (np.asarray(pred_snap) == np.asarray(pred_live)).all(), \
    "snapshot must serve bit-identical predictions"

request_sizes = (2048, 100, 761, 2048, 100)         # ragged, repeated
for B in request_sizes:
    Xq = jnp.array(rng.normal(0, 1, (B, F)).astype(np.float32))
    t0 = time.perf_counter()
    out = sv.predict_snapshot(snap, Xq)
    jax.block_until_ready(out)
    print(f"  served B={B:5d} in {(time.perf_counter() - t0) * 1e3:6.2f} ms")

# the no-recompile contract: one compiled program per pow-2 size bucket,
# repeats hit it warm
buckets = {max(128, 1 << (B - 1).bit_length()) for B in request_sizes}
n_programs = sv._jit_predict(
    kops.resolve_backend(None), kops.depth_bucket(snap.depth),
    snap.single)._cache_size()
print(f"compile cache after the request loop: {n_programs} program(s) "
      f"for {len(buckets)} request-size buckets")
assert n_programs == len(buckets), \
    f"serving recompiled: {n_programs} programs for {len(buckets)} buckets"
assert int(ht.n_leaves(jax.tree.map(lambda a: a[0], state["trees"]))) > 1
print("OK")
