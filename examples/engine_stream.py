"""Continuous serving: train-and-serve with a mid-stream trainer crash.

    PYTHONPATH=src python examples/engine_stream.py

The :class:`repro.core.engine.ServingEngine` runs the trainer and the
server concurrently (DESIGN.md §5.6): the trainer absorbs a
deterministic step-indexed stream and every ``sync_every`` batches
freezes + publishes a validated, versioned snapshot with one atomic
reference swap; the server packs open-loop requests into batches that
land on the cached-jit pow-2 buckets and answers them from whichever
snapshot is published — bit-identical to a standalone
``predict_snapshot`` on that version.

This example injects ONE trainer kill mid-sync-window and shows the
degradation contract: serving never stops, the trainer restores the
newest valid checkpoint, rewinds the stream to its step, re-publishes,
and the publish cadence resumes.  The assertions at the bottom are the
same invariants tests/test_engine.py pins.
"""
import tempfile
import time

import jax
import numpy as np

from repro.checkpoint.ckpt import Checkpointer
from repro.core import engine as eng
from repro.core import faults as fl
from repro.core import forest as fr
from repro.core import hoeffding as ht
from repro.data.synth import piecewise_target

rng = np.random.default_rng(0)
F, T, STEPS, ROWS = 4, 4, 24, 128
tree_cfg = ht.HTRConfig(n_features=F, max_nodes=31, n_bins=16,
                        grace_period=40, max_depth=6, r0=0.3)
cfg = fr.ForestConfig(tree=tree_cfg, n_trees=T)

X_all = rng.normal(0, 1, (STEPS * ROWS, F)).astype(np.float32)
y_all = (piecewise_target(X_all)
         + 0.1 * rng.normal(0, 1, len(X_all))).astype(np.float32)


def stream(step):
    """Deterministic, step-indexed: after a crash-restore to step s the
    trainer replays from s identically — exact recovery, not roughly."""
    if step >= STEPS:
        return None
    lo = step * ROWS
    return X_all[lo:lo + ROWS], y_all[lo:lo + ROWS]


injector = fl.FaultInjector()
injector.arm("trainer.step", fl.Kill(), after=6)    # dies mid-window

with tempfile.TemporaryDirectory() as ckdir:
    e = eng.ServingEngine(
        cfg, fr.init_forest(cfg, jax.random.PRNGKey(0)), stream,
        cfg=eng.EngineConfig(sync_every=4, ckpt_every=1,
                             max_queue_rows=4096, max_batch_rows=1024,
                             keep_versions=16),  # retain all for the audit
        checkpointer=Checkpointer(ckdir), injector=injector)
    print(f"engine up: serving v{e.published_version} "
          f"before the first training step")
    e.start()

    # open-loop requests racing the trainer (and its injected crash)
    tickets = [e.submit(X_all[i * 16:(i * 16) + 48]) for i in range(16)]
    deadline = time.monotonic() + 120
    while e.metrics()["recoveries"] < 1 and time.monotonic() < deadline:
        time.sleep(0.01)
    tickets += [e.submit(X_all[i * 16:(i * 16) + 48]) for i in range(16)]
    # let the trainer finish the stream: the publish cadence must RESUME
    # after the crash (boundaries every 4 steps through step 24)
    while (e.metrics()["published_step"] < STEPS
           and time.monotonic() < deadline):
        time.sleep(0.01)
    for t in tickets:
        t.wait(timeout=60)
    e.stop(drain=True)

    m = e.metrics()
    print(f"trainer crashed {m['trainer_crashes']}x, "
          f"recovered {m['recoveries']}x "
          f"(restored checkpoint + rewound stream + re-published)")
    print(f"served {m['served_requests']} requests "
          f"({m['served_rows']} rows) in {m['serve_batches']} batches, "
          f"shed {m['shed_requests']}, publishes={m['publishes']}, "
          f"final v{m['published_version']} @ step {m['published_step']}")

    # -- the degradation contract -----------------------------------------
    assert injector.fired("trainer.step") == 1, "the kill must have fired"
    assert m["trainer_crashes"] == 1 and m["recoveries"] == 1
    done = [t for t in tickets if t.status == "done"]
    assert len(done) + m["shed_requests"] == len(tickets)
    assert all(t.result is not None and np.isfinite(t.result).all()
               for t in done), "zero failed requests across the crash"
    # every answer is bit-identical to its pinned published version
    from repro.core import serve as sv
    for t in done[:4]:
        np.testing.assert_array_equal(
            t.result, np.asarray(sv.predict_snapshot(
                e.snapshot_for_version(t.version), t.X)))
    assert m["published_step"] == STEPS, "cadence must resume to stream end"
    assert m["published_version"] == m["publishes"], "no version holes"
    print("recovery verified: serving never stopped, answers bit-exact")
