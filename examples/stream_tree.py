"""Online Hoeffding tree regression on a drifting stream (paper §7 realized).

    PYTHONPATH=src python examples/stream_tree.py

Trains the batched Hoeffding tree (QO observers at every leaf x feature)
on a piecewise target, prints prequential MSE as the tree grows, then
a second phase with drifted thresholds to show the tree keeps adapting
(new splits in fresh regions).
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hoeffding as ht
from repro.data.synth import piecewise_target

rng = np.random.default_rng(0)
F, BS = 4, 256
cfg = ht.HTRConfig(n_features=F, max_nodes=127, n_bins=48,
                   grace_period=250, max_depth=8, r0=0.3)
state = ht.init_state(cfg)
upd = jax.jit(functools.partial(ht.update, cfg))
pred = jax.jit(functools.partial(ht.predict, cfg))


print("phase 1: stationary stream")
for step in range(60):
    X = rng.normal(0, 1, (BS, F)).astype(np.float32)
    y = (piecewise_target(X) + 0.1 * rng.normal(0, 1, BS)).astype(np.float32)
    yhat = np.asarray(pred(state, jnp.array(X)))       # test-then-train
    mse = float(np.mean((yhat - y) ** 2))
    state = upd(state, jnp.array(X), jnp.array(y))
    if step % 10 == 0:
        print(f"  step {step:3d}  prequential mse={mse:7.3f}  "
              f"leaves={int(ht.n_leaves(state))}")

print("phase 2: drift (split point moves 0.0 -> 0.8)")
for step in range(60):
    X = rng.normal(0, 1, (BS, F)).astype(np.float32)
    y = (piecewise_target(X, shift=0.8) + 0.1 * rng.normal(0, 1, BS)).astype(np.float32)
    yhat = np.asarray(pred(state, jnp.array(X)))
    mse = float(np.mean((yhat - y) ** 2))
    state = upd(state, jnp.array(X), jnp.array(y))
    if step % 10 == 0:
        print(f"  step {step:3d}  prequential mse={mse:7.3f}  "
              f"leaves={int(ht.n_leaves(state))}")

print(f"final tree: {int(state['n_nodes'])} nodes, "
      f"{int(ht.n_leaves(state))} leaves")
