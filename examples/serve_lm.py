"""Batched serving example: prefill a prompt batch, decode with a KV cache.

    PYTHONPATH=src python examples/serve_lm.py

Runs the same ``serve_step`` code paths the 512-chip dry-run compiles
(prefill + single-token decode against a persistent cache), on a local
mesh with a reduced h2o-danube config — exercising the sliding-window
ring cache (the sub-quadratic path that makes long_500k feasible).
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.configs import ShapeConfig, reduced
from repro.launch.mesh import make_local_mesh
from repro.models import layers as L
from repro.models import model as M
from repro.train import steps as ST

L.set_compute_dtype(jnp.float32)

cfg = reduced(configs.get_arch("h2o-danube-3-4b"), d_model=256, n_layers=4,
              n_heads=8, n_kv_heads=4, d_ff=768, vocab=4096, head_dim=32,
              swa_window=64)
B, PROMPT, GEN, MAXSEQ = 4, 96, 32, 160
mesh = make_local_mesh(1, 1)
shape = ShapeConfig("serve", MAXSEQ, B, "decode")
prefill, decode, _ = ST.build_serve_steps(cfg, shape, mesh, kv_chunk=32)

with mesh:
    params = jax.jit(lambda k: M.init_params(k, cfg))(jax.random.PRNGKey(0))
    cache = jax.jit(lambda: M.init_cache(cfg, B, MAXSEQ))()
    assert "pos" in cache["attn"], "SWA ring cache active (window=64 < 160)"
    prompt = jax.random.randint(jax.random.PRNGKey(1), (B, PROMPT), 0, cfg.vocab)

    t0 = time.perf_counter()
    cache, logits = prefill(params, {"tokens": prompt}, cache)
    jax.block_until_ready(logits)
    print(f"prefill: {B} x {PROMPT} tokens in {time.perf_counter()-t0:.2f}s "
          f"(window={cfg.swa_window}, ring slots={cache['attn']['k'].shape[2]})")

    tok = jnp.argmax(logits, -1)
    out = [np.asarray(tok)]
    t0 = time.perf_counter()
    for i in range(GEN):
        logits, cache = decode(params, tok, cache, jnp.int32(PROMPT + i))
        tok = jnp.argmax(logits, -1)
        out.append(np.asarray(tok))
    jax.block_until_ready(logits)
    dt = time.perf_counter() - t0
    print(f"decode: {GEN} steps x {B} seqs in {dt:.2f}s "
          f"({GEN*B/dt:.1f} tok/s)")
    gen = np.stack(out, 1)
    assert gen.shape == (B, GEN + 1)
    assert np.isfinite(np.asarray(logits)).all()
    print("sample token ids:", gen[0][:16].tolist())
    print("OK")
