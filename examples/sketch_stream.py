"""Quantile-sketch observer vs dense QO tables on a heavy-tail stream
(DESIGN.md §2.8).

    PYTHONPATH=src python examples/sketch_stream.py

Same tree, two observers: ``observer_backend="qo"`` keeps a dense
(M, F, C) bin grid per leaf; ``observer_backend="sketch"`` keeps K
rank-bucketed centroids per (leaf, feature) — O(K·F) state that places
its candidate boundaries where the mass lives.  On a lognormal stream
with 1% far outliers the sketch at K=16 slots BEATS the dense
observer's prequential MSE (~3x here) while carrying 4x less observer
state: the outliers stretch the grid's range so its fixed bins blur
the bulk, while rank buckets are immune to range by construction
(benchmarks/sketch.py quantifies this as the ≥10x equivalent-capacity
gate).
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hoeffding as ht

rng = np.random.default_rng(0)
F, BS, STEPS = 4, 256, 80


def batch():
    X = rng.lognormal(0.0, 1.0, (BS, F))
    out = rng.random((BS, F)) < 0.01                 # 1% far outliers
    X = np.where(out, rng.uniform(1e3, 5e3, (BS, F)), X).astype(np.float32)
    y = (np.where(X[:, 0] > 1.0, 2.0, 0.0) + np.log1p(X[:, 1])
         + 0.1 * rng.normal(0, 1, BS)).astype(np.float32)
    return jnp.array(X), jnp.array(y)


runs = {}
for observer in ("qo", "sketch"):
    cfg = ht.HTRConfig(n_features=F, max_nodes=63, n_bins=64,
                       grace_period=250, max_depth=8, r0=0.3,
                       observer_backend=observer, sketch_k=16)
    state = ht.init_state(cfg)
    upd = jax.jit(functools.partial(ht.update, cfg))
    pred = jax.jit(functools.partial(ht.predict, cfg))
    slots = cfg.observer_bins()
    print(f"observer={observer}: {slots} slots/(leaf,feature), "
          f"{cfg.max_nodes * F * slots * 4 * 4 // 1024} KiB observer state")
    rng = np.random.default_rng(7)                   # same stream per run
    mses = []
    for step in range(STEPS):
        X, y = batch()
        yhat = np.asarray(pred(state, X))            # test-then-train
        mses.append(float(np.mean((np.asarray(y) - yhat) ** 2)))
        state = upd(state, X, y)
        if step % 20 == 19:
            print(f"  step {step:3d}  prequential mse="
                  f"{np.mean(mses[-20:]):7.3f}  "
                  f"leaves={int(ht.n_leaves(state))}")
    runs[observer] = np.mean(mses[STEPS // 2:])

ratio = runs["sketch"] / runs["qo"]
print(f"\nsecond-half prequential MSE: qo={runs['qo']:.3f}  "
      f"sketch={runs['sketch']:.3f}  (ratio {ratio:.2f} at 4x less state)")
