"""End-to-end driver: train a ~100M-parameter qwen3-family model for a few
hundred steps with the full production stack — sharded step, AdamW,
fault-tolerant loop (async checkpoints, auto-resume, NaN-skip), QO
telemetry — on whatever devices exist.

    PYTHONPATH=src python examples/train_lm.py --steps 300

On this CPU container a ~100M config at seq 256 is slow; the default is a
~10M config that finishes in minutes.  --big selects the true ~100M one.
Kill it mid-run and run it again: it resumes from the latest checkpoint.
"""
import argparse
import json

import jax
import jax.numpy as jnp

from repro import configs
from repro.configs import ShapeConfig, reduced
from repro.data.tokens import TokenStream
from repro.launch.mesh import make_local_mesh
from repro.models import layers as L
from repro.optim import adamw
from repro.train import monitor as MON
from repro.train.loop import LoopConfig, Trainer

L.set_compute_dtype(jnp.float32)

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=200)
ap.add_argument("--big", action="store_true", help="~100M params")
ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
args = ap.parse_args()

if args.big:  # ~100M params
    cfg = reduced(configs.get_arch("qwen3-8b"), d_model=768, n_layers=12,
                  n_heads=12, n_kv_heads=4, d_ff=2048, vocab=32000,
                  head_dim=64)
    seq, batch = 512, 8
else:  # ~10M params, minutes on 1 CPU
    cfg = reduced(configs.get_arch("qwen3-8b"), d_model=256, n_layers=4,
                  n_heads=8, n_kv_heads=4, d_ff=768, vocab=8192, head_dim=32)
    seq, batch = 256, 8

n_params = cfg.n_params()
print(f"arch=qwen3-family  params~{n_params/1e6:.1f}M  "
      f"seq={seq} batch={batch} steps={args.steps}")

mesh = make_local_mesh(1, 1)
shape = ShapeConfig("example", seq, batch, "train")
data = TokenStream(vocab=cfg.vocab, seq_len=seq, global_batch=batch, seed=0)
lc = LoopConfig(total_steps=args.steps, ckpt_every=50, log_every=10,
                ckpt_dir=args.ckpt_dir, kv_chunk=128)
opt = adamw.AdamWConfig(lr=1e-3, total_steps=args.steps,
                        warmup_steps=max(10, args.steps // 20))

trainer = Trainer(cfg, shape, mesh, data, lc, opt)
_, _, mon, history = trainer.run(
    log_fn=lambda r: print(json.dumps(r), flush=True))

first = next(r["loss"] for r in history if "loss" in r)
last = [r["loss"] for r in history if "loss" in r][-1]
print(f"\nloss {first:.3f} -> {last:.3f}")
print("telemetry:", json.dumps({
    k: {kk: round(float(vv), 4) for kk, vv in s.items()}
    for k, s in MON.summaries(mon).items()}, indent=1))
assert last < first, "training must reduce loss"
