"""Quickstart: the paper's QO observer in 40 lines.

    PYTHONPATH=src python examples/quickstart.py

Monitors a synthetic stream with QO, E-BST and TE-BST, prints the split
each one proposes, their memory footprint, and validates that the QO
split is within a whisker of the exhaustive baseline — the paper's core
claim (Fig. 1) on one screen.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ebst, qo
from repro.data import synth

# a stream where the best split is x <= 0.3
rng = np.random.default_rng(0)
x = rng.normal(0, 1, 20_000).astype(np.float32)
y = np.where(x <= 0.3, 1.0, 6.0).astype(np.float32) + \
    0.1 * rng.normal(0, 1, 20_000).astype(np.float32)

print(f"stream: n={len(x)}, planted split at x=0.3\n")

# --- Quantizer Observer (the paper's contribution) -----------------------
sigma = float(np.std(x))
coarse = qo.init(capacity=512, radius=sigma / 2, origin=float(np.mean(x)))
coarse = qo.update(coarse, jnp.array(x), jnp.array(y))  # O(1)/element
rc = qo.best_split(coarse)                               # sub-linear query
print(f"QO (r=sigma/2)   split={float(rc.threshold):+.4f}  "
      f"merit={float(rc.merit):.4f}  elements={int(qo.n_slots(coarse))}")

table = qo.init(capacity=1024, radius=0.01, origin=float(np.mean(x)))
table = qo.update(table, jnp.array(x), jnp.array(y))
split = qo.best_split(table)
print(f"QO (r=0.01)      split={float(split.threshold):+.4f}  "
      f"merit={float(split.merit):.4f}  elements={int(qo.n_slots(table))}")

# --- E-BST baseline (what ODTs used before) -------------------------------
t = ebst.init(len(x))
t = jax.jit(ebst.update)(t, jnp.array(x), jnp.array(y))   # O(log n)/element
r = jax.jit(ebst.best_split)(t)                            # O(n) query
print(f"E-BST            split={float(r.threshold):+.4f}  "
      f"merit={float(r.merit):.4f}  elements={int(t['size'])}")

# --- TE-BST (truncated) ----------------------------------------------------
t3 = ebst.init(len(x), decimals=3)
t3 = jax.jit(ebst.update)(t3, jnp.array(x), jnp.array(y))
r3 = jax.jit(ebst.best_split)(t3)
print(f"TE-BST (3 dec)   split={float(r3.threshold):+.4f}  "
      f"merit={float(r3.merit):.4f}  elements={int(t3['size'])}")

ratio = int(t["size"]) / int(qo.n_slots(table))
print(f"\nQO stores {ratio:.0f}x fewer elements than E-BST "
      f"with {float(split.merit) / float(r.merit) * 100:.1f}% of its merit.")
assert abs(float(split.threshold) - float(r.threshold)) < 0.1
print("OK")
