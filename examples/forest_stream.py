"""Online-bagged forest of QO Hoeffding regressors on a drifting stream.

    PYTHONPATH=src python examples/forest_stream.py

Eight trees learn the stream as ONE vmapped program: every instance
reaches every tree with a Poisson(6) sample weight (online bagging), each
tree splits only inside its random feature subspace, and the forest
prediction is the inverse-error-weighted member vote.  Halfway through,
the concept drifts; the per-member ADWIN-style error windows detect it
and swap the worst member for a fresh tree, which the vote then follows.
On a multi-device host the same forest shards over the tree axis via
``repro.train.sharding.build_sharded_forest``.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import forest as fr
from repro.core import hoeffding as ht
from repro.data.synth import piecewise_target

rng = np.random.default_rng(0)
F, BS, T = 4, 256, 8
tree_cfg = ht.HTRConfig(n_features=F, max_nodes=63, n_bins=48,
                        grace_period=250, max_depth=8, r0=0.3)
cfg = fr.ForestConfig(tree=tree_cfg, n_trees=T)
state = fr.init_forest(cfg, jax.random.PRNGKey(0))
upd = jax.jit(functools.partial(fr.update, cfg))


for phase, (shift, steps) in enumerate(((0.0, 60), (0.8, 60))):
    print(f"phase {phase + 1}: "
          + ("stationary stream" if phase == 0 else
             "drift (split point moves 0.0 -> 0.8)"))
    for step in range(steps):
        X = rng.normal(0, 1, (BS, F)).astype(np.float32)
        y = (piecewise_target(X, shift)
             + 0.1 * rng.normal(0, 1, BS)).astype(np.float32)
        state, aux = upd(state, jnp.array(X), jnp.array(y))  # test-then-train
        if step % 10 == 0:
            leaves = np.asarray(fr.n_leaves_per_tree(state))
            print(f"  step {step:3d}  prequential mse={float(aux['forest_mse']):7.3f}  "
                  f"best member={float(np.asarray(aux['member_mse']).min()):7.3f}  "
                  f"leaves/tree={leaves.mean():.1f}  "
                  f"resets={int(np.asarray(state['resets']).sum())}")

resets = np.asarray(state["resets"])
print(f"final forest: {T} trees, "
      f"{np.asarray(fr.n_leaves_per_tree(state)).sum()} total leaves, "
      f"{int(resets.sum())} drift resets {resets.tolist()}")
assert int(resets.sum()) >= 1, "the drift should have tripped a member swap"
print("OK")
