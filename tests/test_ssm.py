"""SSM mixer tests: scan-vs-SSD equivalence, decode-vs-train consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.configs import reduced
from repro.models import ssm as S

KEY = jax.random.PRNGKey(0)


@pytest.fixture(autouse=True)
def _reset_impl():
    yield
    S.set_mamba2_impl("scan")


def test_mamba2_ssd_equals_scan():
    """The SSD quadratic form is algebraically the same recurrence."""
    cfg = reduced(configs.get_arch("zamba2-2.7b"))
    p = S.mamba2_params(KEY, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, cfg.d_model)) * 0.5
    S.set_mamba2_impl("scan")
    y1, c1 = S.mamba2(p, x, cfg, chunk=16)
    S.set_mamba2_impl("ssd")
    y2, c2 = S.mamba2(p, x, cfg, chunk=16)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=2e-3, atol=2e-4)
    np.testing.assert_allclose(np.asarray(c1["ssm"]), np.asarray(c2["ssm"]),
                               rtol=2e-3, atol=2e-4)


@pytest.mark.parametrize("impl", ["scan", "ssd"])
def test_mamba2_decode_matches_parallel(impl):
    """Recurrent decode step == parallel scan at the same position."""
    cfg = reduced(configs.get_arch("zamba2-2.7b"))
    p = S.mamba2_params(KEY, cfg)
    x = jax.random.normal(jax.random.PRNGKey(2), (1, 9, cfg.d_model)) * 0.5
    S.set_mamba2_impl(impl)
    y_par, _ = S.mamba2(p, x, cfg, chunk=4)
    # stream one token at a time through a decode cache
    nh = cfg.d_inner // cfg.ssm_head_dim
    cache = {"ssm": jnp.zeros((1, nh, cfg.ssm_head_dim, cfg.ssm_state)),
             "conv": {"x": jnp.zeros((1, S.CONV_K - 1, cfg.d_inner)),
                      "B": jnp.zeros((1, S.CONV_K - 1, cfg.ssm_state)),
                      "C": jnp.zeros((1, S.CONV_K - 1, cfg.ssm_state))}}
    outs = []
    for t in range(9):
        y, cache = S.mamba2(p, x[:, t:t + 1], cfg, cache=cache)
        outs.append(y)
    y_seq = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_par), np.asarray(y_seq),
                               rtol=5e-3, atol=5e-4)


def test_mamba1_decode_matches_parallel():
    cfg = reduced(configs.get_arch("falcon-mamba-7b"))
    p = S.mamba1_params(KEY, cfg)
    x = jax.random.normal(jax.random.PRNGKey(3), (1, 8, cfg.d_model)) * 0.5
    y_par, _ = S.mamba1(p, x, cfg, chunk=4)
    cache = {"ssm": jnp.zeros((1, cfg.d_inner, cfg.ssm_state)),
             "conv": jnp.zeros((1, S.CONV_K - 1, cfg.d_inner))}
    outs = []
    for t in range(8):
        y, cache = S.mamba1(p, x[:, t:t + 1], cfg, cache=cache)
        outs.append(y)
    y_seq = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_par), np.asarray(y_seq),
                               rtol=5e-3, atol=5e-4)


def test_mamba_chunk_size_invariance():
    """Output must not depend on the chunking."""
    cfg = reduced(configs.get_arch("falcon-mamba-7b"))
    p = S.mamba1_params(KEY, cfg)
    x = jax.random.normal(jax.random.PRNGKey(4), (2, 32, cfg.d_model)) * 0.5
    y8, _ = S.mamba1(p, x, cfg, chunk=8)
    y32, _ = S.mamba1(p, x, cfg, chunk=32)
    np.testing.assert_allclose(np.asarray(y8), np.asarray(y32),
                               rtol=2e-3, atol=2e-4)
