"""Distributed sketch + QO telemetry tests."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import qo, sketch
from repro.train import monitor as MON


def test_quantile_accuracy(rng):
    x = rng.normal(10, 3, 50000).astype(np.float32)
    t = qo.update(qo.init(512, radius=0.1, origin=10.0), jnp.array(x),
                  jnp.array(x))
    for q in (0.1, 0.5, 0.9, 0.99):
        est = float(sketch.quantile(t, jnp.asarray(q)))
        true = float(np.quantile(x, q))
        assert abs(est - true) < 0.15, (q, est, true)


def test_all_merge_across_devices():
    """shard_map all_merge == single-stream table (1 device => trivial but
    exercises the collective path; multi-device covered in test_sharding)."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    from repro.launch.mesh import make_mesh_auto
    mesh = make_mesh_auto((1,), ("d",))
    rng = np.random.default_rng(0)
    x = rng.normal(0, 1, 1024).astype(np.float32)

    def f(xs):
        t = qo.update(qo.init(64, radius=0.2), xs, xs)
        return sketch.all_merge(t, "d")

    out = jax.jit(shard_map(f, mesh=mesh, in_specs=P("d"), out_specs=P(),
                            check_rep=False))(jnp.array(x))
    ref = qo.update(qo.init(64, radius=0.2), jnp.array(x), jnp.array(x))
    np.testing.assert_allclose(np.asarray(out["y"]["n"]),
                               np.asarray(ref["y"]["n"]), atol=1e-3)


def test_monitor_observe_and_alerts():
    mon = MON.init_monitor()
    for i in range(100):
        mon = MON.observe(mon, loss=jnp.float32(5.0 + 0.01 * i),
                          grad_norm=jnp.float32(1.0),
                          step_time=jnp.float32(1.0))
    assert not bool(MON.loss_spike(mon, jnp.float32(5.5)))
    assert bool(MON.loss_spike(mon, jnp.float32(50.0)))
    assert not bool(MON.is_straggler(mon, jnp.float32(1.0)))
    assert bool(MON.is_straggler(mon, jnp.float32(10.0)))
    s = MON.summaries(mon)
    assert abs(float(s["step_time"]["mean"]) - 1.0) < 1e-3
    assert float(s["loss"]["count"]) == 100
