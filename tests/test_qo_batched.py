"""Forest-scale batched kernels vs the jnp reference (interpret mode).

Property coverage demanded by the batched-QO pipeline: ragged batches
(B not a tile multiple), empty leaves (no routed rows), and tables with a
single occupied bin (no valid boundary).  Acceptance bar: bin counts and
VR scores within 1e-4 of the per-table :mod:`repro.core.qo` oracle.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import hoeffding as ht
from repro.core import stats
from repro.data import synth
from repro.kernels import ops, ref
from repro.kernels.qo_update_leaves import pack_forest, unpack_forest

TOL = 1e-4


def _random_forest(rng, M, F, C, occupied_frac=1.0):
    """A forest state built by streaming random rows through the oracle."""
    ao_y = stats.init((M, F, C))
    ao_sum_x = jnp.zeros((M, F, C))
    ao_radius = jnp.array(rng.uniform(0.05, 0.4, (M, F)).astype(np.float32))
    ao_origin = jnp.array(rng.normal(0, 0.5, (M, F)).astype(np.float32))
    B = 160
    leaf = jnp.array(rng.integers(0, max(1, int(M * occupied_frac)), B),
                     jnp.int32)
    X = jnp.array(rng.normal(0, 1, (B, F)).astype(np.float32))
    y = jnp.array(rng.normal(0, 2, B).astype(np.float32))
    ao_y, ao_sum_x = ref.forest_update_ref(
        ao_y, ao_sum_x, ao_radius, ao_origin, leaf, X, y)
    return ao_y, ao_sum_x, ao_radius, ao_origin


@pytest.mark.parametrize("B", [1, 37, 129, 256])
def test_update_leaves_kernel_matches_oracle_ragged(B, rng):
    """Ragged batch sizes: padding rows must contribute nothing."""
    M, F, C = 9, 3, 48
    ao_y = stats.init((M, F, C))
    ao_sum_x = jnp.zeros((M, F, C))
    ao_radius = jnp.array(rng.uniform(0.05, 0.4, (M, F)).astype(np.float32))
    ao_origin = jnp.array(rng.normal(0, 0.5, (M, F)).astype(np.float32))
    # leaf 0 never routed -> stays empty through the kernel too
    leaf = jnp.array(rng.integers(1, M, B), jnp.int32)
    X = jnp.array(rng.normal(0, 1, (B, F)).astype(np.float32))
    y = jnp.array(rng.normal(0, 2, B).astype(np.float32))

    ry, rsx = ref.forest_update_ref(ao_y, ao_sum_x, ao_radius, ao_origin,
                                    leaf, X, y)
    for backend in ("interpret", "jnp"):
        ky, ksx = ops.forest_update(ao_y, ao_sum_x, ao_radius, ao_origin,
                                    leaf, X, y, backend=backend)
        for k in ("n", "mean", "m2"):
            np.testing.assert_allclose(np.asarray(ky[k]), np.asarray(ry[k]),
                                       atol=TOL, rtol=TOL,
                                       err_msg=f"{backend}:{k}")
        np.testing.assert_allclose(np.asarray(ksx), np.asarray(rsx),
                                   atol=TOL, rtol=TOL)
        # empty leaf stays exactly empty
        assert float(jnp.abs(ky["n"][0]).max()) == 0.0


def test_update_leaves_kernel_weighted_and_incremental(rng):
    """Two seeded kernel calls == one oracle pass over the concatenation."""
    M, F, C = 6, 2, 48
    ao_y = stats.init((M, F, C))
    ao_sum_x = jnp.zeros((M, F, C))
    ao_radius = jnp.full((M, F), 0.2, jnp.float32)
    ao_origin = jnp.zeros((M, F), jnp.float32)
    B = 120
    leaf = jnp.array(rng.integers(0, M, B), jnp.int32)
    X = jnp.array(rng.normal(0, 1, (B, F)).astype(np.float32))
    y = jnp.array(rng.normal(0, 1, B).astype(np.float32))
    w = jnp.array(rng.uniform(0.1, 2.0, B).astype(np.float32))

    ky, ksx = ops.forest_update(ao_y, ao_sum_x, ao_radius, ao_origin,
                                leaf[:60], X[:60], y[:60], w[:60],
                                backend="interpret")
    ky, ksx = ops.forest_update(ky, ksx, ao_radius, ao_origin,
                                leaf[60:], X[60:], y[60:], w[60:],
                                backend="interpret")
    ry, rsx = ref.forest_update_ref(ao_y, ao_sum_x, ao_radius, ao_origin,
                                    leaf, X, y, w)
    for k in ("n", "mean", "m2"):
        np.testing.assert_allclose(np.asarray(ky[k]), np.asarray(ry[k]),
                                   atol=5e-4, rtol=5e-4, err_msg=k)
    np.testing.assert_allclose(np.asarray(ksx), np.asarray(rsx),
                               atol=5e-4, rtol=5e-4)


@pytest.mark.parametrize("backend", ["interpret", "jnp"])
def test_query_batched_matches_oracle(backend, rng):
    M, F, C = 12, 3, 48
    ao_y, ao_sum_x, ao_radius, ao_origin = _random_forest(rng, M, F, C)
    attempt = jnp.array(rng.uniform(size=M) < 0.6)

    rm, rt = ref.forest_query_ref(ao_y, ao_sum_x, attempt)
    km, kt = ops.forest_best_splits(ao_y, ao_sum_x, ao_radius, ao_origin,
                                    attempt, backend=backend)
    rm, rt = np.asarray(rm), np.asarray(rt)
    km, kt = np.asarray(km), np.asarray(kt)
    valid = np.isfinite(rm)
    assert (np.isfinite(km) == valid).all(), "validity mask must agree"
    np.testing.assert_allclose(km[valid], rm[valid], atol=TOL, rtol=TOL)
    np.testing.assert_allclose(kt[valid], rt[valid], atol=TOL, rtol=TOL)


def test_query_batched_empty_and_single_bin_tables(rng):
    """Empty tables and single-occupied-bin tables -> no valid boundary."""
    M, F, C = 4, 2, 48
    ao_y = stats.init((M, F, C))
    ao_sum_x = jnp.zeros((M, F, C))
    ao_radius = jnp.full((M, F), 0.1, jnp.float32)
    ao_origin = jnp.zeros((M, F), jnp.float32)
    # leaf 1: every observation lands in ONE bin (identical x)
    leaf = jnp.full((50,), 1, jnp.int32)
    X = jnp.zeros((50, F), jnp.float32)
    y = jnp.array(rng.normal(0, 1, 50).astype(np.float32))
    ao_y, ao_sum_x = ref.forest_update_ref(ao_y, ao_sum_x, ao_radius,
                                           ao_origin, leaf, X, y)
    # leaf 2: a real two-cluster table
    leaf2 = jnp.full((60,), 2, jnp.int32)
    X2 = jnp.array(np.repeat([[-1.0], [1.0]], 30, 0).astype(np.float32))
    X2 = jnp.tile(X2, (1, F))
    y2 = jnp.array(np.repeat([0.0, 5.0], 30).astype(np.float32))
    ao_y, ao_sum_x = ref.forest_update_ref(ao_y, ao_sum_x, ao_radius,
                                           ao_origin, leaf2, X2, y2)

    attempt = jnp.ones((M,), bool)
    for backend in ("interpret", "jnp"):
        km, kt = ops.forest_best_splits(ao_y, ao_sum_x, ao_radius, ao_origin,
                                        attempt, backend=backend)
        km = np.asarray(km)
        assert not np.isfinite(km[0]).any(), "empty leaf must be invalid"
        assert not np.isfinite(km[1]).any(), "single-bin tables are invalid"
        assert np.isfinite(km[2]).all(), "two-cluster tables must be valid"
        # the split must separate the clusters
        assert (-1.0 < np.asarray(kt)[2]).all() and (np.asarray(kt)[2] < 1.0).all()
        # masked leaves report -inf even with valid tables
        km_masked, _ = ops.forest_best_splits(
            ao_y, ao_sum_x, ao_radius, ao_origin,
            jnp.zeros((M,), bool), backend=backend)
        assert not np.isfinite(np.asarray(km_masked)).any()


def test_pack_unpack_roundtrip(rng):
    M, F, C = 13, 3, 48
    ao_y, ao_sum_x, ao_radius, ao_origin = _random_forest(rng, M, F, C)
    dense = pack_forest(ao_y, ao_sum_x, ao_radius, ao_origin)
    uy, usx = unpack_forest(dense, M, C)
    for k in ("n", "mean", "m2"):
        np.testing.assert_array_equal(np.asarray(uy[k]), np.asarray(ao_y[k]))
    np.testing.assert_array_equal(np.asarray(usx), np.asarray(ao_sum_x))


def test_tree_backends_agree_end_to_end():
    """jnp fast path and oracle backend grow near-identical trees."""
    X, y = synth.piecewise_regression(6000, n_features=3, seed=9)
    trees = {}
    for backend in ("jnp", "oracle"):
        cfg = ht.HTRConfig(n_features=3, max_nodes=31, n_bins=32,
                           grace_period=200, max_depth=6, r0=0.3,
                           split_backend=backend)
        s = ht.init_state(cfg)
        upd = jax.jit(functools.partial(ht.update, cfg))
        for i in range(0, 6000 - 255, 256):
            s = upd(s, jnp.array(X[i:i + 256]), jnp.array(y[i:i + 256]))
        trees[backend] = (cfg, s)
    cfg_j, s_j = trees["jnp"]
    cfg_o, s_o = trees["oracle"]
    assert int(s_j["n_nodes"]) == int(s_o["n_nodes"])
    Xt, yt = synth.piecewise_regression(1500, n_features=3, seed=99)
    p_j = np.asarray(ht.predict(cfg_j, s_j, jnp.array(Xt)))
    p_o = np.asarray(ht.predict(cfg_o, s_o, jnp.array(Xt)))
    mse_j = float(np.mean((p_j - yt) ** 2))
    mse_o = float(np.mean((p_o - yt) ** 2))
    assert abs(mse_j - mse_o) <= 0.01 * max(mse_o, 1e-9)


def test_update_stream_matches_batch_loop():
    """One-dispatch scan driver == the per-batch python loop."""
    X, y = synth.piecewise_regression(4096, n_features=2, seed=4)
    cfg = ht.HTRConfig(n_features=2, max_nodes=15, n_bins=32,
                       grace_period=150, max_depth=4, r0=0.3)
    s_loop = ht.init_state(cfg)
    upd = jax.jit(functools.partial(ht.update, cfg))
    for i in range(0, 4096, 256):
        s_loop = upd(s_loop, jnp.array(X[i:i + 256]), jnp.array(y[i:i + 256]))
    s_scan = ht.update_stream(cfg, ht.init_state(cfg), jnp.array(X),
                              jnp.array(y), batch_size=256)
    assert int(s_loop["n_nodes"]) == int(s_scan["n_nodes"])
    np.testing.assert_array_equal(np.asarray(s_loop["is_leaf"]),
                                  np.asarray(s_scan["is_leaf"]))
    np.testing.assert_allclose(np.asarray(s_loop["ystats"]["mean"]),
                               np.asarray(s_scan["ystats"]["mean"]),
                               rtol=1e-5, atol=1e-5)
