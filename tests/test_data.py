"""Data pipeline tests: paper generators + deterministic token stream."""
import numpy as np

from repro.data import synth
from repro.data.tokens import TokenStream


def test_paper_distributions_cover_table1():
    for dist, variants in synth.DISTRIBUTIONS.items():
        for v in range(len(variants)):
            for task in synth.TASKS:
                cfg = synth.SynthConfig(dist=dist, variant=v, task=task,
                                        n=500, seed=0)
                x, y = synth.generate(cfg)
                assert x.shape == y.shape == (500,)
                assert np.isfinite(x).all() and np.isfinite(y).all()


def test_generator_deterministic_per_seed():
    c = synth.SynthConfig(dist="bimodal", variant=2, task="cub", n=1000, seed=4)
    x1, y1 = synth.generate(c)
    x2, y2 = synth.generate(c)
    np.testing.assert_array_equal(x1, x2)
    np.testing.assert_array_equal(y1, y2)
    x3, _ = synth.generate(synth.SynthConfig(dist="bimodal", variant=2,
                                             task="cub", n=1000, seed=5))
    assert not np.array_equal(x1, x3)


def test_bimodal_asymmetric_variant():
    c = synth.SynthConfig(dist="bimodal", variant=2, n=20000, seed=0)
    x, _ = synth.generate(c)
    # modes at -7 (sigma 7, wide) and +7 (sigma 0.1, tight): ~half the mass
    # must sit in a narrow window around +7
    tight = np.abs(x - 7.0) < 0.5
    assert tight.mean() > 0.40
    assert np.std(x[tight]) < 0.2
    left = x[x < 0]
    assert np.std(left) > 3.0


def test_token_stream_skip_ahead_determinism():
    """batch(i) is a pure function of (seed, i): the restart guarantee."""
    s = TokenStream(vocab=128, seq_len=16, global_batch=4, seed=9)
    b5a = s.host_batch(5)
    # simulate a fresh process that resumes at step 5
    s2 = TokenStream(vocab=128, seq_len=16, global_batch=4, seed=9)
    b5b = s2.host_batch(5)
    np.testing.assert_array_equal(b5a["tokens"], b5b["tokens"])
    b6 = s.host_batch(6)
    assert not np.array_equal(b5a["tokens"], b6["tokens"])


def test_token_stream_learnable_structure():
    """Labels shift tokens by one: next-token prediction is well-posed."""
    s = TokenStream(vocab=64, seq_len=32, global_batch=2, seed=0)
    b = s.host_batch(0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])
    assert b["tokens"].min() >= 0 and b["tokens"].max() < 64
