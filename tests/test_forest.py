"""Online-bagged QO Hoeffding forest: growth, diversity, drift, sharding."""
import functools
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import forest as fr
from repro.core import hoeffding as ht
from repro.data import synth

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _small_cfg(**kw):
    tree = ht.HTRConfig(n_features=4, max_nodes=31, n_bins=32,
                        grace_period=200, max_depth=6, r0=0.25)
    return fr.ForestConfig(tree=tree, **kw)


def test_forest_learns_and_beats_mean_predictor():
    cfg = _small_cfg(n_trees=4)
    state = fr.init_forest(cfg, jax.random.PRNGKey(0))
    X, y = synth.piecewise_regression(6000, n_features=4, seed=11)
    state, trace = fr.update_stream(cfg, state, jnp.array(X), jnp.array(y))
    Xt, yt = synth.piecewise_regression(2000, n_features=4, seed=101)
    pred = np.asarray(fr.predict(cfg, state, jnp.array(Xt)))
    mse = float(np.mean((pred - yt) ** 2))
    assert mse < 0.25 * float(np.var(yt)), mse
    assert (np.asarray(fr.n_leaves_per_tree(state)) > 1).all()
    # prequential trace improves over the stream
    f = np.asarray(trace["forest_mse"])
    assert f[-3:].mean() < f[:3].mean()


def test_bagging_and_subspaces_decorrelate_members():
    """Poisson weights + random subspaces must yield distinct members."""
    cfg = _small_cfg(n_trees=6, subspace=0.5)
    state = fr.init_forest(cfg, jax.random.PRNGKey(1))
    masks = np.asarray(state["feat_mask"])
    assert masks.sum(1).min() == cfg.subspace_k()
    assert len({tuple(m) for m in masks}) > 1, "identical subspaces"
    X, y = synth.piecewise_regression(5000, n_features=4, seed=3)
    state, _ = fr.update_stream(cfg, state, jnp.array(X), jnp.array(y))
    yhat = np.asarray(fr.member_predictions(cfg, state, jnp.array(X[:256])))
    spread = yhat.std(axis=0).mean()
    assert spread > 1e-3, "members collapsed to one predictor"


def test_forest_update_stream_matches_python_loop():
    """The one-dispatch scan driver == per-batch python loop (same keys)."""
    cfg = _small_cfg(n_trees=3)
    X, y = synth.piecewise_regression(2048, n_features=4, seed=4)
    s_loop = fr.init_forest(cfg, jax.random.PRNGKey(2))
    upd = jax.jit(functools.partial(fr.update, cfg))
    for i in range(0, 2048, 256):
        s_loop, _ = upd(s_loop, jnp.array(X[i:i + 256]),
                        jnp.array(y[i:i + 256]))
    s_scan, _ = fr.update_stream(cfg, fr.init_forest(cfg, jax.random.PRNGKey(2)),
                                 jnp.array(X), jnp.array(y), batch_size=256)
    np.testing.assert_array_equal(np.asarray(s_loop["trees"]["n_nodes"]),
                                  np.asarray(s_scan["trees"]["n_nodes"]))
    np.testing.assert_allclose(
        np.asarray(s_loop["trees"]["ystats"]["mean"]),
        np.asarray(s_scan["trees"]["ystats"]["mean"]), rtol=1e-5, atol=1e-5)


def test_forest_update_stream_learns_ragged_tail():
    """N not divisible by batch_size: the scan driver processes the tail
    as a masked final batch — identical to a python loop whose last call
    carries the same weight-0 padding rows (same PRNG stream)."""
    cfg = _small_cfg(n_trees=3)
    N, bs = 700, 256                       # 2 full batches + 188 tail rows
    X, y = synth.piecewise_regression(N, n_features=4, seed=6)
    pad = 3 * bs - N                       # pad rows of the final batch
    Xp = np.concatenate([X, np.zeros((pad, 4), np.float32)])
    yp = np.concatenate([y, np.zeros(pad, np.float32)])
    wp = (np.arange(3 * bs) < N).astype(np.float32)
    s_loop = fr.init_forest(cfg, jax.random.PRNGKey(5))
    upd = jax.jit(functools.partial(fr.update, cfg))
    for i in range(3):
        s_loop, _ = upd(s_loop, jnp.array(Xp[i * bs:(i + 1) * bs]),
                        jnp.array(yp[i * bs:(i + 1) * bs]),
                        w=jnp.array(wp[i * bs:(i + 1) * bs]))
    s_scan, trace = fr.update_stream(cfg, fr.init_forest(cfg,
                                                         jax.random.PRNGKey(5)),
                                     jnp.array(X), jnp.array(y),
                                     batch_size=bs)
    assert trace["forest_mse"].shape[0] == 3     # ceil(700 / 256)
    np.testing.assert_array_equal(np.asarray(s_loop["trees"]["n_nodes"]),
                                  np.asarray(s_scan["trees"]["n_nodes"]))
    np.testing.assert_allclose(
        np.asarray(s_loop["trees"]["ystats"]["mean"]),
        np.asarray(s_scan["trees"]["ystats"]["mean"]), rtol=1e-5, atol=1e-5)


def test_forest_update_ignores_weight0_rows():
    """Rows with weight 0 are invisible: garbage in the padded slots must
    not change the learned forest, the drift windows, or the aux errors."""
    cfg = _small_cfg(n_trees=3)
    rng = np.random.default_rng(2)
    X = rng.normal(0, 1, (256, 4)).astype(np.float32)
    y = (X[:, 0] * 2).astype(np.float32)
    w = (np.arange(256) < 200).astype(np.float32)
    Xg, yg = X.copy(), y.copy()
    Xg[200:] = 1e6                          # garbage in the masked rows
    yg[200:] = -1e6
    s0 = fr.init_forest(cfg, jax.random.PRNGKey(7))
    s_a, aux_a = fr.update(cfg, s0, jnp.array(X), jnp.array(y),
                           w=jnp.array(w))
    s_b, aux_b = fr.update(cfg, s0, jnp.array(Xg), jnp.array(yg),
                           w=jnp.array(w))
    flat_a = jax.tree_util.tree_leaves(s_a)
    flat_b = jax.tree_util.tree_leaves(s_b)
    for a, b in zip(flat_a, flat_b):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(aux_a["member_mse"]),
                                  np.asarray(aux_b["member_mse"]))


def test_masked_tail_batch_cannot_fire_spurious_drift():
    """A ragged tail batch holding one real outlier row advances the
    drift windows by its real-mass fraction only — it must not swap a
    trained member where the same outliers at full batch weight would."""
    cfg = _small_cfg(n_trees=4, drift_min_batches=8, drift_kappa=3.0)
    state = fr.init_forest(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    upd = jax.jit(functools.partial(fr.update, cfg))
    for _ in range(25):                      # arm the long windows
        X = rng.normal(0, 1, (256, 4)).astype(np.float32)
        y = (np.where(X[:, 0] <= 0, 1.0, 6.0)
             + 0.1 * rng.normal(0, 1, 256)).astype(np.float32)
        state, aux = upd(state, jnp.array(X), jnp.array(y))
        assert not np.asarray(aux["drift"]).any()
    X = rng.normal(0, 1, (256, 4)).astype(np.float32)
    y_out = (np.where(X[:, 0] <= 0, 1.0, 6.0) + 40.0).astype(np.float32)
    w_tail = (np.arange(256) < 1).astype(np.float32)   # ONE real row
    _, aux_tail = upd(state, jnp.array(X), jnp.array(y_out),
                      w=jnp.array(w_tail))
    assert not np.asarray(aux_tail["drift"]).any(), \
        "a 1-row masked tail batch must not trip the drift detector"
    _, aux_full = upd(state, jnp.array(X), jnp.array(y_out))
    assert np.asarray(aux_full["drift"]).any(), \
        "the same shift at full batch weight must still trip it"


def test_fused_forest_matches_oracle_member_updates():
    """The flat (T*M)-table fused update == vmap of the seed oracle engine
    (same PRNG keys -> same Poisson weights -> same forests)."""
    X, y = synth.piecewise_regression(4096, n_features=3, seed=9)
    states = {}
    for backend in ("jnp", "oracle"):
        tree = ht.HTRConfig(n_features=3, max_nodes=31, n_bins=32,
                            grace_period=200, max_depth=6, r0=0.3,
                            split_backend=backend)
        cfg = fr.ForestConfig(tree=tree, n_trees=3)
        s = fr.init_forest(cfg, jax.random.PRNGKey(8))
        s, _ = fr.update_stream(cfg, s, jnp.array(X), jnp.array(y))
        states[backend] = (cfg, s)
    cfg_j, s_j = states["jnp"]
    cfg_o, s_o = states["oracle"]
    np.testing.assert_array_equal(np.asarray(s_j["trees"]["n_nodes"]),
                                  np.asarray(s_o["trees"]["n_nodes"]))
    Xt, yt = synth.piecewise_regression(1024, n_features=3, seed=99)
    p_j = np.asarray(fr.predict(cfg_j, s_j, jnp.array(Xt)))
    p_o = np.asarray(fr.predict(cfg_o, s_o, jnp.array(Xt)))
    mse_j = float(np.mean((p_j - yt) ** 2))
    mse_o = float(np.mean((p_o - yt) ** 2))
    assert abs(mse_j - mse_o) <= 0.01 * max(mse_o, 1e-9), (mse_j, mse_o)


def test_drift_resets_worst_member():
    """An abrupt target shift must trip the ADWIN-style window and reset
    members (fresh tree, fresh subspace, window restarted)."""
    # NB: min_batches must stay below the decayed window's asymptotic
    # length 1/(1 - drift_decay) or the detector never arms
    cfg = _small_cfg(n_trees=4, drift_min_batches=8, drift_kappa=3.0)
    state = fr.init_forest(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    upd = jax.jit(functools.partial(fr.update, cfg))

    def stream(shift, steps):
        nonlocal state
        drifted = 0
        for _ in range(steps):
            X = rng.normal(0, 1, (256, 4)).astype(np.float32)
            y = (np.where(X[:, 0] <= 0, 1.0, 6.0) + shift
                 + 0.1 * rng.normal(0, 1, 256)).astype(np.float32)
            state, aux = upd(state, jnp.array(X), jnp.array(y))
            drifted += int(np.asarray(aux["drift"]).sum())
        return drifted

    assert stream(0.0, 25) == 0, "stationary phase must not trip the detector"
    n_before = np.asarray(state["trees"]["n_nodes"]).copy()
    assert (n_before > 1).all()
    drifted = stream(40.0, 15)
    assert drifted > 0, "abrupt drift never detected"
    assert int(np.asarray(state["resets"]).sum()) == drifted


def test_sharded_forest_matches_vmapped():
    """shard_map over the tree axis == single-device vmap (subprocess with
    forced host devices, same idiom as test_sharding)."""
    code = """
    import functools, jax, jax.numpy as jnp, numpy as np
    from repro.core import forest as fr, hoeffding as ht
    from repro.data import synth
    from repro.train import sharding as sh
    from repro.launch.mesh import make_mesh_auto

    tree = ht.HTRConfig(n_features=4, max_nodes=31, n_bins=32,
                        grace_period=200, max_depth=6, r0=0.25)
    cfg = fr.ForestConfig(tree=tree, n_trees=8)
    X, y = synth.piecewise_regression(3072, n_features=4, seed=7)
    mesh = make_mesh_auto((4,), ("data",))
    upd, prd = sh.build_sharded_forest(cfg, mesh, "data")

    s_ref = fr.init_forest(cfg, jax.random.PRNGKey(3))
    s_shd = jax.device_put(
        s_ref, sh.to_shardings(mesh, sh.forest_state_specs(s_ref, "data")))
    upd_ref = jax.jit(functools.partial(fr.update, cfg))
    for i in range(0, 3072, 256):
        xb, yb = jnp.array(X[i:i + 256]), jnp.array(y[i:i + 256])
        s_ref, aux_r = upd_ref(s_ref, xb, yb)
        s_shd, aux_s = upd(s_shd, xb, yb)
    assert (np.asarray(s_ref["trees"]["n_nodes"])
            == np.asarray(s_shd["trees"]["n_nodes"])).all()
    p_ref = np.asarray(fr.predict(cfg, s_ref, jnp.array(X[:512])))
    p_shd = np.asarray(prd(s_shd, jnp.array(X[:512])))
    assert float(np.abs(p_ref - p_shd).max()) < 1e-4
    assert abs(float(aux_r["forest_mse"]) - float(aux_s["forest_mse"])) < 1e-5
    print("SHARDED_OK")
    """
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=4",
               PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "SHARDED_OK" in out.stdout
