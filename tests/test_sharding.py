"""Distribution tests on a multi-device (forced-host) mesh.

Run in a subprocess with XLA_FLAGS so the main test process keeps 1 device
(the assignment forbids setting the flag globally)."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_with_devices(code: str, n=8) -> str:
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={n}",
               PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_train_step_agrees_with_single_device():
    """Same tiny model: 4x2 mesh loss == 1-device loss (SPMD correctness)."""
    code = """
    import jax, jax.numpy as jnp, numpy as np
    from repro import configs
    from repro.configs import reduced, ShapeConfig
    from repro.models import layers as L, model as M
    L.set_compute_dtype(jnp.float32)
    from repro.train import steps as ST
    from repro.optim import adamw
    from repro.train import monitor as MON
    from repro.launch.mesh import make_local_mesh

    cfg = reduced(configs.get_arch("qwen3-8b"), d_model=64, n_heads=8,
                  n_kv_heads=4, vocab=256, head_dim=16)
    shape = ShapeConfig("t", 64, 8, "train")
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (8, 64), 0, 256),
             "labels": jax.random.randint(jax.random.PRNGKey(2), (8, 64), 0, 256)}
    losses = {}
    for dp, tp in ((1, 1), (4, 2)):
        mesh = make_local_mesh(dp, tp)
        fn, in_sh, _, _ = ST.build_train_step(cfg, shape, mesh, donate=False)
        with mesh:
            params = jax.jit(lambda k: M.init_params(k, cfg),
                             out_shardings=in_sh[0])(jax.random.PRNGKey(0))
            opt = jax.jit(adamw.init_state, out_shardings=in_sh[1])(params)
            _, _, metrics, _ = fn(params, opt, batch, MON.init_monitor())
            losses[(dp, tp)] = float(metrics["loss"])
    print("LOSSES", losses[(1, 1)], losses[(4, 2)])
    assert abs(losses[(1, 1)] - losses[(4, 2)]) < 2e-3, losses
    """
    out = run_with_devices(code)
    assert "LOSSES" in out


def test_distributed_sketch_merge_8_devices():
    """QO tables merged across a real 8-way axis == single-stream table."""
    code = """
    import jax, jax.numpy as jnp, numpy as np
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    from repro.core import qo, sketch
    from repro.launch.mesh import make_mesh_auto
    mesh = make_mesh_auto((8,), ("data",))
    rng = np.random.default_rng(0)
    x = rng.normal(0, 1, 8 * 500).astype(np.float32)

    def f(xs):
        t = qo.update(qo.init(64, radius=0.2), xs, xs)
        return sketch.all_merge(t, "data")

    out = jax.jit(shard_map(f, mesh=mesh, in_specs=P("data"), out_specs=P(), check_rep=False))(
        jnp.array(x))
    ref = qo.update(qo.init(64, radius=0.2), jnp.array(x), jnp.array(x))
    np.testing.assert_allclose(np.asarray(out["y"]["n"]),
                               np.asarray(ref["y"]["n"]), atol=1e-3)
    np.testing.assert_allclose(np.asarray(out["y"]["mean"]),
                               np.asarray(ref["y"]["mean"]), rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(np.asarray(out["y"]["m2"]),
                               np.asarray(ref["y"]["m2"]), rtol=5e-3, atol=5e-3)
    print("MERGE OK")
    """
    out = run_with_devices(code)
    assert "MERGE OK" in out


def test_int8_quantized_psum_8_devices():
    code = """
    import jax, jax.numpy as jnp, numpy as np
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    from repro.optim import compress
    from repro.launch.mesh import make_mesh_auto
    mesh = make_mesh_auto((8,), ("pod",))
    rng = np.random.default_rng(0)
    g = rng.normal(0, 0.1, (8, 128)).astype(np.float32)

    out = jax.jit(shard_map(
        lambda x: compress.quantized_psum({"g": x[0]}, "pod")["g"],
        mesh=mesh, in_specs=P("pod"), out_specs=P(), check_rep=False))(jnp.array(g))
    ref = g.sum(0)
    err = np.abs(np.asarray(out) - ref).max()
    scale = np.abs(g).max() / 127 * 8
    assert err <= scale + 1e-6, (err, scale)
    print("PSUM OK", err)
    """
    out = run_with_devices(code)
    assert "PSUM OK" in out


def test_dryrun_entrypoint_single_cell():
    """The real dryrun module compiles one cell end-to-end (512 devices)."""
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch",
         "phi3-mini-3.8b", "--shape", "decode_32k", "--out",
         "/tmp/dryrun_test.json"],
        capture_output=True, text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.load(open("/tmp/dryrun_test.json"))
    assert res[0]["status"] == "ok"
    assert res[0]["chips"] == 256
