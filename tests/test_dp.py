"""Data-parallel stream scale-out (DESIGN.md §4.1) correctness pins.

The headline contract: a forest trained with the batch axis sharded over
D devices (``build_data_parallel_forest``) is BIT-IDENTICAL at every
sync boundary to the single-device execution of the same protocol
(``build_data_parallel_reference``) — topology, QO tables, predictor
stats, vote weights, everything — on every backend.  Multi-device runs
use the forced-host-device subprocess idiom of test_sharding.py.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_with_devices(code: str, n=4) -> str:
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={n}",
               PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


@pytest.mark.parametrize("backend", ["jnp", "interpret"])
def test_data_parallel_matches_reference_bitwise(backend):
    """4-shard shard_map training == the single-device reference of the
    same protocol, bitwise, at EVERY sync boundary (trees grow)."""
    code = f"""
    import jax, jax.numpy as jnp, numpy as np
    from repro.core import forest as fr, hoeffding as ht
    from repro.data import synth
    from repro.train import sharding as sh
    from repro.launch.mesh import make_mesh_auto

    tree = ht.HTRConfig(n_features=4, max_nodes=31, n_bins=32,
                        grace_period=100, max_depth=6, r0=0.25,
                        split_backend="{backend}")
    cfg = fr.ForestConfig(tree=tree, n_trees=4)
    X, y = synth.piecewise_regression(2048, n_features=4, seed=7)
    X, y = jnp.asarray(X), jnp.asarray(y)
    mesh = make_mesh_auto((4,), ("data",))
    i_s, u_s, w_s, p_s = sh.build_data_parallel_forest(cfg, mesh, "data",
                                                       sync_every=2)
    i_r, u_r, w_r, p_r = sh.build_data_parallel_reference(cfg, 4,
                                                          sync_every=2)
    st_s, st_r = i_s(jax.random.PRNGKey(5)), i_r(jax.random.PRNGKey(5))
    n_syncs = 0
    for i in range(0, 2048, 256):
        st_s, aux_s = u_s(st_s, X[i:i+256], y[i:i+256])
        st_r, aux_r = u_r(st_r, X[i:i+256], y[i:i+256])
        assert (aux_s is None) == (aux_r is None)
        if aux_s is not None:
            n_syncs += 1
            jax.tree.map(lambda a, b: np.testing.assert_array_equal(
                np.asarray(a), np.asarray(b)), st_s["forest"], st_r["forest"])
            jax.tree.map(lambda a, b: np.testing.assert_array_equal(
                np.asarray(a), np.asarray(b)), aux_s, aux_r)
    assert n_syncs == 4
    assert int(np.asarray(st_s["forest"]["trees"]["n_nodes"]).max()) > 1
    np.testing.assert_array_equal(np.asarray(p_s(st_s, X[:512])),
                                  np.asarray(p_r(st_r, X[:512])))

    # the one-dispatch window path == S per-batch steps + sync, bitwise
    # (sharded window vs BOTH its own per-step path and the reference's
    # window)
    st_w, st_p = i_s(jax.random.PRNGKey(9)), i_s(jax.random.PRNGKey(9))
    st_wr = i_r(jax.random.PRNGKey(9))
    for i in range(0, 1024, 512):
        Xw = X[i:i+512].reshape(2, 256, -1)
        yw = y[i:i+512].reshape(2, 256)
        st_w, aux_w = w_s(st_w, Xw, yw)
        st_wr, aux_wr = w_r(st_wr, Xw, yw)
        for j in (0, 256):
            st_p, aux_p = u_s(st_p, X[i+j:i+j+256], y[i+j:i+j+256])
        for other in (st_p["forest"], st_wr["forest"]):
            jax.tree.map(lambda a, b: np.testing.assert_array_equal(
                np.asarray(a), np.asarray(b)), st_w["forest"], other)
    print("DP_BITWISE_OK")
    """
    assert "DP_BITWISE_OK" in run_with_devices(code)


def test_data_parallel_sync_cadence_single_device():
    """The sync_every knob on a 1-device mesh: aux only at boundaries,
    the delta resets to the merge identity after a sync and carries
    exactly the absorbed mass between syncs, and grace counters advance
    only at sync time."""
    from repro.core import forest as fr, hoeffding as ht
    from repro.data import synth
    from repro.train import sharding as sh
    from repro.launch.mesh import make_mesh_auto

    tree = ht.HTRConfig(n_features=4, max_nodes=31, n_bins=32,
                        grace_period=100, max_depth=6, r0=0.25)
    cfg = fr.ForestConfig(tree=tree, n_trees=4)
    X, y = synth.piecewise_regression(768, n_features=4, seed=3)
    X, y = jnp.asarray(X), jnp.asarray(y)
    mesh = make_mesh_auto((1,), ("data",))
    init, upd, _, _ = sh.build_data_parallel_forest(cfg, mesh, "data",
                                                    sync_every=3)
    st = init(jax.random.PRNGKey(0))
    seen0 = np.asarray(st["forest"]["trees"]["seen_since_attempt"]).copy()

    st, aux = upd(st, X[:256], y[:256])
    assert aux is None
    # between syncs: the forest (incl. grace counters) is untouched
    np.testing.assert_array_equal(
        np.asarray(st["forest"]["trees"]["seen_since_attempt"]), seen0)
    mass1 = float(np.asarray(st["delta"]["ystats"]["n"]).sum())
    assert mass1 > 0  # Poisson(6) mass of 256 rows x 4 trees

    st, aux = upd(st, X[256:512], y[256:512])
    assert aux is None
    st, aux = upd(st, X[512:768], y[512:768])
    assert aux is not None and st["step"] == 3
    # the merged mass the sync reports is everything absorbed since init
    assert float(aux["mass"]) > mass1
    # delta reset to the merge identity
    assert float(np.asarray(st["delta"]["ystats"]["n"]).sum()) == 0.0
    assert float(np.asarray(st["delta"]["ao_y"]["n"]).sum()) == 0.0
    # the merged mass landed in the replicated predictors in one lump
    # (>= because split children inherit copies of the halves), and
    # crossing grace at the boundary let the roots attempt (which
    # resets their seen_since_attempt — hence nodes, not counters)
    assert float(np.asarray(st["forest"]["trees"]["ystats"]["n"]).sum()) \
        >= float(aux["mass"]) - 1e-3
    assert int(np.asarray(st["forest"]["trees"]["n_nodes"]).max()) > 1


def test_data_parallel_int8_compress():
    """The §4.2 cheap-shipping path: int8-quantized delta psum trains a
    close-but-not-bitwise forest (mass within 5% of exact) and serves
    finite predictions."""
    code = """
    import jax, jax.numpy as jnp, numpy as np
    from repro.core import forest as fr, hoeffding as ht
    from repro.data import synth
    from repro.train import sharding as sh
    from repro.launch.mesh import make_mesh_auto

    tree = ht.HTRConfig(n_features=4, max_nodes=31, n_bins=32,
                        grace_period=100, max_depth=6, r0=0.25)
    cfg = fr.ForestConfig(tree=tree, n_trees=4)
    X, y = synth.piecewise_regression(1024, n_features=4, seed=7)
    X, y = jnp.asarray(X), jnp.asarray(y)
    mesh = make_mesh_auto((4,), ("data",))
    i8, u8, _, p8 = sh.build_data_parallel_forest(cfg, mesh, "data",
                                                   sync_every=2,
                                                   compress="int8")
    ir, ur, _, pr = sh.build_data_parallel_reference(cfg, 4, sync_every=2)
    s8, sr = i8(jax.random.PRNGKey(5)), ir(jax.random.PRNGKey(5))
    for i in range(0, 1024, 256):
        s8, _ = u8(s8, X[i:i+256], y[i:i+256])
        sr, _ = ur(sr, X[i:i+256], y[i:i+256])
    n8 = float(np.asarray(s8["forest"]["trees"]["ystats"]["n"]).sum())
    nr = float(np.asarray(sr["forest"]["trees"]["ystats"]["n"]).sum())
    assert abs(n8 - nr) / nr < 0.05, (n8, nr)
    assert int(np.asarray(s8["forest"]["trees"]["n_nodes"]).max()) > 1
    p = np.asarray(p8(s8, X[:256]))
    assert np.isfinite(p).all()
    print("DP_INT8_OK")
    """
    assert "DP_INT8_OK" in run_with_devices(code)


def test_update_equals_local_plus_attempt():
    """The §4.1 staging refactor of the single tree: ``update`` is
    exactly ``attempt_splits(update_local(...))`` (bitwise), so the DP
    protocol's local/global split introduces no third semantics."""
    from repro.core import hoeffding as ht
    from repro.data import synth

    cfg = ht.HTRConfig(n_features=4, max_nodes=31, n_bins=32,
                       grace_period=50, max_depth=6, r0=0.25)
    X, y = synth.piecewise_regression(512, n_features=4, seed=1)
    X, y = jnp.asarray(X), jnp.asarray(y)
    s1 = s2 = ht.init_state(cfg)
    for i in range(0, 512, 128):
        xb, yb = X[i:i + 128], y[i:i + 128]
        s1 = ht.update(cfg, s1, xb, yb)
        s2 = ht.attempt_splits(cfg, ht.update_local(cfg, s2, xb, yb))
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), s1, s2)
    assert int(np.asarray(s1["n_nodes"])) > 1
