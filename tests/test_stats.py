"""Property tests for the robust variance algebra (paper §3, Eqs. 2-7)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import stats  # noqa: E402

finite_arrays = st.lists(
    st.floats(-1e4, 1e4, allow_nan=False, width=32), min_size=1, max_size=200)


def np_stats(y):
    y = np.asarray(y, np.float64)
    return len(y), y.mean(), ((y - y.mean()) ** 2).sum()


def close(a, b, tol=1e-3):
    return np.isclose(a, b, rtol=tol, atol=tol * 10)


@given(finite_arrays)
@settings(max_examples=100, deadline=None)
def test_observe_matches_numpy(ys):
    s = stats.init()
    for y in ys:
        s = stats.observe(s, y)
    n, mean, m2 = np_stats(ys)
    assert close(float(s["n"]), n)
    scale = max(1.0, abs(mean))
    assert abs(float(s["mean"]) - mean) / scale < 1e-3
    scale2 = max(1.0, m2)
    assert abs(float(s["m2"]) - m2) / scale2 < 1e-2


@given(finite_arrays, finite_arrays)
@settings(max_examples=100, deadline=None)
def test_merge_is_exact_concatenation(a, b):
    """merge(stats(A), stats(B)) == stats(A ++ B)  (paper Eqs. 4-5)."""
    sa = stats.from_batch(jnp.array(a, jnp.float32))
    sb = stats.from_batch(jnp.array(b, jnp.float32))
    m = stats.merge(sa, sb)
    n, mean, m2 = np_stats(a + b)
    assert close(float(m["n"]), n)
    assert abs(float(m["mean"]) - mean) / max(1.0, abs(mean)) < 1e-3
    assert abs(float(m["m2"]) - m2) / max(1.0, m2) < 1e-2


@given(finite_arrays, finite_arrays)
@settings(max_examples=100, deadline=None)
def test_subtract_inverts_merge(a, b):
    """subtract(merge(A,B), B) == A  (paper Eqs. 6-7 — the new result)."""
    sa = stats.from_batch(jnp.array(a, jnp.float32))
    sb = stats.from_batch(jnp.array(b, jnp.float32))
    sab = stats.merge(sa, sb)
    rec = stats.subtract(sab, sb)
    assert close(float(rec["n"]), float(sa["n"]))
    # the subtraction cancels against the MERGED statistics, so float32
    # error scales with |AB|, not |A| (inherent to Eqs. 6-7)
    mscale = max(1.0, abs(float(sa["mean"])), 1e-4 * abs(float(sab["mean"])))
    assert abs(float(rec["mean"]) - float(sa["mean"])) / mscale < 5e-3
    scale2 = max(1.0, float(sa["m2"]), 1e-4 * float(sab["m2"]))
    assert abs(float(rec["m2"]) - float(sa["m2"])) / scale2 < 5e-2


@given(finite_arrays)
@settings(max_examples=50, deadline=None)
def test_merge_associative_commutative(ys):
    """The merge operator is a legal reduction: order must not matter."""
    third = max(1, len(ys) // 3)
    parts = [ys[:third], ys[third:2 * third], ys[2 * third:]]
    parts = [p for p in parts if p]
    ss = [stats.from_batch(jnp.array(p, jnp.float32)) for p in parts]
    import functools
    left = functools.reduce(stats.merge, ss)
    right = functools.reduce(stats.merge, ss[::-1])
    assert close(float(left["n"]), float(right["n"]))
    assert close(float(left["mean"]), float(right["mean"]), 1e-3)
    assert abs(float(left["m2"]) - float(right["m2"])) / max(1.0, float(left["m2"])) < 1e-2


def test_merge_identity():
    s = stats.from_batch(jnp.arange(10.0))
    z = stats.init()
    m = stats.merge(s, z)
    for k in s:
        np.testing.assert_allclose(np.asarray(m[k]), np.asarray(s[k]), rtol=1e-6)


def test_welford_beats_naive_on_cancellation():
    """The paper's motivation: naive sum-of-squares cancels at large mean."""
    rng = np.random.default_rng(0)
    y = (1e6 + 0.1 * rng.normal(0, 1, 4000)).astype(np.float32)
    s = stats.init()
    bs = 100
    for i in range(0, len(y), bs):
        tile = stats.from_batch(jnp.array(y[i:i + bs]))
        s = stats.merge(s, tile)
    robust = float(stats.variance(s))
    # naive float32 accumulation
    sy = np.float32(0); syy = np.float32(0)
    for v in y:
        sy += v; syy += v * v
    naive = (syy - sy * sy / len(y)) / (len(y) - 1)
    truth = np.var(y.astype(np.float64), ddof=1)
    assert abs(robust - truth) / truth < 0.05
    assert abs(naive - truth) > abs(robust - truth)  # robust strictly better


def test_tree_reduce_merge_matches_sequential():
    rng = np.random.default_rng(1)
    ys = rng.normal(3, 2, (16, 50)).astype(np.float32)
    stacked = stats.from_batch(jnp.array(ys), axis=1)
    red = stats.tree_reduce_merge(stacked, axis=0)
    n, mean, m2 = np_stats(ys.reshape(-1))
    assert close(float(red["n"]), n)
    assert close(float(red["mean"]), mean, 1e-3)
    assert abs(float(red["m2"]) - m2) / m2 < 1e-2
