"""Weighted-absorption algebra: the online-bagging contract.

Property demanded by :mod:`repro.core.forest`: for every backend of
``kernels.ops.forest_update``, absorbing a batch with integer sample
weights must equal absorbing the weight-expanded batch (each row repeated
w times at unit weight) — the Oza–Russell bagging identity — and a
weight-0 batch must be an exact no-op, all the way up through
``hoeffding.update``.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import hoeffding as ht
from repro.core import stats
from repro.kernels import ops
from tests.helpers import repeat_by_weights

# hypothesis is a test extra: the property tests skip without it, the
# deterministic weighted tests below always run
try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover
    HAVE_HYPOTHESIS = False
needs_hypothesis = pytest.mark.skipif(not HAVE_HYPOTHESIS,
                                      reason="hypothesis not installed")

BACKENDS = [
    "interpret", "jnp",
    pytest.param("pallas", marks=pytest.mark.skipif(
        jax.default_backend() != "tpu",
        reason="compiled Pallas kernels need a TPU")),
]

M, F, C = 5, 2, 32


def _empty_forest():
    return (stats.init((M, F, C)), jnp.zeros((M, F, C)),
            jnp.full((M, F), 0.25, jnp.float32), jnp.zeros((M, F)))


def _check_weighted_vs_repeated(backend, w, leaf, X, y):
    ao_y, ao_sum_x, ao_radius, ao_origin = _empty_forest()
    wy, wsx = ops.forest_update(ao_y, ao_sum_x, ao_radius, ao_origin,
                                jnp.array(leaf), jnp.array(X), jnp.array(y),
                                jnp.array(w), backend=backend)
    leaf_r, X_r, y_r = repeat_by_weights(w, leaf, X, y)
    if len(leaf_r) == 0:  # all-zero weights: exact no-op
        for k in ("n", "mean", "m2"):
            np.testing.assert_array_equal(np.asarray(wy[k]),
                                          np.asarray(ao_y[k]))
        np.testing.assert_array_equal(np.asarray(wsx), np.asarray(ao_sum_x))
        return
    ry, rsx = ops.forest_update(ao_y, ao_sum_x, ao_radius, ao_origin,
                                jnp.array(leaf_r), jnp.array(X_r),
                                jnp.array(y_r), backend=backend)
    for k in ("n", "mean", "m2"):
        np.testing.assert_allclose(np.asarray(wy[k]), np.asarray(ry[k]),
                                   atol=1e-4, rtol=1e-4, err_msg=k)
    np.testing.assert_allclose(np.asarray(wsx), np.asarray(rsx),
                               atol=1e-4, rtol=1e-4)


if HAVE_HYPOTHESIS:
    @pytest.mark.parametrize("backend", BACKENDS)
    @settings(max_examples=20, deadline=None)
    @given(data=st.data())
    def test_weighted_absorb_equals_repeated_unit_absorbs(backend, data):
        """forest_update(w) == forest_update(rows repeated w times, w=1)."""
        B = data.draw(st.integers(1, 10), label="B")
        w = np.array(data.draw(st.lists(st.integers(0, 4), min_size=B,
                                        max_size=B), label="w"), np.float32)
        rng = np.random.default_rng(
            data.draw(st.integers(0, 2**31), label="seed"))
        leaf = rng.integers(0, M, B).astype(np.int32)
        X = rng.normal(0, 1, (B, F)).astype(np.float32)
        y = rng.normal(0, 2, B).astype(np.float32)
        _check_weighted_vs_repeated(backend, w, leaf, X, y)

    @settings(max_examples=25, deadline=None)
    @given(data=st.data())
    def test_stats_weighted_observe_equals_repeated_merge(data):
        """The scalar algebra itself: observe(y, w) == w unit observes."""
        ys = data.draw(st.lists(st.floats(-50, 50), min_size=1, max_size=8))
        ws = data.draw(st.lists(st.integers(0, 4), min_size=len(ys),
                                max_size=len(ys)))
        s_w, s_u = stats.init(()), stats.init(())
        for yv, wv in zip(ys, ws):
            s_w = stats.observe(s_w, yv, float(wv))
            for _ in range(wv):
                s_u = stats.observe(s_u, yv, 1.0)
        np.testing.assert_allclose(float(s_w["n"]), float(s_u["n"]),
                                   atol=1e-5)
        np.testing.assert_allclose(float(s_w["mean"]), float(s_u["mean"]),
                                   atol=1e-3, rtol=1e-4)
        np.testing.assert_allclose(float(s_w["m2"]), float(s_u["m2"]),
                                   atol=1e-2, rtol=1e-3)


@pytest.mark.parametrize("backend", BACKENDS)
def test_weighted_absorb_fixed_seeds(backend):
    """Deterministic slice of the bagging identity (runs without
    hypothesis; includes an all-zero-weight batch)."""
    for seed, B in ((0, 1), (1, 7), (2, 12)):
        rng = np.random.default_rng(seed)
        w = rng.integers(0, 5, B).astype(np.float32)
        _check_weighted_vs_repeated(
            backend, w, rng.integers(0, M, B).astype(np.int32),
            rng.normal(0, 1, (B, F)).astype(np.float32),
            rng.normal(0, 2, B).astype(np.float32))
    _check_weighted_vs_repeated(
        backend, np.zeros(4, np.float32), np.zeros(4, np.int32),
        np.ones((4, F), np.float32), np.ones(4, np.float32))


@pytest.mark.parametrize("backend", ["jnp", "oracle"])
def test_tree_update_weight_zero_is_noop(backend):
    """A weight-0 batch leaves the WHOLE tree state bit-identical."""
    rng = np.random.default_rng(0)
    cfg = ht.HTRConfig(n_features=3, max_nodes=15, n_bins=32,
                       grace_period=100, max_depth=4, r0=0.3,
                       split_backend=backend)
    state = ht.init_state(cfg)
    upd = jax.jit(functools.partial(ht.update, cfg))
    # warm the tree so the no-op check covers a non-trivial state
    for _ in range(3):
        X = jnp.array(rng.normal(0, 1, (128, 3)).astype(np.float32))
        y = jnp.array(rng.normal(0, 2, 128).astype(np.float32))
        state = upd(state, X, y)
    X = jnp.array(rng.normal(0, 1, (64, 3)).astype(np.float32))
    y = jnp.array(rng.normal(0, 2, 64).astype(np.float32))
    after = upd(state, X, y, jnp.zeros((64,), jnp.float32))
    flat_b, _ = jax.tree_util.tree_flatten(state)
    flat_a, _ = jax.tree_util.tree_flatten(after)
    for b, a in zip(flat_b, flat_a):
        np.testing.assert_array_equal(np.asarray(b), np.asarray(a))


def test_tree_integer_weights_match_repeated_rows():
    """hoeffding.update with integer w grows the same tree as the
    weight-expanded stream (leaf stats, QO tables and splits all agree)."""
    rng = np.random.default_rng(5)
    cfg = ht.HTRConfig(n_features=2, max_nodes=15, n_bins=32,
                       grace_period=80, max_depth=4, r0=0.3)
    s_w, s_r = ht.init_state(cfg), ht.init_state(cfg)
    upd = jax.jit(functools.partial(ht.update, cfg))
    for _ in range(6):
        X = rng.normal(0, 1, (96, 2)).astype(np.float32)
        y = np.where(X[:, 0] <= 0, 1.0, 6.0).astype(np.float32)
        w = rng.poisson(2.0, 96).astype(np.float32)
        X_r, y_r = repeat_by_weights(w, X, y)
        s_w = upd(s_w, jnp.array(X), jnp.array(y), jnp.array(w))
        if len(X_r):
            s_r = upd(s_r, jnp.array(X_r), jnp.array(y_r))
    assert int(s_w["n_nodes"]) == int(s_r["n_nodes"])
    np.testing.assert_array_equal(np.asarray(s_w["is_leaf"]),
                                  np.asarray(s_r["is_leaf"]))
    np.testing.assert_allclose(np.asarray(s_w["ystats"]["n"]),
                               np.asarray(s_r["ystats"]["n"]), atol=1e-3)
    np.testing.assert_allclose(np.asarray(s_w["ystats"]["mean"]),
                               np.asarray(s_r["ystats"]["mean"]),
                               atol=1e-3, rtol=1e-4)
