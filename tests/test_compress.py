"""Gradient compression: sketch-thresholded top-k + int8 all-reduce."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import compress


def test_sparsify_keeps_top_fraction(rng):
    g = {"a": jnp.array(rng.normal(0, 1, (64, 64)).astype(np.float32)),
         "b": jnp.array(rng.normal(0, 3, (128,)).astype(np.float32))}
    err = compress.init_error_state(g)
    sparse, new_err, m = compress.sparsify_with_sketch(g, err, keep_frac=0.1)
    dens = float(m["density"])
    assert 0.02 < dens < 0.35  # sketch threshold approximates 10%
    # kept entries are the large ones
    kept = np.abs(np.asarray(sparse["a"]))[np.asarray(sparse["a"]) != 0]
    dropped_max = np.abs(np.asarray(g["a"] - sparse["a"])).max()
    assert kept.min() >= dropped_max * 0.5


def test_error_feedback_is_lossless_over_time(rng):
    """sum(transmitted) + final_error == sum(original grads)."""
    g = jnp.array(rng.normal(0, 1, (256,)).astype(np.float32))
    err = jnp.zeros_like(g)
    sent = jnp.zeros_like(g)
    for _ in range(5):
        sparse, err, _ = compress.sparsify_with_sketch(
            {"g": g}, {"g": err}, keep_frac=0.2)
        sparse, err = sparse["g"], err["g"]
        sent = sent + sparse
    np.testing.assert_allclose(np.asarray(sent + err), np.asarray(5 * g),
                               rtol=1e-4, atol=1e-4)


def test_int8_quantized_psum_single_device(rng):
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    from repro.launch.mesh import make_mesh_auto
    mesh = make_mesh_auto((1,), ("pod",))
    g = jnp.array(rng.normal(0, 0.1, (64,)).astype(np.float32))

    out = shard_map(
        lambda x: compress.quantized_psum({"g": x}, "pod")["g"],
        mesh=mesh, in_specs=P(), out_specs=P())(g)
    np.testing.assert_allclose(np.asarray(out), np.asarray(g),
                               atol=float(jnp.abs(g).max()) / 100)


def test_int8_encode_decode_roundtrip(rng):
    g = jnp.array(rng.normal(0, 2, (1000,)).astype(np.float32))
    q, s = compress.int8_encode(g)
    rec = compress.int8_decode(q, s)
    assert q.dtype == jnp.int8
    np.testing.assert_allclose(np.asarray(rec), np.asarray(g),
                               atol=float(jnp.abs(g).max()) / 120)
