"""The inference engine: batched routing + frozen serving snapshots.

Routing equivalence is a bit-exactness contract (DESIGN.md §2.6): the
fused level-synchronous sweep must return the scalar oracle's leaf id
for every row on every backend, including the degenerate shapes that
break naive traversal code — an untrained root, a root-only split, a
single maximum-depth chain, batches that are not a power of two.  On
top of that, serving snapshots must predict bit-identically to the live
state they froze, and the cached-jit dispatch must never recompile for
a fixed shape bucket.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import forest as fr
from repro.core import hoeffding as ht
from repro.core import serve as sv
from repro.data import synth
from repro.kernels import ops, ref

BACKENDS = [
    "interpret", "jnp",
    pytest.param("pallas", marks=pytest.mark.skipif(
        jax.default_backend() != "tpu",
        reason="compiled Pallas kernels need a TPU")),
]

CFG = ht.HTRConfig(n_features=3, max_nodes=31, n_bins=32, grace_period=200,
                   max_depth=6, r0=0.3)


def _trained_tree(n=6000):
    X, y = synth.piecewise_regression(n, n_features=3, seed=9)
    return ht.update_stream(CFG, ht.init_state(CFG), jnp.array(X),
                            jnp.array(y)), jnp.array(X[:512])


def _chain_tree(cfg):
    """Pathological single max-depth chain: every internal node's right
    child is a leaf, the left child splits again on feature 0."""
    s = ht.init_state(cfg)
    feature = np.zeros(cfg.max_nodes, np.int32)
    threshold = np.zeros(cfg.max_nodes, np.float32)
    child = np.full((cfg.max_nodes, 2), -1, np.int32)
    is_leaf = np.ones(cfg.max_nodes, bool)
    depth = np.zeros(cfg.max_nodes, np.int32)
    node, nxt = 0, 1
    for d in range(cfg.max_depth):
        threshold[node] = -0.5 * d
        child[node] = [nxt, nxt + 1]
        is_leaf[node] = False
        depth[nxt] = depth[nxt + 1] = d + 1
        node, nxt = nxt, nxt + 2
    mean = np.arange(cfg.max_nodes, dtype=np.float32)  # distinct per node
    return dict(
        s, feature=jnp.array(feature), threshold=jnp.array(threshold),
        child=jnp.array(child), is_leaf=jnp.array(is_leaf),
        depth=jnp.array(depth), n_nodes=jnp.int32(2 * cfg.max_depth + 1),
        ystats=dict(s["ystats"], mean=jnp.array(mean)))


def _degenerate_states(cfg):
    root = ht.init_state(cfg)                     # untrained root
    split = ht.init_state(cfg)                    # one root split
    split = dict(
        split,
        feature=split["feature"].at[0].set(1),
        threshold=split["threshold"].at[0].set(0.25),
        child=split["child"].at[0].set(jnp.array([1, 2])),
        is_leaf=split["is_leaf"].at[0].set(False).at[1].set(True)
        .at[2].set(True),
        depth=split["depth"].at[1].set(1).at[2].set(1),
        n_nodes=jnp.int32(3),
        ystats=dict(split["ystats"],
                    mean=split["ystats"]["mean"].at[1].set(-3.0)
                    .at[2].set(7.0)))
    return {"untrained_root": root, "root_only_split": split,
            "max_depth_chain": _chain_tree(cfg)}


# --------------------------------------------------------------------------
# routing equivalence: fused sweep == scalar oracle, bit for bit
# --------------------------------------------------------------------------

@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("case", ["untrained_root", "root_only_split",
                                  "max_depth_chain", "trained"])
@pytest.mark.parametrize("B", [1, 100, 256])      # 100: not a power of two
def test_route_matches_scalar_oracle(backend, case, B, rng):
    if case == "trained":
        s, _ = _trained_tree()
    else:
        s = _degenerate_states(CFG)[case]
    X = jnp.array(rng.normal(0, 1.5, (B, CFG.n_features)).astype(np.float32))
    want = ref.route_ref(s["feature"], s["threshold"], s["child"],
                         s["is_leaf"], X, CFG.max_depth)
    got = ops.route(s["feature"], s["threshold"], s["child"], s["is_leaf"],
                    X, depth=CFG.max_depth, backend=backend)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # any ply count >= the realized depth is equivalent (self-loop no-ops)
    realized = int(s["depth"].max())
    got_trim = ops.route(s["feature"], s["threshold"], s["child"],
                         s["is_leaf"], X, depth=realized, backend=backend)
    np.testing.assert_array_equal(np.asarray(got_trim), np.asarray(want))


@pytest.mark.parametrize("backend", BACKENDS)
def test_forest_route_matches_vmapped_oracle(backend, rng):
    """The folded T-tree sweep == T independent scalar walks (diverse
    member shapes: a chain, a root, a trained tree in one forest)."""
    states = _degenerate_states(CFG)
    trained, _ = _trained_tree()
    members = [states["max_depth_chain"], states["untrained_root"], trained,
               states["root_only_split"]]
    trees = jax.tree.map(lambda *a: jnp.stack(a), *[
        {k: m[k] for k in ("feature", "threshold", "child", "is_leaf")}
        for m in members])
    X = jnp.array(rng.normal(0, 1.5, (200, CFG.n_features)).astype(np.float32))
    want = ref.forest_route_ref(trees["feature"], trees["threshold"],
                                trees["child"], trees["is_leaf"], X,
                                CFG.max_depth)
    got = ops.forest_route(trees["feature"], trees["threshold"],
                           trees["child"], trees["is_leaf"], X,
                           depth=CFG.max_depth, backend=backend)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_route_traced_inline_matches_concrete_dispatch(rng):
    """jit(route) (inlined sweep) == the concrete cached-jit dispatch."""
    s, _ = _trained_tree()
    X = jnp.array(rng.normal(0, 1.5, (300, 3)).astype(np.float32))
    concrete = ops.route(s["feature"], s["threshold"], s["child"],
                         s["is_leaf"], X, depth=CFG.max_depth, backend="jnp")
    traced = jax.jit(functools.partial(ops.route, depth=CFG.max_depth,
                                       backend="jnp"))(
        s["feature"], s["threshold"], s["child"], s["is_leaf"], X)
    np.testing.assert_array_equal(np.asarray(concrete), np.asarray(traced))


@pytest.mark.parametrize("bad", [np.nan, np.inf, -np.inf])
def test_non_finite_rows_follow_the_oracle(bad, rng):
    """NaN/±inf features route exactly like the oracle's `x <= thr`
    convention on both engines — serving garbage must not diverge.
    (-inf is the nasty one: a settled row must keep self-looping at its
    leaf even when its feature value compares True against everything.)"""
    s, _ = _trained_tree()
    X = jnp.array(rng.normal(0, 1.5, (64, 3)).astype(np.float32))
    X = X.at[::3].set(bad)
    X = X.at[1, :].set(bad)                       # a fully-poisoned row
    want = ref.route_ref(s["feature"], s["threshold"], s["child"],
                         s["is_leaf"], X, CFG.max_depth)
    got = ops.route(s["feature"], s["threshold"], s["child"], s["is_leaf"],
                    X, depth=CFG.max_depth, backend="jnp")
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_tree_update_rides_fused_route_bit_identically(monkeypatch):
    """The rewired training hot path: stream trees learned with the
    fused routing sweep == the same split engine routing through the
    seed's scalar walk, bit for bit (routing feeds absorb, so a single
    mis-routed row would diverge the learned state)."""
    X, y = synth.piecewise_regression(4000, n_features=3, seed=5)
    cfg = ht.HTRConfig(n_features=3, max_nodes=31, n_bins=32,
                       grace_period=200, max_depth=6, r0=0.3)
    s_fused = ht.update_stream(cfg, ht.init_state(cfg), jnp.array(X),
                               jnp.array(y))

    def scalar_route(feature, threshold, child, is_leaf, X, *, depth,
                     backend=None, tile_b=256):
        return ref.route_ref(feature, threshold, child, is_leaf, X, depth)

    monkeypatch.setattr(ops, "route", scalar_route)
    jax.clear_caches()      # force a retrace that sees the shim
    try:
        s_scalar = ht.update_stream(cfg, ht.init_state(cfg), jnp.array(X),
                                    jnp.array(y))
    finally:
        monkeypatch.undo()
        jax.clear_caches()  # drop programs traced over the shim
    flat_f, _ = jax.tree_util.tree_flatten_with_path(s_fused)
    flat_s, _ = jax.tree_util.tree_flatten_with_path(s_scalar)
    for (path, a), (_, b) in zip(flat_f, flat_s):
        np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b),
            err_msg=f"state leaf {jax.tree_util.keystr(path)} diverged")


# --------------------------------------------------------------------------
# serving snapshots: freeze -> predict, bit-identical to the live state
# --------------------------------------------------------------------------

def _trained_forest(n=4096, T=4):
    tcfg = ht.HTRConfig(n_features=3, max_nodes=31, n_bins=32,
                        grace_period=200, max_depth=6, r0=0.3)
    cfg = fr.ForestConfig(tree=tcfg, n_trees=T)
    X, y = synth.piecewise_regression(n, n_features=3, seed=7)
    s = fr.init_forest(cfg, jax.random.PRNGKey(2))
    s, _ = fr.update_stream(cfg, s, jnp.array(X), jnp.array(y))
    return cfg, s, jnp.array(X[:300])


def test_tree_snapshot_predicts_bit_identically(rng):
    s, Xt = _trained_tree()
    snap = sv.freeze(s)
    live = ht.predict(CFG, s, Xt)
    np.testing.assert_array_equal(np.asarray(sv.predict_snapshot(snap, Xt)),
                                  np.asarray(live))
    # trimming: snapshot stores the realized tree, not cfg capacity
    assert snap.single and snap.depth == int(s["depth"].max())
    assert snap.feature.shape[1] <= CFG.max_nodes + 1
    assert snap.depth <= CFG.max_depth


def test_forest_snapshot_predicts_bit_identically():
    cfg, s, Xt = _trained_forest()
    snap = sv.freeze(s)
    live = fr.predict(cfg, s, Xt)
    np.testing.assert_array_equal(np.asarray(sv.predict_snapshot(snap, Xt)),
                                  np.asarray(live))
    assert not snap.single
    np.testing.assert_array_equal(np.asarray(snap.vote_w),
                                  np.asarray(s["vote_w"]))


def test_snapshot_bfs_reindex_is_level_ordered():
    """Breadth-first contract: node ids are contiguous front-loaded
    levels — every child id > its parent id, depths are sorted."""
    s, _ = _trained_tree()
    snap = sv.freeze(s)
    child = np.asarray(snap.child[0])
    is_leaf = np.asarray(snap.is_leaf[0])
    n = int((~is_leaf).sum()) * 2 + 1            # realized nodes
    depth = np.full(child.shape[0], 0)
    for u in range(n):
        if not is_leaf[u]:
            assert (child[u] > u).all()
            depth[child[u]] = depth[u] + 1
    assert (np.diff(depth[:n]) >= 0).all(), "BFS order must be level-sorted"


def test_degenerate_snapshots(rng):
    for name, s in _degenerate_states(CFG).items():
        snap = sv.freeze(s)
        X = jnp.array(rng.normal(0, 1.5, (50, 3)).astype(np.float32))
        np.testing.assert_array_equal(
            np.asarray(sv.predict_snapshot(snap, X)),
            np.asarray(ht.predict(CFG, s, X)), err_msg=name)
    assert sv.freeze(_degenerate_states(CFG)["untrained_root"]).depth == 0


def test_vote_weights_carried_in_state():
    """`vote_w` rides in ForestState (refreshed once per update) and the
    read path consumes it — predict must not re-derive from the windows."""
    cfg, s, Xt = _trained_forest()
    np.testing.assert_array_equal(np.asarray(s["vote_w"]),
                                  np.asarray(fr.vote_weights(cfg, s)))
    tampered = dict(s, vote_w=jnp.zeros_like(s["vote_w"]).at[0].set(1.0))
    p = np.asarray(fr.predict(cfg, tampered, Xt))
    only0 = np.asarray(fr.member_predictions(cfg, tampered, Xt))[0]
    np.testing.assert_allclose(p, only0, rtol=1e-6)


# --------------------------------------------------------------------------
# cached-jit dispatch: fixed shape bucket -> zero recompiles
# --------------------------------------------------------------------------

def test_predict_snapshot_same_bucket_does_not_recompile():
    ops.clear_jit_caches()
    cfg, s, _ = _trained_forest()
    snap = sv.freeze(s)
    rng = np.random.default_rng(1)
    for B in (100, 128, 77, 128):                # one 128-row bucket
        Xq = jnp.array(rng.normal(0, 1, (B, 3)).astype(np.float32))
        sv.predict_snapshot(snap, Xq, backend="jnp")
    handle = sv._jit_predict("jnp", ops.depth_bucket(snap.depth), False)
    assert handle._cache_size() == 1, "same-bucket requests retraced"
    # a second bucket compiles once more, the first stays warm
    sv.predict_snapshot(
        snap, jnp.array(rng.normal(0, 1, (200, 3)).astype(np.float32)),
        backend="jnp")
    assert handle._cache_size() == 2
    ops.clear_jit_caches()
    assert sv._jit_predict("jnp", ops.depth_bucket(snap.depth),
                           False)._cache_size() == 0


def test_route_same_bucket_does_not_recompile(rng):
    ops.clear_jit_caches()
    s, _ = _trained_tree()
    realized = int(s["depth"].max())
    for B in (100, 120, 128):
        X = jnp.array(rng.normal(0, 1, (B, 3)).astype(np.float32))
        ops.route(s["feature"], s["threshold"], s["child"], s["is_leaf"],
                  X, depth=realized, backend="jnp")
    handle = ops._jit_route_single("jnp", 256, ops.depth_bucket(realized))
    assert handle._cache_size() == 1, "same-bucket route calls retraced"


def test_live_forest_predict_dispatch_cached():
    ops.clear_jit_caches()
    cfg, s, _ = _trained_forest()
    rng = np.random.default_rng(3)
    for B in (64, 100, 128):
        Xq = jnp.array(rng.normal(0, 1, (B, 3)).astype(np.float32))
        fr.predict(cfg, s, Xq)
    depth = min(cfg.tree.max_depth, int(s["trees"]["depth"].max()))
    handle = fr._jit_predict_live(ops.resolve_backend(cfg.tree.split_backend),
                                  ops.depth_bucket(depth))
    assert handle._cache_size() == 1, "live predict retraced per request"


# --------------------------------------------------------------------------
# batch-axis-sharded serving == single-device serving
# --------------------------------------------------------------------------

def test_batch_sharded_serving_matches_single_device():
    """shard_map over the request batch (1-device mesh here; the
    multi-device path shares the body and is exercised by the subprocess
    sharding tests' idiom) == plain snapshot predict."""
    from jax.sharding import Mesh

    from repro.train import sharding as sh

    import dataclasses

    cfg, s, Xt = _trained_forest()
    snap = sv.freeze(s)
    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    pred = sh.build_sharded_serving(snap, mesh, "data")
    np.testing.assert_array_equal(np.asarray(pred(snap, Xt)),
                                  np.asarray(sv.predict_snapshot(snap, Xt)))
    # a refreshed snapshot whose realized depth changed but still fits
    # the build-time ply budget serves fine (the depth aux must not leak
    # into the shard_map treedef) ...
    shallower = dataclasses.replace(snap, depth=snap.depth - 1)
    np.testing.assert_array_equal(np.asarray(pred(shallower, Xt)),
                                  np.asarray(sv.predict_snapshot(snap, Xt)))
    # ... while one DEEPER than the ply budget is rejected loudly, never
    # silently under-routed
    deeper = dataclasses.replace(snap, depth=ops.depth_bucket(snap.depth) + 1)
    with pytest.raises(ValueError, match="rebuild"):
        pred(deeper, Xt)


# --------------------------------------------------------------------------
# publish-validation gate: validate_snapshot + freeze version stamps
# --------------------------------------------------------------------------

def _chain_snap():
    """Frozen chain tree — guaranteed internal nodes at every depth, so
    corruption sites exist regardless of how training happened to grow."""
    return sv.freeze(_chain_tree(CFG))


def test_validate_snapshot_accepts_healthy_trees():
    s, _ = _trained_tree()
    assert sv.validate_snapshot(sv.freeze(s)) is not None
    cfg, fs, _ = _trained_forest()
    snap = sv.freeze(fs, version=3, step=12)
    assert sv.validate_snapshot(snap) is snap  # returns it for inline gating


def test_validate_rejects_nan_threshold_on_internal_node():
    import dataclasses
    snap = _chain_snap()
    bad = dataclasses.replace(
        snap, threshold=snap.threshold.at[0, 0].set(jnp.nan))
    with pytest.raises(sv.SnapshotValidationError,
                       match="non-finite threshold"):
        sv.validate_snapshot(bad)


def test_validate_rejects_child_out_of_range():
    import dataclasses
    snap = _chain_snap()
    Mr = snap.child.shape[1]
    bad = dataclasses.replace(snap, child=snap.child.at[0, 0, 1].set(Mr))
    with pytest.raises(sv.SnapshotValidationError, match="out of range"):
        sv.validate_snapshot(bad)


def test_validate_rejects_level_order_violation():
    import dataclasses
    snap = _chain_snap()
    # point an internal node's child back at the root: breaks both
    # child > parent and root-never-a-child
    bad = dataclasses.replace(snap, child=snap.child.at[0, 0, 1].set(0))
    with pytest.raises(sv.SnapshotValidationError, match="BFS|root"):
        sv.validate_snapshot(bad)


def test_validate_rejects_leaf_with_children():
    import dataclasses
    snap = _chain_snap()
    leaf = int(np.nonzero(np.asarray(snap.is_leaf[0]))[0][0])
    bad = dataclasses.replace(snap, child=snap.child.at[0, leaf, 0].set(1))
    with pytest.raises(sv.SnapshotValidationError, match="-1 children"):
        sv.validate_snapshot(bad)


def test_validate_rejects_bad_vote_weights_and_means():
    import dataclasses
    cfg, fs, _ = _trained_forest()
    snap = sv.freeze(fs)
    for field, val, msg in [
            ("vote_w", jnp.nan, "vote weights"),
            ("vote_w", -1.0, "vote weights"),
            ("leaf_mean", jnp.inf, "leaf means")]:
        arr = getattr(snap, field)
        flat_bad = arr.reshape(-1).at[0].set(val).reshape(arr.shape)
        with pytest.raises(sv.SnapshotValidationError, match=msg):
            sv.validate_snapshot(dataclasses.replace(snap, **{field: flat_bad}))


def test_freeze_stamps_version_and_step():
    """version/step ride as i32 *leaves* (not static aux): republishing
    never changes the treedef, so the cached routing jits stay warm and
    the stamps round-trip through the checkpointer by value."""
    s, Xt = _trained_tree()
    snap = sv.freeze(s, version=5, step=40)
    assert (int(snap.version), int(snap.step)) == (5, 40)
    default = sv.freeze(s)
    assert (int(default.version), int(default.step)) == (0, 0)
    same_def = jax.tree_util.tree_structure(snap) == \
        jax.tree_util.tree_structure(default)
    assert same_def, "version bump must not change the treedef"
    np.testing.assert_array_equal(
        np.asarray(sv.predict_snapshot(snap, Xt)),
        np.asarray(sv.predict_snapshot(default, Xt)))
    with pytest.raises(sv.SnapshotValidationError, match="non-negative"):
        sv.freeze(s, version=-1)
