"""Attempt scheduling + compacted split query (DESIGN.md §2.5).

Edge-case coverage demanded by the K-compacted query path: K = 0 (no
query dispatched at all — asserted via a counting shim on the query
internals), K = 1, K = M, a leaf crossing its grace period exactly on a
batch boundary, bit-identical compacted vs full-scan results on every
backend, and the cached-jit no-recompile regression.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import hoeffding as ht
from repro.core import stats
from repro.data import synth
from repro.kernels import ops, ref

BACKENDS = [
    "interpret", "jnp",
    pytest.param("pallas", marks=pytest.mark.skipif(
        jax.default_backend() != "tpu",
        reason="compiled Pallas kernels need a TPU")),
]


def _forest_state(rng, M=12, F=3, C=48):
    """Random occupied forest built through the per-table oracle."""
    ao_y = stats.init((M, F, C))
    ao_sum_x = jnp.zeros((M, F, C))
    ao_radius = jnp.array(rng.uniform(0.05, 0.4, (M, F)).astype(np.float32))
    ao_origin = jnp.array(rng.normal(0, 0.5, (M, F)).astype(np.float32))
    B = 160
    leaf = jnp.array(rng.integers(0, M, B), jnp.int32)
    X = jnp.array(rng.normal(0, 1, (B, F)).astype(np.float32))
    y = jnp.array(rng.normal(0, 2, B).astype(np.float32))
    ao_y, ao_sum_x = ref.forest_update_ref(
        ao_y, ao_sum_x, ao_radius, ao_origin, leaf, X, y)
    return ao_y, ao_sum_x, ao_radius, ao_origin


def _attempt_with_k(rng, M, K):
    att = np.zeros(M, bool)
    att[rng.choice(M, K, replace=False)] = True
    return jnp.array(att)


# --------------------------------------------------------------------------
# K edge cases: 0, 1, M — compacted == full scan, bitwise
# --------------------------------------------------------------------------

def test_k0_dispatches_no_query(rng, monkeypatch):
    """attempt all-False: the concrete path must not run ANY query."""
    ao_y, ao_sum_x, ao_radius, ao_origin = _forest_state(rng)
    calls = {"full": 0, "compact": 0}
    real_full, real_compact = ops._query_full, ops._query_compact

    def count_full(*a, **k):
        calls["full"] += 1
        return real_full(*a, **k)

    def count_compact(*a, **k):
        calls["compact"] += 1
        return real_compact(*a, **k)

    ops.clear_jit_caches()  # fresh traces must see the counting shim
    monkeypatch.setattr(ops, "_query_full", count_full)
    monkeypatch.setattr(ops, "_query_compact", count_compact)
    try:
        M = ao_sum_x.shape[0]
        merit, thr = ops.forest_best_splits(
            ao_y, ao_sum_x, ao_radius, ao_origin, jnp.zeros((M,), bool),
            backend="jnp")
        assert calls == {"full": 0, "compact": 0}, \
            "K=0 must short-circuit before any query"
        assert not np.isfinite(np.asarray(merit)).any()
        assert (np.asarray(thr) == 0.0).all()
        # K=1 by contrast dispatches exactly one compacted query (which
        # delegates to the shared _query_full body over the K_pad buffer)
        ops.forest_best_splits(ao_y, ao_sum_x, ao_radius, ao_origin,
                               _attempt_with_k(rng, M, 1), backend="jnp")
        assert calls["compact"] == 1
    finally:
        ops.clear_jit_caches()  # drop jits traced over the shim


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("K", [1, 5, "M"])
def test_compacted_matches_full_scan(backend, K, rng):
    """Compacted gather->query->scatter is bit-identical to the full scan
    wherever the full scan reports a finite merit."""
    ao_y, ao_sum_x, ao_radius, ao_origin = _forest_state(rng)
    M = ao_sum_x.shape[0]
    K = M if K == "M" else K
    attempt = _attempt_with_k(rng, M, K)
    mf, tf = ops.forest_best_splits(ao_y, ao_sum_x, ao_radius, ao_origin,
                                    attempt, backend=backend, compact=False)
    mc, tc = ops.forest_best_splits(ao_y, ao_sum_x, ao_radius, ao_origin,
                                    attempt, backend=backend, compact=True)
    mf, tf, mc, tc = map(np.asarray, (mf, tf, mc, tc))
    fin = np.isfinite(mf)
    assert (np.isfinite(mc) == fin).all()
    np.testing.assert_array_equal(mc[fin], mf[fin])
    np.testing.assert_array_equal(tc[fin], tf[fin])
    # non-attempting leaves are fully masked either way
    assert not np.isfinite(mc[~np.asarray(attempt)]).any()


@pytest.mark.parametrize("backend", ["interpret", "jnp"])
def test_traced_switch_matches_concrete_dispatch(backend, rng):
    """The lax.switch bucket selection (traced path) == the python-side
    bucket dispatch (concrete path) for every K regime."""
    ao_y, ao_sum_x, ao_radius, ao_origin = _forest_state(rng)
    M = ao_sum_x.shape[0]
    jitted = jax.jit(functools.partial(
        ops.forest_best_splits, backend=backend, compact=True))
    for K in (1, 3, 9, M):
        attempt = _attempt_with_k(rng, M, K)
        me, te = ops.forest_best_splits(
            ao_y, ao_sum_x, ao_radius, ao_origin, attempt, backend=backend)
        mt, tt = jitted(ao_y, ao_sum_x, ao_radius, ao_origin, attempt)
        np.testing.assert_array_equal(np.asarray(me), np.asarray(mt))
        fin = np.isfinite(np.asarray(me))
        np.testing.assert_array_equal(np.asarray(te)[fin],
                                      np.asarray(tt)[fin])


# --------------------------------------------------------------------------
# grace-period scheduling semantics
# --------------------------------------------------------------------------

def _two_cluster_batch(rng, n, F=3):
    """Linearly separable batch: feature 0 carries all the signal."""
    X = rng.normal(0, 0.05, (n, F)).astype(np.float32)
    half = n // 2
    X[:half, 0] -= 1.0
    X[half:, 0] += 1.0
    y = np.where(X[:, 0] <= 0, 0.0, 5.0).astype(np.float32)
    return jnp.array(X), jnp.array(y)


def test_grace_crossing_on_batch_boundary(rng):
    """A leaf whose counter hits grace_period EXACTLY at a batch boundary
    attempts on that batch — and one unit short of it does not."""
    F, bs = 3, 256
    X, y = _two_cluster_batch(rng, bs, F)
    # grace == batch size: the very first batch crosses exactly
    cfg = ht.HTRConfig(n_features=F, max_nodes=15, n_bins=32,
                       grace_period=bs, max_depth=4, r0=0.3, delta=1e-2)
    s = ht.update(cfg, ht.init_state(cfg), X, y)
    assert int(s["n_nodes"]) > 1, "attempt must fire at seen == grace"
    # grace one past the batch: no attempt on batch 1, attempt on batch 2
    cfg2 = ht.HTRConfig(n_features=F, max_nodes=15, n_bins=32,
                        grace_period=bs + 1, max_depth=4, r0=0.3, delta=1e-2)
    s2 = ht.update(cfg2, ht.init_state(cfg2), X, y)
    assert int(s2["n_nodes"]) == 1, "seen < grace must not attempt"
    assert float(s2["seen_since_attempt"][0]) == bs
    s2 = ht.update(cfg2, s2, X, y)
    assert int(s2["n_nodes"]) > 1


def test_failed_attempt_resets_grace_counter(rng):
    """Paper-faithful semantics: an attempt that does NOT split still
    resets seen_since_attempt, so the leaf leaves the attempt set until
    grace_period NEW mass arrives (no monotone always-attempting set)."""
    F = 2
    cfg = ht.HTRConfig(n_features=F, max_nodes=15, n_bins=32,
                       grace_period=100, max_depth=4, r0=0.3)
    X = jnp.array(rng.normal(0, 1, (150, F)).astype(np.float32))
    y = jnp.full((150,), 3.0, jnp.float32)      # constant target: VR == 0
    s = ht.update(cfg, ht.init_state(cfg), X, y)
    assert int(s["n_nodes"]) == 1, "zero-merit data must not split"
    assert float(s["seen_since_attempt"][0]) == 0.0, \
        "failed attempt must reset the grace counter"
    # the next sub-grace batch must NOT re-enter the attempt set
    s = ht.update(cfg, s, X[:50], y[:50])
    assert float(s["seen_since_attempt"][0]) == 50.0


def test_eager_schedule_keeps_mature_leaves_attempting():
    """attempt_schedule='eager': a mature leaf attempts every batch even
    right after a reset; 'grace' waits for fresh mass."""
    grace_cfg = ht.HTRConfig(n_features=2, max_nodes=7, grace_period=100)
    eager_cfg = ht.HTRConfig(n_features=2, max_nodes=7, grace_period=100,
                             attempt_schedule="eager")
    state = ht.init_state(grace_cfg)
    state = dict(state, ystats=jax.tree.map(
        lambda a, v: a.at[0].set(v),
        state["ystats"], {"n": 500.0, "mean": 1.0, "m2": 10.0}))
    # counter just reset (post-attempt): grace waits, eager re-attempts
    assert not bool(ht.attempt_mask(grace_cfg, state)[0])
    assert bool(ht.attempt_mask(eager_cfg, state)[0])
    state = dict(state,
                 seen_since_attempt=state["seen_since_attempt"].at[0].set(100.0))
    assert bool(ht.attempt_mask(grace_cfg, state)[0])
    with pytest.raises(ValueError):
        ht.HTRConfig(n_features=2, attempt_schedule="bogus")


# --------------------------------------------------------------------------
# the hard gate: learned trees bit-identical, compacted vs full scan
# --------------------------------------------------------------------------

def test_stream_trees_bit_identical_compacted_vs_full_scan():
    """The tier-1 stream protocol, compact_query on vs off: every state
    array of the learned trees must match exactly (mse_rel_diff == 0)."""
    X, y = synth.piecewise_regression(6000, n_features=3, seed=9)
    states = {}
    for compact in (True, False):
        cfg = ht.HTRConfig(n_features=3, max_nodes=31, n_bins=32,
                           grace_period=200, max_depth=6, r0=0.3,
                           compact_query=compact)
        states[compact] = ht.update_stream(cfg, ht.init_state(cfg),
                                           jnp.array(X), jnp.array(y),
                                           batch_size=256)
    flat_c, _ = jax.tree_util.tree_flatten_with_path(states[True])
    flat_f, _ = jax.tree_util.tree_flatten_with_path(states[False])
    for (path, a), (_, b) in zip(flat_c, flat_f):
        np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b),
            err_msg=f"state leaf {jax.tree_util.keystr(path)} diverged")
    cfg = ht.HTRConfig(n_features=3, max_nodes=31, n_bins=32,
                       grace_period=200, max_depth=6, r0=0.3)
    Xt, yt = synth.piecewise_regression(1000, n_features=3, seed=90)
    p_c = np.asarray(ht.predict(cfg, states[True], jnp.array(Xt)))
    p_f = np.asarray(ht.predict(cfg, states[False], jnp.array(Xt)))
    mse_c = float(np.mean((p_c - yt) ** 2))
    mse_f = float(np.mean((p_f - yt) ** 2))
    assert abs(mse_c - mse_f) / max(mse_f, 1e-12) == 0.0


# --------------------------------------------------------------------------
# cached-jit regression: same bucket never retraces
# --------------------------------------------------------------------------

def test_query_same_bucket_does_not_recompile(rng):
    ops.clear_jit_caches()
    ao_y, ao_sum_x, ao_radius, ao_origin = _forest_state(rng)  # M = 12
    M = ao_sum_x.shape[0]
    assert ops.query_buckets(M) == (8, 12)
    for K in (1, 3, 5):  # all land in the K_pad = 8 bucket
        ops.forest_best_splits(ao_y, ao_sum_x, ao_radius, ao_origin,
                               _attempt_with_k(rng, M, K), backend="jnp")
    handle = ops._jit_forest_query("jnp", 128, 8)
    assert handle._cache_size() == 1, "same-bucket queries retraced"
    # K past the last power-of-two bucket falls into the full-scan bucket
    ops.forest_best_splits(ao_y, ao_sum_x, ao_radius, ao_origin,
                           _attempt_with_k(rng, M, 10), backend="jnp")
    assert ops._jit_forest_query("jnp", 128, None)._cache_size() == 1
    assert handle._cache_size() == 1


def test_update_same_bucket_does_not_recompile(rng):
    ops.clear_jit_caches()
    ao_y, ao_sum_x, ao_radius, ao_origin = _forest_state(rng)
    M, F, C = ao_sum_x.shape
    for B in (100, 120, 128):  # one 128-row batch bucket
        leaf = jnp.array(rng.integers(0, M, B), jnp.int32)
        X = jnp.array(rng.normal(0, 1, (B, F)).astype(np.float32))
        y = jnp.array(rng.normal(0, 1, B).astype(np.float32))
        ops.forest_update(ao_y, ao_sum_x, ao_radius, ao_origin,
                          leaf, X, y, backend="jnp")
    assert ops._jit_forest_update("jnp", 256, 128)._cache_size() == 1, \
        "same-bucket batches retraced"
