"""QO attribute observer: split quality vs the exact oracle and E-BST."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import ebst, qo, stats  # noqa: E402
from repro.data import synth  # noqa: E402
from tests.helpers import exact_best_split  # noqa: E402


def test_qo_finds_planted_split(rng):
    x = rng.normal(0, 1, 8000).astype(np.float32)
    y = np.where(x <= 0.4, 1.0, 8.0) + 0.05 * rng.normal(0, 1, 8000)
    t = qo.init(256, radius=0.05)
    t = qo.update(t, jnp.array(x), jnp.array(y.astype(np.float32)))
    r = qo.best_split(t)
    assert bool(r.valid)
    assert abs(float(r.threshold) - 0.4) < 0.05
    merit_exact, _ = exact_best_split(x, y)
    assert float(r.merit) >= 0.9 * merit_exact  # paper §6.1: similar merit


def test_qo_merit_close_to_ebst_on_paper_protocol():
    """Paper Fig. 1: QO's VR within a few % of E-BST across tasks."""
    for task in ("lin", "cub"):
        cfg = synth.SynthConfig(dist="normal", variant=0, task=task,
                                n=3000, seed=1)
        x, y = synth.generate(cfg)
        sigma = float(np.std(x))
        t = qo.init(512, radius=sigma / 2, origin=float(np.mean(x)))
        t = qo.update(t, jnp.array(x), jnp.array(y))
        rq = qo.best_split(t)

        e = ebst.init(len(x))
        e = jax.jit(ebst.update)(e, jnp.array(x), jnp.array(y))
        re_ = jax.jit(ebst.best_split)(e)
        assert bool(rq.valid) and bool(re_.valid)
        assert float(rq.merit) >= 0.85 * float(re_.merit), task


def test_qo_batched_equals_streaming(rng):
    """Folding one batch == folding it in chunks (Chan merge correctness)."""
    x = rng.normal(0, 2, 1000).astype(np.float32)
    y = (x ** 2).astype(np.float32)
    t1 = qo.init(128, radius=0.2)
    t1 = qo.update(t1, jnp.array(x), jnp.array(y))
    t2 = qo.init(128, radius=0.2)
    for i in range(0, 1000, 100):
        t2 = qo.update(t2, jnp.array(x[i:i + 100]), jnp.array(y[i:i + 100]))
    for k in ("n", "mean", "m2"):
        np.testing.assert_allclose(np.asarray(t1["y"][k]), np.asarray(t2["y"][k]),
                                   rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(t1["sum_x"]), np.asarray(t2["sum_x"]),
                               rtol=1e-4, atol=1e-3)


def test_merge_tables_is_distributed_update(rng):
    """Two shards merged == one stream (the cross-device reduction)."""
    x = rng.normal(0, 1, 2000).astype(np.float32)
    y = np.sin(x).astype(np.float32)
    full = qo.update(qo.init(128, radius=0.1), jnp.array(x), jnp.array(y))
    a = qo.update(qo.init(128, radius=0.1), jnp.array(x[:1000]), jnp.array(y[:1000]))
    b = qo.update(qo.init(128, radius=0.1), jnp.array(x[1000:]), jnp.array(y[1000:]))
    merged = qo.merge_tables(a, b)
    for k in ("n", "mean", "m2"):
        np.testing.assert_allclose(np.asarray(full["y"][k]),
                                   np.asarray(merged["y"][k]), rtol=2e-3, atol=2e-3)
    rf, rm = qo.best_split(full), qo.best_split(merged)
    np.testing.assert_allclose(float(rf.threshold), float(rm.threshold), rtol=1e-4)


@given(st.integers(16, 512), st.integers(2, 400), st.integers(0, 10_000))
@settings(max_examples=30, deadline=None)
def test_qo_slot_count_bounded(capacity, n, seed):
    """|H| <= capacity and |H| <= n (the paper's memory claim |H| << n)."""
    rng = np.random.default_rng(seed)
    x = rng.normal(0, 1, n).astype(np.float32)
    t = qo.init(capacity, radius=0.5)
    t = qo.update(t, jnp.array(x), jnp.array(x))
    slots = int(qo.n_slots(t))
    assert 1 <= slots <= min(capacity, n)
    tot = qo.total_stats(t)
    assert abs(float(tot["n"]) - n) < 1e-3  # no observation lost


def test_empty_and_single_observation():
    t = qo.init(64, radius=0.1)
    r = qo.best_split(t)
    assert not bool(r.valid)
    t = qo.update(t, jnp.array([1.0]), jnp.array([2.0]))
    r = qo.best_split(t)
    assert not bool(r.valid)  # one bin -> no boundary


def test_quantization_radius_tradeoff(rng):
    """Paper §6.1/Fig.3: smaller radius -> more slots & merit closer to
    E-BST; larger radius -> fewer slots."""
    x = rng.normal(0, 1, 5000).astype(np.float32)
    y = np.where(x <= 0.25, 0.0, 4.0).astype(np.float32) + \
        0.1 * rng.normal(0, 1, 5000).astype(np.float32)
    merit_exact, _ = exact_best_split(x, y)
    slots, merits = [], []
    for r in (1.0, 0.5, 0.1, 0.02):
        t = qo.update(qo.init(1024, radius=r), jnp.array(x), jnp.array(y))
        slots.append(int(qo.n_slots(t)))
        merits.append(float(qo.best_split(t).merit))
    assert slots == sorted(slots), "smaller radius must give more slots"
    assert merits[-1] >= 0.98 * merit_exact
    assert merits[-1] >= merits[0] - 1e-3
