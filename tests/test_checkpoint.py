"""Checkpoint save/restore/corruption/reshard tests."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.ckpt import Checkpointer, reshard


def _tree(key):
    k1, k2 = jax.random.split(key)
    return {"params": {"w": jax.random.normal(k1, (32, 16)),
                       "b": jnp.zeros((16,))},
            "opt": {"m": jax.random.normal(k2, (32, 16)),
                    "step": jnp.int32(7)}}


def test_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path))
    tree = _tree(jax.random.PRNGKey(0))
    ck.save(10, tree, blocking=True)
    assert ck.latest_step() == 10
    template = jax.eval_shape(lambda: tree)
    rest = ck.restore(10, template)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a), np.asarray(b)), tree, rest)


def test_latest_pointer_and_gc(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    tree = _tree(jax.random.PRNGKey(1))
    for s in (5, 10, 15):
        ck.save(s, tree, blocking=True)
    assert ck.latest_step() == 15
    dirs = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert dirs == ["step_000000010", "step_000000015"]  # gc kept last 2


def test_async_save_then_wait(tmp_path):
    ck = Checkpointer(str(tmp_path))
    tree = _tree(jax.random.PRNGKey(2))
    ck.save(1, tree, blocking=False)
    ck.wait()
    assert ck.latest_step() == 1


def test_corruption_detected(tmp_path):
    ck = Checkpointer(str(tmp_path))
    tree = _tree(jax.random.PRNGKey(3))
    ck.save(1, tree, blocking=True)
    # corrupt the shard
    shard = tmp_path / "step_000000001" / "shard_0.npz"
    data = dict(np.load(shard))
    k = sorted(data)[0]
    data[k] = data[k] + 1.0
    np.savez(shard, **data)
    with pytest.raises(IOError, match="corruption"):
        ck.restore(1, jax.eval_shape(lambda: tree))


def test_reshard_onto_new_sharding(tmp_path):
    """Elastic restart: restore written under one mesh, place onto another."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    ck = Checkpointer(str(tmp_path))
    tree = {"w": jnp.arange(32.0).reshape(8, 4)}
    ck.save(1, tree, blocking=True)
    rest = ck.restore(1, jax.eval_shape(lambda: tree))
    from repro.launch.mesh import make_mesh_auto
    mesh = make_mesh_auto((1,), ("data",))
    sh = {"w": NamedSharding(mesh, P("data", None))}
    placed = reshard(rest, sh)
    assert placed["w"].sharding == sh["w"]
    np.testing.assert_allclose(np.asarray(placed["w"]), np.asarray(tree["w"]))
