"""Checkpoint save/restore/corruption/reshard tests."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.ckpt import Checkpointer, reshard


def _tree(key):
    k1, k2 = jax.random.split(key)
    return {"params": {"w": jax.random.normal(k1, (32, 16)),
                       "b": jnp.zeros((16,))},
            "opt": {"m": jax.random.normal(k2, (32, 16)),
                    "step": jnp.int32(7)}}


def test_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path))
    tree = _tree(jax.random.PRNGKey(0))
    ck.save(10, tree, blocking=True)
    assert ck.latest_step() == 10
    template = jax.eval_shape(lambda: tree)
    rest = ck.restore(10, template)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a), np.asarray(b)), tree, rest)


def test_latest_pointer_and_gc(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    tree = _tree(jax.random.PRNGKey(1))
    for s in (5, 10, 15):
        ck.save(s, tree, blocking=True)
    assert ck.latest_step() == 15
    dirs = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert dirs == ["step_000000010", "step_000000015"]  # gc kept last 2


def test_async_save_then_wait(tmp_path):
    ck = Checkpointer(str(tmp_path))
    tree = _tree(jax.random.PRNGKey(2))
    ck.save(1, tree, blocking=False)
    ck.wait()
    assert ck.latest_step() == 1


def test_corruption_detected(tmp_path):
    ck = Checkpointer(str(tmp_path))
    tree = _tree(jax.random.PRNGKey(3))
    ck.save(1, tree, blocking=True)
    # corrupt the shard
    shard = tmp_path / "step_000000001" / "shard_0.npz"
    data = dict(np.load(shard))
    k = sorted(data)[0]
    data[k] = data[k] + 1.0
    np.savez(shard, **data)
    with pytest.raises(IOError, match="corruption"):
        ck.restore(1, jax.eval_shape(lambda: tree))


def _small_forest():
    from repro.core import forest as fr, hoeffding as ht
    from repro.data import synth

    tree = ht.HTRConfig(n_features=4, max_nodes=31, n_bins=32,
                        grace_period=50, max_depth=6, r0=0.25)
    cfg = fr.ForestConfig(tree=tree, n_trees=4)
    X, y = synth.piecewise_regression(768, n_features=4, seed=11)
    state = fr.init_forest(cfg, jax.random.PRNGKey(2))
    state, _ = fr.update_stream(cfg, state, jnp.asarray(X), jnp.asarray(y))
    return cfg, state, jnp.asarray(X[:256])


def test_forest_state_roundtrip_predict_bitwise(tmp_path):
    """ForestState is a plain pytree: save -> restore_latest -> predict
    is bit-exact (the model-refresh/crash-recovery contract)."""
    from repro.core import forest as fr

    cfg, state, X = _small_forest()
    assert int(np.asarray(state["trees"]["n_nodes"]).max()) > 1  # trained
    ck = Checkpointer(str(tmp_path))
    ck.save(3, state, blocking=True)
    rest = ck.restore_latest(jax.eval_shape(lambda: state))
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), state, rest)
    np.testing.assert_array_equal(np.asarray(fr.predict(cfg, state, X)),
                                  np.asarray(fr.predict(cfg, rest, X)))


def test_snapshot_roundtrip_predict_bitwise(tmp_path):
    """serve.Snapshot (a registered-pytree dataclass) round-trips through
    the checkpointer with its static aux data (depth, single) intact and
    serves bit-identical predictions."""
    from repro.core import serve

    cfg, state, X = _small_forest()
    snap = serve.freeze(state)
    ck = Checkpointer(str(tmp_path))
    ck.save(7, snap, blocking=True)
    rest = ck.restore_latest(jax.eval_shape(lambda: snap))
    assert (rest.depth, rest.single) == (snap.depth, snap.single)
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), snap, rest)
    np.testing.assert_array_equal(
        np.asarray(serve.predict_snapshot(snap, X)),
        np.asarray(serve.predict_snapshot(rest, X)))


def test_restore_latest_empty_dir(tmp_path):
    ck = Checkpointer(str(tmp_path))
    with pytest.raises(FileNotFoundError):
        ck.restore_latest(jax.eval_shape(lambda: {"w": jnp.zeros(2)}))


def test_reshard_onto_new_sharding(tmp_path):
    """Elastic restart: restore written under one mesh, place onto another."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    ck = Checkpointer(str(tmp_path))
    tree = {"w": jnp.arange(32.0).reshape(8, 4)}
    ck.save(1, tree, blocking=True)
    rest = ck.restore(1, jax.eval_shape(lambda: tree))
    from repro.launch.mesh import make_mesh_auto
    mesh = make_mesh_auto((1,), ("data",))
    sh = {"w": NamedSharding(mesh, P("data", None))}
    placed = reshard(rest, sh)
    assert placed["w"].sharding == sh["w"]
    np.testing.assert_allclose(np.asarray(placed["w"]), np.asarray(tree["w"]))


# --------------------------------------------------------------------------
# validated restore: corrupt checkpoints are skipped, never served
# --------------------------------------------------------------------------

def _corrupt_shard(tmp_path, step):
    shard = tmp_path / f"step_{step:09d}" / "shard_0.npz"
    data = dict(np.load(shard))
    k = sorted(data)[0]
    data[k] = data[k] + 1.0
    np.savez(shard, **data)


def test_available_steps_lists_completed_dirs(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=10)
    tree = _tree(jax.random.PRNGKey(4))
    for s in (3, 1, 2):
        ck.save(s, tree, blocking=True)
    assert ck.available_steps() == [1, 2, 3]
    # a crashed writer's temp dir never shows up
    os.makedirs(tmp_path / ".tmp_step_000000009")
    assert ck.available_steps() == [1, 2, 3]


def test_restore_latest_falls_back_past_corrupt_newest(tmp_path):
    """The crash-recovery contract: a torn newest checkpoint is skipped
    and the previous good step is served, with its true step id."""
    ck = Checkpointer(str(tmp_path), keep=10)
    good = _tree(jax.random.PRNGKey(5))
    ck.save(1, good, blocking=True)
    ck.save(2, jax.tree.map(lambda a: a * 0 + 9.0
                            if a.dtype.kind == "f" else a, good),
            blocking=True)
    _corrupt_shard(tmp_path, 2)
    rest, step = ck.restore_latest(jax.eval_shape(lambda: good),
                                   return_step=True)
    assert step == 1
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), good, rest)


def test_restore_latest_falls_back_past_truncated_npz(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=10)
    good = _tree(jax.random.PRNGKey(6))
    ck.save(4, good, blocking=True)
    ck.save(7, good, blocking=True)
    shard = tmp_path / "step_000000007" / "shard_0.npz"
    shard.write_bytes(shard.read_bytes()[:40])  # cut mid-write
    rest, step = ck.restore_latest(jax.eval_shape(lambda: good),
                                   return_step=True)
    assert step == 4


def test_restore_latest_raises_when_nothing_valid(tmp_path):
    ck = Checkpointer(str(tmp_path))
    tree = _tree(jax.random.PRNGKey(7))
    ck.save(1, tree, blocking=True)
    _corrupt_shard(tmp_path, 1)
    with pytest.raises(FileNotFoundError, match="no valid checkpoint"):
        ck.restore_latest(jax.eval_shape(lambda: tree))


def test_restore_detects_schema_mismatch(tmp_path):
    """Shape/dtype drift between manifest and shard is corruption, not
    an assert — the serving engine must survive it."""
    from repro.checkpoint.ckpt import CheckpointCorruption

    ck = Checkpointer(str(tmp_path))
    tree = _tree(jax.random.PRNGKey(8))
    ck.save(1, tree, blocking=True)
    shard = tmp_path / "step_000000001" / "shard_0.npz"
    data = dict(np.load(shard))
    k = sorted(data)[0]
    data[k] = data[k].reshape(-1)  # same bytes, wrong shape
    np.savez(shard, **data)
    with pytest.raises(CheckpointCorruption, match="corruption in leaf"):
        ck.restore(1, jax.eval_shape(lambda: tree))
