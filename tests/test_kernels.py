"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import qo
from repro.kernels import ops, ref
from repro.kernels.qo_update import qo_update_pallas
from repro.kernels.qo_query import qo_query_pallas


@pytest.mark.parametrize("cap", [128, 256, 512])
@pytest.mark.parametrize("n", [64, 1000, 4096])
def test_qo_update_kernel_matches_oracle(cap, n, rng):
    x = rng.normal(0.3, 1.7, n).astype(np.float32)
    y = (np.sin(x) * 3).astype(np.float32)
    t0 = qo.init(cap, radius=0.07, origin=0.3)
    t_ref = qo.update(t0, jnp.array(x), jnp.array(y))
    t_ker = ops.qo_update(t0, jnp.array(x), jnp.array(y), interpret=True)
    for k in ("n", "mean", "m2"):
        np.testing.assert_allclose(np.asarray(t_ref["y"][k]),
                                   np.asarray(t_ker["y"][k]),
                                   rtol=5e-4, atol=5e-4, err_msg=k)
    np.testing.assert_allclose(np.asarray(t_ref["sum_x"]),
                               np.asarray(t_ker["sum_x"]), rtol=1e-5, atol=1e-3)


@pytest.mark.parametrize("cap", [128, 256])
def test_qo_update_kernel_weighted(cap, rng):
    n = 777
    x = rng.normal(0, 1, n).astype(np.float32)
    y = (x * 2 + 1).astype(np.float32)
    w = rng.uniform(0.1, 2.0, n).astype(np.float32)
    t0 = qo.init(cap, radius=0.1)
    t_ref = qo.update(t0, jnp.array(x), jnp.array(y), jnp.array(w))
    t_ker = ops.qo_update(t0, jnp.array(x), jnp.array(y), jnp.array(w),
                          interpret=True)
    for k in ("n", "mean", "m2"):
        np.testing.assert_allclose(np.asarray(t_ref["y"][k]),
                                   np.asarray(t_ker["y"][k]),
                                   rtol=1e-3, atol=1e-3, err_msg=k)


def test_qo_update_kernel_incremental(rng):
    """Seeded continuation: second call accumulates onto the first."""
    cap = 128
    x = rng.normal(0, 1, 600).astype(np.float32)
    y = x.copy()
    t = qo.init(cap, radius=0.1)
    t = ops.qo_update(t, jnp.array(x[:300]), jnp.array(y[:300]), interpret=True)
    t = ops.qo_update(t, jnp.array(x[300:]), jnp.array(y[300:]), interpret=True)
    ref_t = qo.update(qo.init(cap, radius=0.1), jnp.array(x), jnp.array(y))
    np.testing.assert_allclose(np.asarray(t["y"]["n"]),
                               np.asarray(ref_t["y"]["n"]), atol=1e-3)
    np.testing.assert_allclose(float(qo.total_stats(t)["mean"]),
                               float(qo.total_stats(ref_t)["mean"]), rtol=1e-4)


@pytest.mark.parametrize("cap", [128, 256, 512])
def test_qo_query_kernel_matches_oracle(cap, rng):
    x = rng.normal(0.5, 2.0, 3000).astype(np.float32)
    y = np.where(x <= 1.0, 0.0, 5.0).astype(np.float32)
    t = qo.update(qo.init(cap, radius=0.15, origin=0.5),
                  jnp.array(x), jnp.array(y))
    dense, _ = ref.pack_table(t)
    out_k = qo_query_pallas(dense, interpret=True)
    out_r = ref.qo_query_ref(dense)
    # VR scores equal where valid
    valid = np.isfinite(np.asarray(out_r[0]))
    np.testing.assert_allclose(np.asarray(out_k[0])[valid],
                               np.asarray(out_r[0])[valid], rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(np.asarray(out_k[1])[valid],
                               np.asarray(out_r[1])[valid], rtol=1e-4)
    r_api = ops.qo_best_split(t, interpret=True)
    r_core = qo.best_split(t)
    np.testing.assert_allclose(float(r_api.threshold), float(r_core.threshold),
                               rtol=1e-4)
    np.testing.assert_allclose(float(r_api.merit), float(r_core.merit),
                               rtol=1e-3)


def test_query_kernel_sparse_table(rng):
    """Few occupied, widely separated bins."""
    t = qo.init(256, radius=0.01)
    x = np.array([-1.0, -1.0, 0.5, 0.5, 0.9], np.float32)
    y = np.array([0.0, 0.1, 5.0, 5.1, 5.2], np.float32)
    t = qo.update(t, jnp.array(x), jnp.array(y))
    r_k = ops.qo_best_split(t, interpret=True)
    r_c = qo.best_split(t)
    assert bool(r_k.valid)
    np.testing.assert_allclose(float(r_k.threshold), float(r_c.threshold), rtol=1e-5)
    # split must separate the -1 cluster from the rest
    assert -1.0 < float(r_k.threshold) < 0.5


def test_kernel_tile_padding(rng):
    """N not a multiple of the tile: padding rows must not contribute."""
    for n in (1, 127, 129, 1025):
        x = rng.normal(0, 1, n).astype(np.float32)
        t = ops.qo_update(qo.init(128, radius=0.2), jnp.array(x), jnp.array(x),
                          interpret=True)
        assert abs(float(qo.total_stats(t)["n"]) - n) < 1e-3


# --------------------------------------------------------------------------
# qo_update tile clamp: pad/clamp is a schedule, never a semantics, knob
# --------------------------------------------------------------------------

def test_qo_update_tile_clamp_formula():
    """A batch whose pow-2 round-up fits one maximal tile is absorbed in
    a SINGLE pass of exactly that round-up (floored at the 128-lane
    alignment) no matter what tile was requested — the request is a
    streaming cap for big batches, not a splitter for small ones.  The
    old min(tile, round_up) clamp split B = 129 into two 128-passes
    under tile=128 but one 256-pass otherwise: same math, different f32
    merge order, different bits."""
    assert ops.qo_update_tile(1, 1024) == 128
    assert ops.qo_update_tile(127, 1024) == 128
    assert ops.qo_update_tile(128, 1024) == 128
    assert ops.qo_update_tile(129, 1024) == 256
    assert ops.qo_update_tile(129, 128) == 256     # request ignored: 1 pass
    assert ops.qo_update_tile(1024, 128) == 1024   # still single-pass
    assert ops.qo_update_tile(4096, 1024) == 1024  # big B: requested cap
    assert ops.qo_update_tile(4096, 512) == 512    # streaming cap honored


@pytest.mark.parametrize("B", [1, 127, 128, 129])
def test_qo_update_clamp_bit_identical_across_tiles(B, rng):
    """B around the 128 boundary x every tile choice: the padded/clamped
    update must be BIT-identical — the single-pass rule resolves every
    request to the same one-tile schedule, and pad rows carry w = 0 and
    vanish, so no tile choice may perturb a single bit."""
    x = rng.normal(0.2, 1.3, B).astype(np.float32)
    y = (x * 1.7 - 0.4).astype(np.float32)
    t0 = qo.init(128, radius=0.15)
    outs = []
    for tile in (128, 256, 1024):
        t = ops.qo_update(t0, jnp.array(x), jnp.array(y), tile=tile,
                          interpret=True)
        outs.append(jax.tree.leaves(t))
    for leaves in outs[1:]:
        for a, b in zip(outs[0], leaves):
            np.testing.assert_array_equal(
                np.asarray(a), np.asarray(b),
                err_msg=f"B={B}: tile choice changed bits")


def test_pallas_backend_falls_back_off_tpu(rng):
    """backend="pallas" on a host with neither TPU nor GPU must run the
    kernel under the interpreter (the multi-backend smoke contract) and
    agree with the jnp lowering — not fail to compile."""
    if jax.default_backend() in ("tpu", "gpu"):
        pytest.skip("native kernel path exists here")
    assert ops._kernel_interpret("pallas") is True
    assert ops._kernel_interpret("interpret") is True
    M, F, C, B = 16, 3, 8, 64
    from repro.core import stats
    ao_y = stats.init((M, F, C))
    ao_sum_x = jnp.zeros((M, F, C))
    ao_radius = jnp.full((M, F), 0.2, jnp.float32)
    ao_origin = jnp.zeros((M, F), jnp.float32)
    leaf = jnp.array(rng.integers(0, M, B), jnp.int32)
    X = jnp.array(rng.normal(0, 1, (B, F)).astype(np.float32))
    y = jnp.array(rng.normal(0, 1, B).astype(np.float32))
    ky, ksx = ops.forest_update(ao_y, ao_sum_x, ao_radius, ao_origin,
                                leaf, X, y, backend="pallas")
    jy, jsx = ops.forest_update(ao_y, ao_sum_x, ao_radius, ao_origin,
                                leaf, X, y, backend="jnp")
    np.testing.assert_allclose(np.asarray(ky["n"]), np.asarray(jy["n"]),
                               atol=1e-3)
    np.testing.assert_allclose(np.asarray(ksx), np.asarray(jsx),
                               rtol=1e-4, atol=1e-3)
