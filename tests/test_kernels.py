"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import qo
from repro.kernels import ops, ref
from repro.kernels.qo_update import qo_update_pallas
from repro.kernels.qo_query import qo_query_pallas


@pytest.mark.parametrize("cap", [128, 256, 512])
@pytest.mark.parametrize("n", [64, 1000, 4096])
def test_qo_update_kernel_matches_oracle(cap, n, rng):
    x = rng.normal(0.3, 1.7, n).astype(np.float32)
    y = (np.sin(x) * 3).astype(np.float32)
    t0 = qo.init(cap, radius=0.07, origin=0.3)
    t_ref = qo.update(t0, jnp.array(x), jnp.array(y))
    t_ker = ops.qo_update(t0, jnp.array(x), jnp.array(y), interpret=True)
    for k in ("n", "mean", "m2"):
        np.testing.assert_allclose(np.asarray(t_ref["y"][k]),
                                   np.asarray(t_ker["y"][k]),
                                   rtol=5e-4, atol=5e-4, err_msg=k)
    np.testing.assert_allclose(np.asarray(t_ref["sum_x"]),
                               np.asarray(t_ker["sum_x"]), rtol=1e-5, atol=1e-3)


@pytest.mark.parametrize("cap", [128, 256])
def test_qo_update_kernel_weighted(cap, rng):
    n = 777
    x = rng.normal(0, 1, n).astype(np.float32)
    y = (x * 2 + 1).astype(np.float32)
    w = rng.uniform(0.1, 2.0, n).astype(np.float32)
    t0 = qo.init(cap, radius=0.1)
    t_ref = qo.update(t0, jnp.array(x), jnp.array(y), jnp.array(w))
    t_ker = ops.qo_update(t0, jnp.array(x), jnp.array(y), jnp.array(w),
                          interpret=True)
    for k in ("n", "mean", "m2"):
        np.testing.assert_allclose(np.asarray(t_ref["y"][k]),
                                   np.asarray(t_ker["y"][k]),
                                   rtol=1e-3, atol=1e-3, err_msg=k)


def test_qo_update_kernel_incremental(rng):
    """Seeded continuation: second call accumulates onto the first."""
    cap = 128
    x = rng.normal(0, 1, 600).astype(np.float32)
    y = x.copy()
    t = qo.init(cap, radius=0.1)
    t = ops.qo_update(t, jnp.array(x[:300]), jnp.array(y[:300]), interpret=True)
    t = ops.qo_update(t, jnp.array(x[300:]), jnp.array(y[300:]), interpret=True)
    ref_t = qo.update(qo.init(cap, radius=0.1), jnp.array(x), jnp.array(y))
    np.testing.assert_allclose(np.asarray(t["y"]["n"]),
                               np.asarray(ref_t["y"]["n"]), atol=1e-3)
    np.testing.assert_allclose(float(qo.total_stats(t)["mean"]),
                               float(qo.total_stats(ref_t)["mean"]), rtol=1e-4)


@pytest.mark.parametrize("cap", [128, 256, 512])
def test_qo_query_kernel_matches_oracle(cap, rng):
    x = rng.normal(0.5, 2.0, 3000).astype(np.float32)
    y = np.where(x <= 1.0, 0.0, 5.0).astype(np.float32)
    t = qo.update(qo.init(cap, radius=0.15, origin=0.5),
                  jnp.array(x), jnp.array(y))
    dense, _ = ref.pack_table(t)
    out_k = qo_query_pallas(dense, interpret=True)
    out_r = ref.qo_query_ref(dense)
    # VR scores equal where valid
    valid = np.isfinite(np.asarray(out_r[0]))
    np.testing.assert_allclose(np.asarray(out_k[0])[valid],
                               np.asarray(out_r[0])[valid], rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(np.asarray(out_k[1])[valid],
                               np.asarray(out_r[1])[valid], rtol=1e-4)
    r_api = ops.qo_best_split(t, interpret=True)
    r_core = qo.best_split(t)
    np.testing.assert_allclose(float(r_api.threshold), float(r_core.threshold),
                               rtol=1e-4)
    np.testing.assert_allclose(float(r_api.merit), float(r_core.merit),
                               rtol=1e-3)


def test_query_kernel_sparse_table(rng):
    """Few occupied, widely separated bins."""
    t = qo.init(256, radius=0.01)
    x = np.array([-1.0, -1.0, 0.5, 0.5, 0.9], np.float32)
    y = np.array([0.0, 0.1, 5.0, 5.1, 5.2], np.float32)
    t = qo.update(t, jnp.array(x), jnp.array(y))
    r_k = ops.qo_best_split(t, interpret=True)
    r_c = qo.best_split(t)
    assert bool(r_k.valid)
    np.testing.assert_allclose(float(r_k.threshold), float(r_c.threshold), rtol=1e-5)
    # split must separate the -1 cluster from the rest
    assert -1.0 < float(r_k.threshold) < 0.5


def test_kernel_tile_padding(rng):
    """N not a multiple of the tile: padding rows must not contribute."""
    for n in (1, 127, 129, 1025):
        x = rng.normal(0, 1, n).astype(np.float32)
        t = ops.qo_update(qo.init(128, radius=0.2), jnp.array(x), jnp.array(x),
                          interpret=True)
        assert abs(float(qo.total_stats(t)["n"]) - n) < 1e-3
