"""Test config: CPU compute dtype + a few shared fixtures.

NOTE: no XLA_FLAGS here on purpose — smoke tests and benches must see ONE
device; only launch/dryrun.py forces 512 placeholder devices.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import layers as L

L.set_compute_dtype(jnp.float32)  # CPU cannot execute bf16 dots


@pytest.fixture
def rng():
    return np.random.default_rng(0)
