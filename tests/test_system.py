"""End-to-end behaviour tests: the fault-tolerant trainer on a real
(tiny) model, resume-after-kill, and the QO-monitored training loop."""
import functools
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.configs import ShapeConfig, reduced
from repro.data.tokens import TokenStream
from repro.launch.mesh import make_local_mesh
from repro.optim import adamw
from repro.train.loop import LoopConfig, Trainer


def _mk_trainer(tmp_path, steps=24, arch="phi3-mini-3.8b", horizon=None):
    """``steps`` = where this run stops; ``horizon`` = the schedule's true
    total (a preempted run keeps the full-horizon LR schedule)."""
    cfg = reduced(configs.get_arch(arch), d_model=64, n_layers=2,
                  n_heads=4, n_kv_heads=4, d_ff=128, vocab=128, head_dim=16)
    mesh = make_local_mesh(1, 1)
    shape = ShapeConfig("t", 64, 4, "train")
    data = TokenStream(vocab=cfg.vocab, seq_len=64, global_batch=4, seed=1)
    lc = LoopConfig(total_steps=steps, ckpt_every=8, log_every=4,
                    ckpt_dir=str(tmp_path), kv_chunk=32)
    opt = adamw.AdamWConfig(lr=5e-3, total_steps=horizon or steps,
                            warmup_steps=4)
    return Trainer(cfg, shape, mesh, data, lc, opt)


def test_training_reduces_loss(tmp_path):
    tr = _mk_trainer(tmp_path, steps=24)
    logs = []
    tr.run(log_fn=logs.append)
    losses = [r["loss"] for r in logs if "loss" in r]
    assert losses[-1] < losses[0] - 0.1, losses
    assert all(r.get("skipped", 0) == 0 for r in logs if "loss" in r)


def test_resume_from_checkpoint_is_exact(tmp_path):
    # run 16 steps in one go
    tr_full = _mk_trainer(tmp_path / "full", steps=16)
    p_full, _, _, _ = tr_full.run(log_fn=lambda r: None)

    # run 8 steps (ckpt_every=8 saves at step 8), then a NEW trainer resumes
    tr_a = _mk_trainer(tmp_path / "split", steps=8, horizon=16)
    tr_a.run(log_fn=lambda r: None)
    tr_b = _mk_trainer(tmp_path / "split", steps=16)
    assert tr_b.ckpt.latest_step() == 8
    p_split, _, _, _ = tr_b.run(log_fn=lambda r: None)

    flat_f = jax.tree.leaves(p_full)
    flat_s = jax.tree.leaves(p_split)
    for a, b in zip(flat_f, flat_s):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-4)


def test_monitor_collects_during_training(tmp_path):
    tr = _mk_trainer(tmp_path, steps=8)
    _, _, mon, _ = tr.run(log_fn=lambda r: None)
    from repro.train import monitor as MON
    s = MON.summaries(mon)
    assert float(s["loss"]["count"]) == 8
    assert float(s["step_time"]["count"]) == 8
    assert float(s["loss"]["p50"]) > 0


def test_nan_step_is_skipped():
    """A poisoned step must not destroy the parameters."""
    from repro.train import steps as ST
    from repro.models import model as M
    from repro.train import monitor as MON
    cfg = reduced(configs.get_arch("phi3-mini-3.8b"), d_model=32, n_layers=1,
                  n_heads=2, n_kv_heads=2, d_ff=64, vocab=64, head_dim=16)
    mesh = make_local_mesh(1, 1)
    shape = ShapeConfig("t", 32, 2, "train")
    fn, in_sh, _, shapes = ST.build_train_step(cfg, shape, mesh, donate=False)
    with mesh:
        params = jax.jit(lambda k: M.init_params(k, cfg))(jax.random.PRNGKey(0))
        opt = jax.jit(adamw.init_state)(params)
        mon = MON.init_monitor()
        bad = {"tokens": jnp.zeros((2, 32), jnp.int32),
               "labels": jnp.zeros((2, 32), jnp.int32)}
        poisoned = jax.tree.map(
            lambda p: p.at[(0,) * p.ndim].set(jnp.nan) if p.ndim else p, params)
        p2, o2, metrics, mon = fn(poisoned, opt, bad, mon)
        assert float(metrics["skipped"]) == 1.0
        for a, b in zip(jax.tree.leaves(p2), jax.tree.leaves(poisoned)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
