"""Tests for the multi-target QO extension and the HLO cost walker."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import multi, qo
from repro.launch import hlocost


# ---- multi-target QO (paper §7 future work) ------------------------------

def test_multi_target_reduces_to_single(rng):
    x = rng.normal(0, 1, 4000).astype(np.float32)
    y = np.where(x <= 0.2, 1.0, 7.0).astype(np.float32)
    t1 = qo.update(qo.init(256, radius=0.1), jnp.array(x), jnp.array(y))
    tm = multi.update(multi.init(256, 1, radius=0.1), jnp.array(x),
                      jnp.array(y[:, None]))
    r1, rm = qo.best_split(t1), multi.best_split(tm)
    np.testing.assert_allclose(float(r1.threshold), float(rm.threshold),
                               rtol=1e-4)
    assert int(qo.n_slots(t1)) == int(multi.n_slots(tm))


def test_multi_target_finds_shared_split(rng):
    """Two targets that agree on the cut point; one has 100x the scale —
    per-target normalization must keep both influential."""
    x = rng.normal(0, 1, 6000).astype(np.float32)
    y1 = np.where(x <= -0.1, 0.0, 1.0) + 0.05 * rng.normal(0, 1, 6000)
    y2 = 100 * np.where(x <= -0.1, 2.0, 5.0) + rng.normal(0, 1, 6000)
    Y = np.stack([y1, y2], 1).astype(np.float32)
    t = multi.update(multi.init(512, 2, radius=0.05), jnp.array(x),
                     jnp.array(Y))
    r = multi.best_split(t)
    assert bool(r.valid)
    assert abs(float(r.threshold) + 0.1) < 0.06


def test_multi_target_conflicting_targets(rng):
    """Targets with different best cuts: merit maximizes the AVERAGE."""
    x = rng.uniform(-1, 1, 8000).astype(np.float32)
    y1 = np.where(x <= -0.5, 0.0, 1.0)
    y2 = np.where(x <= 0.5, 0.0, 1.0)
    Y = np.stack([y1, y2], 1).astype(np.float32)
    t = multi.update(multi.init(512, 2, radius=0.02), jnp.array(x),
                     jnp.array(Y))
    r = multi.best_split(t)
    # either boundary is a 0.5-normalized-VR optimum; both beat the middle
    assert bool(r.valid)
    assert abs(abs(float(r.threshold)) - 0.5) < 0.1


# ---- HLO cost walker ------------------------------------------------------

def test_walker_counts_scan_trip_counts():
    def f(x, w):
        def body(c, wi):
            return jnp.tanh(c @ wi), None
        return jax.lax.scan(body, x, w)[0]

    comp = jax.jit(f).lower(
        jax.ShapeDtypeStruct((64, 64), jnp.float32),
        jax.ShapeDtypeStruct((5, 64, 64), jnp.float32)).compile()
    r = hlocost.analyze(comp.as_text())
    assert r["flops"] == 5 * 2 * 64 ** 3
    # raw cost_analysis counts the body once — the walker must not
    assert hlocost.cost_dict(comp)["flops"] < r["flops"]


def test_walker_nested_scans_multiply():
    def g(x, w):
        def outer(c, wi):
            def inner(c2, _):
                return jnp.tanh(c2 @ wi), None
            return jax.lax.scan(inner, c, None, length=3)[0], None
        return jax.lax.scan(outer, x, w)[0]

    comp = jax.jit(g).lower(
        jax.ShapeDtypeStruct((32, 32), jnp.float32),
        jax.ShapeDtypeStruct((4, 32, 32), jnp.float32)).compile()
    r = hlocost.analyze(comp.as_text())
    assert r["flops"] == 4 * 3 * 2 * 32 ** 3


def test_walker_plain_matmul():
    comp = jax.jit(lambda a, b: a @ b).lower(
        jax.ShapeDtypeStruct((128, 256), jnp.float32),
        jax.ShapeDtypeStruct((256, 64), jnp.float32)).compile()
    r = hlocost.analyze(comp.as_text())
    assert r["flops"] == 2 * 128 * 256 * 64
    # traffic at least the operands + result once
    assert r["bytes"] >= (128 * 256 + 256 * 64 + 128 * 64) * 4
