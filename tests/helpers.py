"""Shared test oracles (importable without pulling in any test module)."""
import numpy as np


def repeat_by_weights(w, *arrays):
    """Expand integer sample weights into repeated unit-weight rows.

    ``w``: (B,) non-negative ints.  Each of ``arrays`` (leading dim B) is
    repeated row-wise w[i] times — the bagging identity the weighted
    kernels must satisfy: absorbing (row, weight w) must equal absorbing
    w copies of the row at weight 1 (weight-0 rows vanish).
    """
    w = np.asarray(w, np.int64)
    idx = np.repeat(np.arange(len(w)), w)
    return tuple(np.asarray(a)[idx] for a in arrays)


def exact_best_split(x, y):
    """Exhaustive batch VR maximization (the batch-DT oracle)."""
    order = np.argsort(x, kind="stable")
    xs, ys = np.asarray(x, np.float64)[order], np.asarray(y, np.float64)[order]
    n = len(ys)
    csum, csq = np.cumsum(ys), np.cumsum(ys ** 2)
    tot, totsq = csum[-1], csq[-1]
    s2d = np.var(ys, ddof=1)
    best = (-np.inf, None)
    for i in range(n - 1):
        if xs[i] == xs[i + 1]:
            continue
        nl, nr = i + 1, n - i - 1
        vl = (csq[i] - csum[i] ** 2 / nl) / (nl - 1) if nl > 1 else 0.0
        vr = ((totsq - csq[i]) - (tot - csum[i]) ** 2 / nr) / (nr - 1) if nr > 1 else 0.0
        m = s2d - nl / n * vl - nr / n * vr
        if m > best[0]:
            best = (m, xs[i])
    return best
