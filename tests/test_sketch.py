"""Sketch-observer contract: core/sketch.py + the ``observer_backend``
knob (DESIGN.md §2.8).

Four pillars, mirroring tests/test_decide.py's structure:

* **Mergeability algebra** — the sketch merge is commutative (bitwise on
  distinct prototypes, the stable-sort guarantee), associative within
  the documented rank-error bound, and ``merge(A, B)`` agrees with a
  single-pass ``sketch(A ‖ B)`` within the same bound; capacity
  saturates at exactly K slots; weight-w rows equal w repeated unit
  rows exactly in the total statistics (and slot-for-slot when no
  bucket straddles a prototype); empty and single-element sketches are
  merge identities.  Property tests run under hypothesis when
  installed, with deterministic fallbacks.
* **Merit-error oracle gate** — trees and forests trained with
  ``observer_backend="sketch"`` on fixed-seed step streams must place
  their first split within an ε-rank band of
  ``tests/helpers.py::exact_best_split`` on the exact prefix the
  observer saw, under BOTH the grace and eager attempt schedules, and
  the exact merit at the sketch threshold must retain ≥ MERIT_FRAC of
  the oracle optimum.  benchmarks/check_regression.py runs the same
  gate over the BENCH_sketch streams.
* **Kernel contract** — ``ops.sketch_update`` / ``ops.sketch_merge``
  match their ref.py oracles on every backend, batch-ladder padding and
  ``tile_r`` are bitwise no-ops, traced callers inline, and the
  ``sketch_to_bins`` densify adapter is idempotent and merit-preserving
  (it feeds the UNCHANGED prefix-merge VR query).
* **Non-regression pins** — ``observer_backend="qo"`` (the default) is
  bit-identical to a config that never mentions the knob, the observer
  choice never reaches a kernel jit-cache key (``cache_info`` /
  ``_cache_size`` stay unfragmented across observer and sketch_k
  changes), freeze drops sketch state from snapshots, and the sketch
  planes round-trip through the checkpointer and the PR-5 DP sync
  protocol without protocol changes.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import forest as fr
from repro.core import hoeffding as ht
from repro.core import serve as sv
from repro.core import sketch as sk
from repro.core import stats
from repro.kernels import ops, ref
from repro.train import sharding
from tests.helpers import exact_best_split, repeat_by_weights

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover
    HAVE_HYPOTHESIS = False
needs_hypothesis = pytest.mark.skipif(not HAVE_HYPOTHESIS,
                                      reason="hypothesis not installed")

BACKENDS = [
    "interpret", "jnp",
    pytest.param("pallas", marks=pytest.mark.skipif(
        jax.default_backend() != "tpu",
        reason="compiled Pallas kernels need a TPU")),
]

#: documented rank-error budget per merge level, in units of 1/K
#: (§2.8: one compaction moves any rank by < 1 bucket width)
RANK_SLACK = 4.0


def _table_planes(t):
    return np.asarray(t["y"]["n"]), np.asarray(t["y"]["mean"]), \
        np.asarray(t["y"]["m2"]), np.asarray(t["sum_x"])


def _assert_tables_equal(a, b, *, bitwise=True, rtol=1e-5, atol=1e-6):
    for pa, pb in zip(_table_planes(a), _table_planes(b)):
        if bitwise:
            np.testing.assert_array_equal(pa, pb)
        else:
            np.testing.assert_allclose(pa, pb, rtol=rtol, atol=atol)


def _rank(xs, v):
    """Empirical CDF of sample ``xs`` at value ``v``."""
    return float(np.mean(np.asarray(xs, np.float64) <= float(v)))


def _merit_at(x, y, thr):
    """Exact VR (helpers.exact_best_split's formula) at a GIVEN cut."""
    x = np.asarray(x, np.float64)
    y = np.asarray(y, np.float64)
    left = x <= float(thr)
    nl, nr = int(left.sum()), int((~left).sum())
    if nl == 0 or nr == 0:
        return -np.inf
    n = len(y)
    vl = np.var(y[left], ddof=1) if nl > 1 else 0.0
    vr = np.var(y[~left], ddof=1) if nr > 1 else 0.0
    return np.var(y, ddof=1) - nl / n * vl - nr / n * vr


def _lognormal(seed, n):
    rng = np.random.default_rng(seed)
    x = rng.lognormal(0.0, 1.0, size=n).astype(np.float32)
    y = (np.log(x) + 0.1 * rng.normal(size=n)).astype(np.float32)
    return x, y


# --------------------------------------------------------------------------
# mergeability algebra (satellite 1)
# --------------------------------------------------------------------------

def test_empty_and_single_element():
    e = sk.init(8)
    assert int(sk.n_slots(e)) == 0
    _assert_tables_equal(sk.merge(e, e), e)

    s = sk.from_batch(np.float32([3.0]), np.float32([2.0]), k=8)
    assert int(sk.n_slots(s)) == 1
    tot = sk.total_stats(s)
    assert float(tot["n"]) == 1.0
    assert float(tot["mean"]) == pytest.approx(2.0)
    # a single occupied slot offers no boundary: no valid split
    assert not bool(sk.best_split(s).valid)
    # empty is a (two-sided) merge identity on the total statistics
    for m in (sk.merge(s, e), sk.merge(e, s)):
        mt = sk.total_stats(m)
        assert float(mt["n"]) == 1.0
        assert float(mt["mean"]) == pytest.approx(2.0)


def test_merge_commutative_bitwise_on_distinct_prototypes():
    # disjoint value sets -> all prototypes distinct -> the stable sort
    # inside compaction sees the SAME ordered centroid list either way,
    # so the two merge orders are bitwise identical
    xa, ya = _lognormal(11, 300)
    xb, yb = _lognormal(12, 300)
    xb = xb + 100.0  # disjoint support
    a = sk.from_batch(xa, ya, k=16)
    b = sk.from_batch(xb, yb, k=16)
    _assert_tables_equal(sk.merge(a, b), sk.merge(b, a), bitwise=True)


def test_merge_associative_within_rank_eps():
    k = 32
    parts = [_lognormal(20 + i, 400) for i in range(3)]
    ts = [sk.from_batch(x, y, k=k) for x, y in parts]
    left = sk.merge(sk.merge(ts[0], ts[1]), ts[2])
    right = sk.merge(ts[0], sk.merge(ts[1], ts[2]))
    # total statistics are exactly associative (Chan merge algebra)
    for key in ("n", "mean", "m2"):
        np.testing.assert_allclose(float(sk.total_stats(left)[key]),
                                   float(sk.total_stats(right)[key]),
                                   rtol=1e-5)
    # quantile geometry agrees within the rank-error budget
    xs = np.concatenate([p[0] for p in parts])
    for q in (0.1, 0.25, 0.5, 0.75, 0.9):
        rl = _rank(xs, sk.quantile_sk(left, q))
        rr = _rank(xs, sk.quantile_sk(right, q))
        assert abs(rl - rr) <= RANK_SLACK / k


def test_merge_equals_single_pass_within_rank_eps():
    k = 32
    xa, ya = _lognormal(31, 600)
    xb, yb = _lognormal(32, 600)
    merged = sk.merge(sk.from_batch(xa, ya, k=k), sk.from_batch(xb, yb, k=k))
    single = sk.from_batch(np.concatenate([xa, xb]),
                           np.concatenate([ya, yb]), k=k)
    for key in ("n", "mean", "m2"):
        np.testing.assert_allclose(float(sk.total_stats(merged)[key]),
                                   float(sk.total_stats(single)[key]),
                                   rtol=1e-5)
    xs = np.concatenate([xa, xb])
    for q in (0.1, 0.25, 0.5, 0.75, 0.9):
        rm = _rank(xs, sk.quantile_sk(merged, q))
        rs = _rank(xs, sk.quantile_sk(single, q))
        assert abs(rm - rs) <= RANK_SLACK / k
        assert abs(rm - q) <= RANK_SLACK / k


def test_capacity_saturation():
    k = 16
    x, y = _lognormal(40, 2500)  # >> k distinct values
    t = sk.from_batch(x, y, k=k)
    n, _, _, sum_x = _table_planes(t)
    assert int(sk.n_slots(t)) == k          # every slot occupied...
    assert n.shape == (k,)                  # ...and never more than k
    np.testing.assert_allclose(float(n.sum()), 2500.0, rtol=1e-6)
    protos = sum_x / n
    assert np.all(np.diff(protos) > 0)      # strictly ordered centroids
    assert protos.min() >= x.min() and protos.max() <= x.max()
    # streaming a second slab cannot grow past capacity
    t2 = sk.update(t, *_lognormal(41, 2500)[:2])
    assert int(sk.n_slots(t2)) == k
    np.testing.assert_allclose(float(sk.total_stats(t2)["n"]), 5000.0,
                               rtol=1e-6)


def test_weighted_equals_repeated_total_stats():
    rng = np.random.default_rng(50)
    x = rng.normal(size=64).astype(np.float32)
    y = rng.normal(size=64).astype(np.float32)
    w = rng.integers(0, 5, size=64)
    xr, yr = repeat_by_weights(w, x, y)
    tw = sk.from_batch(x, y, w.astype(np.float32), k=16)
    tr = sk.from_batch(xr.astype(np.float32), yr.astype(np.float32), k=16)
    for key in ("n", "mean", "m2"):
        np.testing.assert_allclose(float(sk.total_stats(tw)[key]),
                                   float(sk.total_stats(tr)[key]),
                                   rtol=1e-4, atol=1e-4)


def test_weighted_equals_repeated_slotwise_when_aligned():
    # K distinct values at EQUAL weight w: every unit row of value i
    # lands in bucket i (midpoints never straddle), so the weighted and
    # repeated constructions agree slot-for-slot, not just in total
    k, w = 8, 5
    rng = np.random.default_rng(51)
    x = np.sort(rng.normal(size=k)).astype(np.float32)
    y = rng.normal(size=k).astype(np.float32)
    tw = sk.from_batch(x, y, np.full(k, float(w), np.float32), k=k)
    xr, yr = repeat_by_weights(np.full(k, w), x, y)
    tr = sk.from_batch(xr, yr, k=k)
    _assert_tables_equal(tw, tr, bitwise=False, rtol=1e-5, atol=1e-5)


def test_quantile_rank_error_bound():
    k = 32
    x, y = _lognormal(60, 4000)
    chunks = np.array_split(np.arange(4000), 4)
    t = sk.init(k)
    for c in chunks:  # one merge level per chunk: the streaming shape
        t = sk.update(t, x[c], y[c])
    for q in np.linspace(0.05, 0.95, 19):
        assert abs(_rank(x, sk.quantile_sk(t, float(q))) - q) \
            <= RANK_SLACK / k


def _check_merge_commutative_totals(seed, na, nb):
    rng = np.random.default_rng(seed)
    a = sk.from_batch(rng.normal(size=na).astype(np.float32),
                      rng.normal(size=na).astype(np.float32), k=8)
    b = sk.from_batch(rng.normal(size=nb).astype(np.float32),
                      rng.normal(size=nb).astype(np.float32), k=8)
    for key in ("n", "mean", "m2"):
        np.testing.assert_allclose(
            float(sk.total_stats(sk.merge(a, b))[key]),
            float(sk.total_stats(sk.merge(b, a))[key]), rtol=1e-4,
            atol=1e-4)


def _check_weighted_equals_repeated_totals(seed, n):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=n).astype(np.float32)
    y = rng.normal(size=n).astype(np.float32)
    w = rng.integers(0, 4, size=n)
    if int(w.sum()) == 0:
        return
    xr, yr = repeat_by_weights(w, x, y)
    tw = sk.from_batch(x, y, w.astype(np.float32), k=8)
    tr = sk.from_batch(xr.astype(np.float32), yr.astype(np.float32), k=8)
    for key in ("n", "mean", "m2"):
        np.testing.assert_allclose(float(sk.total_stats(tw)[key]),
                                   float(sk.total_stats(tr)[key]),
                                   rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("seed,na,nb", [(0, 2, 2), (1, 7, 31), (2, 40, 3)])
def test_merge_commutative_totals_fallback(seed, na, nb):
    _check_merge_commutative_totals(seed, na, nb)


@pytest.mark.parametrize("seed,n", [(0, 1), (1, 13), (2, 30)])
def test_weighted_equals_repeated_totals_fallback(seed, n):
    _check_weighted_equals_repeated_totals(seed, n)


if HAVE_HYPOTHESIS:
    @needs_hypothesis
    @settings(max_examples=25, deadline=None)
    @given(st.integers(0, 2 ** 31 - 1), st.integers(2, 40),
           st.integers(2, 40))
    def test_hyp_merge_commutative_totals(seed, na, nb):
        _check_merge_commutative_totals(seed, na, nb)

    @needs_hypothesis
    @settings(max_examples=25, deadline=None)
    @given(st.integers(0, 2 ** 31 - 1), st.integers(1, 30))
    def test_hyp_weighted_equals_repeated_totals(seed, n):
        _check_weighted_equals_repeated_totals(seed, n)


# --------------------------------------------------------------------------
# merit-error oracle gate (satellite 2)
# --------------------------------------------------------------------------

GRACE = 512
SKETCH_K = 32
RANK_EPS_TREE = 0.12     # 2 merge levels + boundary quantization @ K=32
RANK_EPS_FOREST = 0.25   # + Poisson bagging jitter on the observed ranks
MERIT_FRAC = 0.8


def _step_stream(seed, n=1536, F=3):
    """Step signal on feature 0, pure noise elsewhere — the split is
    unambiguous, so the FIRST attempt fires and the observed prefix is
    exactly the first ``GRACE`` rows (both schedules mature there)."""
    rng = np.random.default_rng(seed)
    X = rng.lognormal(0.0, 1.0, size=(n, F)).astype(np.float32)
    y = (np.where(X[:, 0] > 1.0, 2.0, 0.0)
         + 0.05 * rng.normal(size=n)).astype(np.float32)
    return X, y


def _sketch_cfg(schedule):
    return ht.HTRConfig(n_features=3, max_nodes=3, n_bins=8,
                        grace_period=GRACE, max_depth=3, r0=0.3,
                        split_backend="jnp", attempt_schedule=schedule,
                        observer_backend="sketch", sketch_k=SKETCH_K)


@pytest.mark.parametrize("schedule", ["grace", "eager"])
def test_tree_first_split_within_rank_eps_of_oracle(schedule):
    X, y = _step_stream(70)
    cfg = _sketch_cfg(schedule)
    state = ht.update_stream(cfg, ht.init_state(cfg), jnp.asarray(X),
                             jnp.asarray(y), batch_size=256)
    assert int(state["n_nodes"]) == 3, "step signal must split the root"
    assert int(state["feature"][0]) == 0, "champion must be the signal"
    thr = float(state["threshold"][0])
    # the first attempt happens after exactly GRACE rows on both
    # schedules (grace: counter crossing; eager: maturity floor)
    xp, yp = X[:GRACE, 0], y[:GRACE]
    m_star, t_star = exact_best_split(xp, yp)
    assert abs(_rank(xp, thr) - _rank(xp, t_star)) <= RANK_EPS_TREE
    assert _merit_at(xp, yp, thr) >= MERIT_FRAC * m_star


@pytest.mark.parametrize("schedule", ["grace", "eager"])
def test_forest_splits_within_rank_eps_of_oracle(schedule):
    X, y = _step_stream(71, n=2048)
    fcfg = fr.ForestConfig(tree=_sketch_cfg(schedule), n_trees=3,
                           subspace=0.99)
    fstate = fr.init_forest(fcfg, jax.random.PRNGKey(0))
    out = fr.update_stream(fcfg, fstate, jnp.asarray(X), jnp.asarray(y),
                           batch_size=256)
    fstate = out[0] if isinstance(out, tuple) else out
    trees = fstate["trees"]
    n_nodes = np.asarray(trees["n_nodes"])
    split_members = np.nonzero(n_nodes >= 3)[0]
    assert split_members.size >= 1, "at least one member must split"
    # Poisson bagging reweights each member's view of the stream, so the
    # gate compares against the full-stream oracle with a wider band
    m_star, t_star = exact_best_split(X[:, 0], y)
    for t in split_members:
        assert int(trees["feature"][t, 0]) == 0
        thr = float(trees["threshold"][t, 0])
        assert abs(_rank(X[:, 0], thr) - _rank(X[:, 0], t_star)) \
            <= RANK_EPS_FOREST
        assert _merit_at(X[:, 0], y, thr) >= MERIT_FRAC * m_star


# --------------------------------------------------------------------------
# kernel contract: ops families vs ref oracles
# --------------------------------------------------------------------------

def _rand_state(seed, M=5, F=3, K=8, B=96):
    rng = np.random.default_rng(seed)
    leaf = rng.integers(0, M, size=B).astype(np.int32)
    leaf[rng.random(B) < 0.1] = -1  # pad/unrouted rows
    X = rng.normal(size=(B, F)).astype(np.float32)
    y = rng.normal(size=B).astype(np.float32)
    w = rng.integers(0, 3, size=B).astype(np.float32)
    n, mean, m2, sum_x = sk.from_batch_planes(
        jnp.asarray(np.maximum(leaf, 0)), jnp.asarray(X) + 10.0,
        jnp.asarray(y), jnp.ones(B, jnp.float32), M, K)
    ao_y = {"n": n, "mean": mean, "m2": m2}
    return ao_y, sum_x, jnp.asarray(leaf), jnp.asarray(X), \
        jnp.asarray(y), jnp.asarray(w)


@pytest.mark.parametrize("backend", BACKENDS)
def test_sketch_update_matches_ref(backend):
    ao_y, ao_sum_x, leaf, X, y, w = _rand_state(80)
    got_y, got_sx = ops.sketch_update(ao_y, ao_sum_x, leaf, X, y, w,
                                      backend=backend)
    ref_y, ref_sx = ref.sketch_update_ref(ao_y, ao_sum_x, leaf, X, y, w)
    for key in ("n", "mean", "m2"):
        np.testing.assert_allclose(np.asarray(got_y[key]),
                                   np.asarray(ref_y[key]), rtol=1e-4,
                                   atol=1e-4)
    np.testing.assert_allclose(np.asarray(got_sx), np.asarray(ref_sx),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("backend", BACKENDS)
def test_sketch_merge_matches_ref(backend):
    a_y, a_sx = _rand_state(81)[:2]
    b_y, b_sx = _rand_state(82)[:2]
    got_y, got_sx = ops.sketch_merge(a_y, a_sx, b_y, b_sx, backend=backend)
    ref_y, ref_sx = ref.sketch_merge_ref(a_y, a_sx, b_y, b_sx)
    for key in ("n", "mean", "m2"):
        np.testing.assert_allclose(np.asarray(got_y[key]),
                                   np.asarray(ref_y[key]), rtol=1e-4,
                                   atol=1e-4)
    np.testing.assert_allclose(np.asarray(got_sx), np.asarray(ref_sx),
                               rtol=1e-4, atol=1e-4)


def test_sketch_update_batch_pad_is_bitwise_noop():
    ao_y, ao_sum_x, leaf, X, y, w = _rand_state(83, B=100)
    pad = 28
    leaf_p = jnp.concatenate([leaf, jnp.full(pad, -1, jnp.int32)])
    X_p = jnp.concatenate([X, jnp.zeros((pad, X.shape[1]), X.dtype)])
    y_p = jnp.concatenate([y, jnp.zeros(pad, y.dtype)])
    w_p = jnp.concatenate([w, jnp.zeros(pad, w.dtype)])
    a = ops.sketch_update(ao_y, ao_sum_x, leaf, X, y, w, backend="jnp")
    b = ops.sketch_update(ao_y, ao_sum_x, leaf_p, X_p, y_p, w_p,
                          backend="jnp")
    for pa, pb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(pa), np.asarray(pb))


def test_sketch_merge_tile_r_is_bitwise_noop():
    a_y, a_sx = _rand_state(84)[:2]
    b_y, b_sx = _rand_state(85)[:2]
    small = ops.sketch_merge(a_y, a_sx, b_y, b_sx, backend="interpret",
                             tile_r=64)
    big = ops.sketch_merge(a_y, a_sx, b_y, b_sx, backend="interpret",
                           tile_r=256)
    for pa, pb in zip(jax.tree.leaves(small), jax.tree.leaves(big)):
        np.testing.assert_array_equal(np.asarray(pa), np.asarray(pb))


def test_sketch_update_traced_caller_inlines():
    ao_y, ao_sum_x, leaf, X, y, w = _rand_state(86)

    @jax.jit
    def run(ao_y, ao_sum_x, leaf, X, y, w):
        return ops.sketch_update(ao_y, ao_sum_x, leaf, X, y, w,
                                 backend="jnp")

    traced = run(ao_y, ao_sum_x, leaf, X, y, w)
    eager = ops.sketch_update(ao_y, ao_sum_x, leaf, X, y, w, backend="jnp")
    for pa, pb in zip(jax.tree.leaves(traced), jax.tree.leaves(eager)):
        np.testing.assert_allclose(np.asarray(pa), np.asarray(pb),
                                   rtol=1e-6, atol=1e-6)


def test_sketch_to_bins_idempotent_and_merit_preserving():
    ao_y, ao_sum_x = _rand_state(87)[:2]
    d_y, d_sx = ops.sketch_to_bins(ao_y, ao_sum_x)
    d2_y, d2_sx = ops.sketch_to_bins(d_y, d_sx)
    for pa, pb in zip(jax.tree.leaves((d_y, d_sx)),
                      jax.tree.leaves((d2_y, d2_sx))):
        np.testing.assert_array_equal(np.asarray(pa), np.asarray(pb))
    # the adapter feeds the UNCHANGED VR query: merits must survive it
    M, F, K = ao_y["n"].shape
    radius = jnp.ones((M, F), jnp.float32)
    origin = jnp.zeros((M, F), jnp.float32)
    attempt = jnp.ones((M,), bool)
    raw = ops.forest_best_splits(ao_y, ao_sum_x, radius, origin, attempt,
                                 backend="jnp")
    via = ops.forest_best_splits(d_y, d_sx, radius, origin, attempt,
                                 backend="jnp")
    np.testing.assert_allclose(np.asarray(raw[0]), np.asarray(via[0]),
                               rtol=1e-5, atol=1e-6)


# --------------------------------------------------------------------------
# non-regression pins (satellite 3)
# --------------------------------------------------------------------------

def test_default_config_never_mentions_the_knob():
    plain = ht.HTRConfig(n_features=3)
    explicit = ht.HTRConfig(n_features=3, observer_backend="qo")
    assert plain == explicit and hash(plain) == hash(explicit)
    assert plain.observer_bins() == plain.n_bins
    skcfg = ht.HTRConfig(n_features=3, observer_backend="sketch",
                         sketch_k=24)
    assert skcfg.observer_bins() == 24


def test_config_validation():
    with pytest.raises(ValueError):
        ht.HTRConfig(n_features=3, observer_backend="bogus")
    with pytest.raises(ValueError):
        ht.HTRConfig(n_features=3, observer_backend="sketch",
                     split_backend="oracle")
    with pytest.raises(ValueError):
        ht.HTRConfig(n_features=3, observer_backend="sketch", sketch_k=1)


def test_qo_default_bitwise_vs_explicit_knob():
    # the qo path must be bit-identical whether or not the new fields are
    # spelled out (sketch_k differs on purpose: it must be inert under qo)
    X, y = _step_stream(90, n=1024)
    base = dict(n_features=3, max_nodes=15, n_bins=16, grace_period=200,
                max_depth=4, r0=0.3, split_backend="jnp")
    a_cfg = ht.HTRConfig(**base)
    b_cfg = ht.HTRConfig(**base, observer_backend="qo", sketch_k=64)
    a = ht.update_stream(a_cfg, ht.init_state(a_cfg), jnp.asarray(X),
                         jnp.asarray(y), batch_size=256)
    b = ht.update_stream(b_cfg, ht.init_state(b_cfg), jnp.asarray(X),
                         jnp.asarray(y), batch_size=256)
    for ka, kb in zip(sorted(a), sorted(b)):
        assert ka == kb
        for la, lb in zip(jax.tree.leaves(a[ka]), jax.tree.leaves(b[kb])):
            np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


def test_observer_knob_never_fragments_kernel_caches():
    ops.clear_jit_caches()
    try:
        X, y = _step_stream(91, n=512)
        qo_cfg = ht.HTRConfig(n_features=3, max_nodes=7, n_bins=16,
                              grace_period=200, max_depth=3,
                              split_backend="jnp")
        ht.update_stream(qo_cfg, ht.init_state(qo_cfg), jnp.asarray(X),
                         jnp.asarray(y), batch_size=256)

        for k in (16, 8):  # two sketch capacities, SAME outer cache keys
            cfg = ht.HTRConfig(n_features=3, max_nodes=7, n_bins=16,
                               grace_period=200, max_depth=3,
                               split_backend="jnp",
                               observer_backend="sketch", sketch_k=k)
            ht.update_stream(cfg, ht.init_state(cfg), jnp.asarray(X),
                             jnp.asarray(y), batch_size=256)
        # inside the jitted tree step sketch_update is traced -> inlined:
        # the factory lrus stay EMPTY (no per-config entries at all)
        assert ops._jit_sketch_update.cache_info().currsize == 0
        assert ops._jit_sketch_merge.cache_info().currsize == 0
        # concrete dispatch at two capacities: the observer capacity
        # lives in the ARRAY SHAPES, never in an lru key — both K values
        # share ONE (backend, tile_r) factory entry per family
        for k in (16, 8):
            st8 = _rand_state(95, K=k)
            ops.sketch_update(*st8, backend="jnp")
            ops.sketch_merge(st8[0], st8[1], st8[0], st8[1], backend="jnp")
        assert ops._jit_sketch_update.cache_info().currsize == 1
        assert ops._jit_sketch_merge.cache_info().currsize == 1
        assert ops._jit_sketch_update("jnp", 256) \
            is ops._jit_sketch_update("jnp", 256)
        n_dispatch = ops._dispatch_cached.cache_info().currsize

        # a fresh qo run AFTER the sketch runs mints no new qo-family
        # dispatch entries: the knob never reached those cache keys
        ht.update_stream(qo_cfg, ht.init_state(qo_cfg), jnp.asarray(X),
                         jnp.asarray(y), batch_size=256)
        assert ops._dispatch_cached.cache_info().currsize == n_dispatch
    finally:
        ops.clear_jit_caches()


def test_freeze_drops_sketch_state():
    X, y = _step_stream(92, n=1024)
    fcfg = fr.ForestConfig(tree=_sketch_cfg("grace"), n_trees=2,
                           subspace=0.99)
    out = fr.update_stream(fcfg, fr.init_forest(fcfg, jax.random.PRNGKey(1)),
                           jnp.asarray(X), jnp.asarray(y), batch_size=256)
    fstate = out[0] if isinstance(out, tuple) else out
    snap = sv.freeze(fstate, version=1, step=7)
    for field in vars(snap):
        assert not field.startswith("ao_"), \
            f"snapshot must not carry observer state, found {field}"
    live = np.asarray(fr.predict(fcfg, fstate, jnp.asarray(X[:64])))
    frozen = np.asarray(sv.predict_snapshot(snap, jnp.asarray(X[:64])))
    np.testing.assert_allclose(frozen, live, rtol=1e-5, atol=1e-5)


def test_checkpoint_roundtrip_preserves_sketch_planes(tmp_path):
    from repro.checkpoint.ckpt import Checkpointer
    cfg = _sketch_cfg("grace")
    X, y = _step_stream(93, n=1024)
    state = ht.update_stream(cfg, ht.init_state(cfg), jnp.asarray(X),
                             jnp.asarray(y), batch_size=256)
    ck = Checkpointer(str(tmp_path))
    ck.save(1, state, blocking=True)
    restored = ck.restore(1, state)
    for la, lb in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


def test_dp_sync_runs_under_sketch_observer():
    # PR-5 protocol, sketch tables: sync boundaries go through
    # kops.sketch_merge instead of the elementwise Chan forest_merge,
    # with NO protocol change (same delta treedef, same reduce shape)
    X, y = _step_stream(94, n=2048)
    fcfg = fr.ForestConfig(tree=_sketch_cfg("grace"), n_trees=2,
                           subspace=0.99)
    dp = sharding.build_data_parallel_reference(fcfg, n_shards=2,
                                                sync_every=2)
    dst = dp.init(jax.random.PRNGKey(2))
    for i in range(8):
        dst, _ = dp.update(dst, jnp.asarray(X[i * 256:(i + 1) * 256]),
                           jnp.asarray(y[i * 256:(i + 1) * 256]))
    trees = dst["forest"]["trees"]
    n = np.asarray(trees["ao_y"]["n"])
    assert np.isfinite(n).all() and float(n.sum()) > 0
    # synced observer state is replicated bitwise across members' shards
    yhat = np.asarray(fr.predict(fcfg, dst["forest"],
                                 jnp.asarray(X[:64])))
    assert np.isfinite(yhat).all()
