"""Continuous-serving engine fault-path tests (DESIGN.md §5.6).

Every test drives the engine through its deterministic single-step
methods (``train_once`` / ``serve_once``) so the fault timing is exact;
one threaded smoke test runs the deployment shape.  The invariant under
EVERY injected fault: all admitted requests are served from a validated
published snapshot, bit-identical to ``predict_snapshot`` on that
version, sheds are counted, and the engine recovers to publishing.
"""
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.ckpt import Checkpointer
from repro.core import engine as eng
from repro.core import faults as fl
from repro.core import forest as fr
from repro.core import hoeffding as ht
from repro.core import serve as sv

F, B, N = 4, 64, 4096
TCFG = ht.HTRConfig(n_features=F, max_nodes=31, n_bins=16, grace_period=40,
                    max_depth=6, r0=0.3)
FCFG = fr.ForestConfig(tree=TCFG, n_trees=4)


def _data():
    rng = np.random.default_rng(7)
    X = rng.normal(0, 1, (N, F)).astype(np.float32)
    y = (2.0 * (X[:, 0] > 0) + 0.1 * rng.normal(0, 1, N)).astype(np.float32)
    return X, y


X_ALL, Y_ALL = _data()


def stream(step):
    """Deterministic, step-indexed (wraps) — crash recovery replays it."""
    i = (step * B) % (N - B)
    return jnp.asarray(X_ALL[i:i + B]), jnp.asarray(Y_ALL[i:i + B])


def make_engine(tmp_path=None, injector=None, **cfg_kw):
    cfg = eng.EngineConfig(**{"sync_every": 2, "max_queue_rows": 512,
                              "max_batch_rows": 256, **cfg_kw})
    ck = Checkpointer(str(tmp_path)) if tmp_path is not None else None
    state = fr.init_forest(FCFG, jax.random.PRNGKey(0))
    return eng.ServingEngine(FCFG, state, stream, cfg=cfg,
                             checkpointer=ck, injector=injector)


def _served_bit_identical(e, t):
    """The acceptance pin: a ticket's rows == a standalone
    predict_snapshot on the version that served it, bitwise."""
    assert t.status == "done" and t.version is not None
    snap = e.snapshot_for_version(t.version)
    ref = np.asarray(sv.predict_snapshot(snap, jnp.asarray(t.X)))
    np.testing.assert_array_equal(t.result, ref)


# -- publish / versioning --------------------------------------------------

def test_engine_publishes_on_cadence_with_monotone_versions():
    e = make_engine()
    assert e.published_version == 1          # never cold-starts
    seen = [e.published_version]
    for _ in range(6):
        e.train_once()
        if e.published_version != seen[-1]:
            seen.append(e.published_version)
    assert seen == [1, 2, 3, 4]              # sync_every=2 over 6 steps
    st = e.staleness()
    assert st["published_step"] == 6 and st["age_steps"] == 0
    assert not st["stale"]


def test_stale_publish_version_is_rejected():
    e = make_engine()
    e.train_once(), e.train_once()           # published v2
    old = sv.freeze(fr.init_forest(FCFG, jax.random.PRNGKey(1)),
                    version=1, step=0)       # not past v2
    assert not e.publish(old)
    assert e.published_version == 2
    assert e.metrics()["rollbacks"] == 1


# -- fault: trainer killed mid-sync-window ---------------------------------

def test_trainer_kill_mid_window_serving_uninterrupted(tmp_path):
    inj = fl.FaultInjector()
    e = make_engine(tmp_path, inj)
    for _ in range(4):
        e.train_once()                       # v3 published, ckpt at step 4
    v_before = e.published_version

    # kill the trainer MID-window (one step past the boundary)
    inj.arm("trainer.step", fl.Kill(), after=1)
    tickets = []
    for k in range(3):                       # steps 5 (ok), 6 (kill), 7
        tickets.append(e.submit(X_ALL[k * 10:k * 10 + 10]))
        e.train_once()
        while e.serve_once():
            pass
    assert inj.fired("trainer.step") == 1

    m = e.metrics()
    assert m["trainer_crashes"] == 1 and m["recoveries"] == 1
    # zero failed requests: everything admitted was served, bit-identically
    assert all(t.status == "done" for t in tickets)
    for t in tickets:
        _served_bit_identical(e, t)
    # recovery re-published (a fresh version of the restored model) and
    # the cadence resumed: within one sync window a NEW training-fresh
    # snapshot is out
    assert e.published_version > v_before
    v_recov = e.published_version
    for _ in range(e.cfg.sync_every):
        e.train_once()
    assert e.published_version > v_recov
    assert e.metrics()["trainer_crashes"] == 1      # no repeat crash


def test_recovery_restores_from_checkpoint_step(tmp_path):
    inj = fl.FaultInjector()
    e = make_engine(tmp_path, inj)
    for _ in range(4):
        e.train_once()                       # last ckpt at step 4
    e.train_once()                           # step 5 (mid-window)
    assert e._trainer_step == 5
    inj.arm("trainer.step", fl.Kill())
    e.train_once()                           # dies -> restore
    assert e._trainer_step == 4              # rewound to the ckpt step
    assert int(np.asarray(e._published.snap.step)) == 4


def test_recovery_without_checkpointer_falls_back_to_memory():
    inj = fl.FaultInjector()
    e = make_engine(None, inj)
    for _ in range(3):
        e.train_once()
    step = e._trainer_step
    inj.arm("trainer.step", fl.Kill())
    e.train_once()
    m = e.metrics()
    assert m["trainer_crashes"] == 1 and m["recoveries"] == 1
    assert e._trainer_step == step           # in-memory state kept
    assert e.published_version >= 2          # still re-published


# -- fault: corrupt publish -> rollback ------------------------------------

def test_corrupt_publish_rolls_back_to_last_good():
    inj = fl.FaultInjector()
    e = make_engine(None, inj)
    e.train_once(), e.train_once()           # v2 out
    v_good = e.published_version
    good_snap = e.snapshot_for_version(v_good)

    # NaN the vote weights in flight: invalid regardless of how far the
    # young trees have grown (threshold/BFS corruption is pinned by the
    # controlled-topology tests in test_serve.py)
    inj.arm("publish", fl.Corrupt(lambda s: dataclasses.replace(
        s, vote_w=s.vote_w.at[0].set(jnp.nan))))
    e.train_once(), e.train_once()           # boundary: corrupt publish
    assert inj.fired("publish") == 1
    m = e.metrics()
    assert m["publish_failures"] == 1 and m["rollbacks"] == 1
    # rollback = the reference never moved: still serving v_good, bitwise
    assert e.published_version == v_good
    t = e.submit(X_ALL[:50])
    e.serve_once()
    assert t.version == v_good
    np.testing.assert_array_equal(
        t.result, np.asarray(sv.predict_snapshot(good_snap,
                                                 jnp.asarray(t.X))))
    # the NEXT boundary publishes clean with a monotone version
    e.train_once(), e.train_once()
    assert e.published_version > v_good


def test_corrupt_vote_weights_and_child_range_rejected():
    e = make_engine()
    e.train_once(), e.train_once()
    snap = e.snapshot_for_version(e.published_version)
    bad_vote = dataclasses.replace(
        snap, vote_w=snap.vote_w.at[0].set(-1.0),
        version=jnp.int32(99), step=jnp.int32(99))
    assert not e.publish(bad_vote)
    bad_child = dataclasses.replace(
        snap, child=jnp.full_like(snap.child, snap.feature.shape[1]),
        version=jnp.int32(99), step=jnp.int32(99))
    assert not e.publish(bad_child)
    assert e.metrics()["rollbacks"] == 2


# -- fault: dropped publishes -> staleness watchdog ------------------------

def test_dropped_publishes_trip_staleness_watchdog():
    inj = fl.FaultInjector()
    e = make_engine(None, inj, sync_every=2, staleness_factor=2.0)
    e.train_once(), e.train_once()           # v2 at step 2
    inj.arm("publish", fl.Drop(), times=4)   # lose the next 4 publishes
    for _ in range(8):
        e.train_once()
    m = e.metrics()
    assert m["publishes_dropped"] == 4
    st = e.staleness()
    assert st["published_step"] == 2 and st["age_steps"] == 8
    assert st["stale"] and m["stale_events"] > 0
    # the drop armed out: next boundary publishes again and the flag clears
    e.train_once(), e.train_once()
    assert not e.staleness()["stale"]
    assert e.published_version == 3          # monotone, no version holes


# -- admission control ------------------------------------------------------

def test_queue_overflow_sheds_exactly_the_excess():
    e = make_engine(None, None, max_queue_rows=512)
    tickets = [e.submit(X_ALL[:200]) for _ in range(4)]
    statuses = [t.status for t in tickets]
    assert statuses == ["queued", "queued", "shed", "shed"]
    m = e.metrics()
    assert m["admitted_rows"] == 400 and m["shed_rows"] == 400
    assert m["shed_requests"] == 2
    # shed tickets are resolved (never hang a caller), with no result
    assert tickets[2].wait(timeout=1) and tickets[2].result is None
    # draining reopens admission
    while e.serve_once():
        pass
    assert e.submit(X_ALL[:200]).status == "queued"
    assert e.metrics()["served_rows"] == 400


def test_packed_batch_splits_per_ticket_bit_identically():
    e = make_engine(None, None, max_batch_rows=256)
    sizes = (100, 37, 119)                    # packs into one 256-row batch
    tickets = [e.submit(X_ALL[i * 200:i * 200 + s])
               for i, s in enumerate(sizes)]
    assert e.serve_once() == sum(sizes)
    assert e.metrics()["serve_batches"] == 1  # ONE dispatch for all three
    for t in tickets:
        _served_bit_identical(e, t)


def test_inflight_requests_drain_on_the_pinned_version():
    """The hot-swap drain contract, exercised deterministically: tickets
    queued before a publish that are served after it still carry a
    consistent version and bit-identical results for that version."""
    e = make_engine()
    t_old = e.submit(X_ALL[:80])
    e.train_once(), e.train_once()           # hot-swap to v2 while queued
    e.serve_once()
    assert t_old.version == e.published_version    # served post-swap: v2
    _served_bit_identical(e, t_old)                # ...consistently


# -- threaded deployment shape ---------------------------------------------

def test_threaded_engine_serves_everything_admitted(tmp_path):
    inj = fl.FaultInjector()
    inj.arm("trainer.step", fl.Kill(), after=3)
    e = make_engine(tmp_path, inj, sync_every=2, max_queue_rows=4096,
                    max_batch_rows=512)
    e.start()
    try:
        tickets = [e.submit(X_ALL[i % 32:(i % 32) + 48]) for i in range(20)]
        # let the injected kill actually land before shutting down (the
        # trainer thread paces itself; a fault that never fired proves
        # nothing)
        deadline = time.monotonic() + 120
        while (e.metrics()["recoveries"] < 1
               and time.monotonic() < deadline):
            time.sleep(0.01)
        tickets += [e.submit(X_ALL[i % 32:(i % 32) + 48]) for i in range(20)]
        admitted = [t for t in tickets if t.status != "shed"]
        for t in admitted:
            assert t.wait(timeout=30), "admitted ticket never served"
    finally:
        e.stop(drain=True)
    m = e.metrics()
    assert m["trainer_crashes"] == 1 and m["recoveries"] == 1
    assert all(t.status == "done" for t in admitted)
    assert m["served_requests"] == len(admitted)
    assert m["served_rows"] + m["shed_rows"] == sum(t.rows for t in tickets)
    for t in admitted:                       # zero torn reads, bitwise
        _served_bit_identical(e, t)


# -- publish boundary on the data-parallel trainer -------------------------

def test_dp_on_sync_is_a_publish_boundary():
    jnp_cfg = fr.ForestConfig(
        tree=dataclasses.replace(TCFG, split_backend="jnp"), n_trees=4)
    from repro.train import sharding as sh

    calls = []

    def on_sync(forest, step, aux):
        calls.append((step, sv.freeze(forest, version=len(calls) + 1,
                                      step=step)))

    dp = sh.build_data_parallel_reference(jnp_cfg, n_shards=2,
                                          sync_every=2, on_sync=on_sync)
    st = dp.init(jax.random.PRNGKey(0))
    for k in range(4):
        st, aux = dp.update(st, jnp.asarray(X_ALL[k * B:(k + 1) * B]),
                            jnp.asarray(Y_ALL[k * B:(k + 1) * B]))
        assert (aux is None) == bool((k + 1) % 2)
    assert [s for s, _ in calls] == [2, 4]   # fired exactly at boundaries
    # the published snapshot IS the synced forest: frozen-at-boundary
    # predictions match the trainer's own
    step, snap = calls[-1]
    np.testing.assert_array_equal(
        np.asarray(sv.predict_snapshot(snap, jnp.asarray(X_ALL[:B]))),
        np.asarray(dp.predict(st, jnp.asarray(X_ALL[:B]))))
    assert int(np.asarray(snap.version)) == 2


# -- snapshot identity round-trip ------------------------------------------

def test_version_and_step_round_trip_through_checkpoint(tmp_path):
    state = fr.init_forest(FCFG, jax.random.PRNGKey(0))
    state, _ = fr.update(FCFG, state, jnp.asarray(X_ALL[:B]),
                         jnp.asarray(Y_ALL[:B]))
    snap = sv.freeze(state, version=17, step=123)
    ck = Checkpointer(str(tmp_path))
    ck.save(123, snap, blocking=True)
    # the template carries DIFFERENT stamps: restore must bring back the
    # SAVED identity (leaves, not aux), so rollback audits can pin it
    template = sv.freeze(state, version=1, step=0)
    rest = ck.restore_latest(template)
    assert int(np.asarray(rest.version)) == 17
    assert int(np.asarray(rest.step)) == 123
    np.testing.assert_array_equal(
        np.asarray(sv.predict_snapshot(rest, jnp.asarray(X_ALL[:100]))),
        np.asarray(sv.predict_snapshot(snap, jnp.asarray(X_ALL[:100]))))
