"""Perf layer: tuned dispatch is schedule-only, cached, and persistent.

Three contracts (DESIGN.md §8):

* **bit-identity** — ANY legal parameter tuple from the tuner's search
  space, installed through ``ops.set_tuning``, produces bit-identical
  outputs to the hard-coded defaults on every dispatchable backend
  (tiles/ladders/rounding are a schedule, never a semantics, knob);
* **no cache fragmentation** — tuned parameters resolve BEFORE the jit
  key is formed: a tuning-table hit adds ZERO extra jit entries on
  repeat dispatch, an empty table reproduces today's literal cache keys;
* **persistence round-trip** — tune -> save -> load -> install restores
  exactly the measured winners, filtered to the current device kind.
"""
import itertools
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import stats
from repro.kernels import ops
from repro.perf import tune as ptune


@pytest.fixture(autouse=True)
def _clean_tuning():
    """Every test starts and ends untuned with cold dispatch caches."""
    ops.set_tuning({})
    ops.clear_jit_caches()
    yield
    ops.set_tuning({})
    ops.clear_jit_caches()


def _forest_inputs(rng, M=32, F=3, C=8, B=300):
    ao_y = stats.init((M, F, C))
    ao_sum_x = jnp.zeros((M, F, C))
    ao_radius = jnp.full((M, F), 0.2, jnp.float32)
    ao_origin = jnp.zeros((M, F), jnp.float32)
    leaf = jnp.array(rng.integers(0, M, B), jnp.int32)
    X = jnp.array(rng.normal(0, 1, (B, F)).astype(np.float32))
    y = jnp.array(rng.normal(0, 1, B).astype(np.float32))
    # one real update so the query sees populated tables
    ao_y, ao_sum_x = ops.forest_update(ao_y, ao_sum_x, ao_radius, ao_origin,
                                       leaf, X, y, backend="jnp")
    attempt = jnp.array([i < M // 4 for i in range(M)])
    return ao_y, ao_sum_x, ao_radius, ao_origin, leaf, X, y, attempt


def _bits(tree):
    return [np.asarray(leaf) for leaf in jax.tree.leaves(tree)]


def _assert_same_bits(a, b, msg):
    for x, y in zip(_bits(a), _bits(b)):
        np.testing.assert_array_equal(x, y, err_msg=msg)


# --------------------------------------------------------------------------
# property: every search-space tuple is bit-identical to defaults
# --------------------------------------------------------------------------

def _space_tuples(family):
    space = ptune.SEARCH_SPACE[family]
    keys = sorted(space)
    return [dict(zip(keys, combo))
            for combo in itertools.product(*(space[k] for k in keys))]


@pytest.mark.parametrize("backend", ["jnp", "interpret"])
def test_every_search_space_tuple_bit_identical(backend, rng):
    """The whole tuner grid, on both CPU-dispatchable backends: installing
    any SEARCHABLE candidate changes performance only.  On the kernel
    path the grid excludes the batch-streaming knobs by construction
    (KERNEL_STREAM_KNOBS — they reorder the f32 Chan merge there), and
    this test is exactly the contract that exclusion protects.  (The
    interpret backend runs a reduced grid — the Pallas interpreter is
    slow — but still covers every searchable knob's extremes via the
    smoke space.)"""
    w = ptune.make_workloads(**ptune.SMOKE_SHAPES)
    space = ptune.SMOKE_SPACE if backend == "interpret" else None
    for family in ptune.TUNE_FAMILIES:
        run = ptune._runner(family, w, backend)
        ops.set_tuning({})
        ref = jax.block_until_ready(run())
        tkey = (family, backend, w["shape_class"][family])
        cands = ptune.candidates(family, space, backend=backend)
        if backend == "interpret":
            cands = cands[:4]
        for cand in cands:
            ops.set_tuning({tkey: cand})
            out = jax.block_until_ready(run())
            _assert_same_bits(ref, out, f"{family}/{backend}: {cand}")
        ops.set_tuning({})


def test_kernel_stream_knobs_pinned_on_kernel_path():
    """The kernel-path grid never varies a stream knob, the jnp grid
    does, and every family with a stream knob is covered by the map."""
    for family, pinned in ptune.KERNEL_STREAM_KNOBS.items():
        for knob in pinned:
            default = ops.DEFAULT_PARAMS[family][knob]
            kvals = {c[knob] for c in
                     ptune.candidates(family, backend="interpret")}
            assert kvals == {default}, (family, knob)
            jvals = {c[knob] for c in ptune.candidates(family)}
            assert len(jvals) > 1, (family, knob)


def test_ladder_buckets_are_schedule_only(rng):
    """pow2 vs pow2_half ladder on a public route dispatch around the
    1024 boundary: identical leaf ids, different padded work."""
    w = ptune.make_workloads(M=64, F=4, C=8, T=4, B=1100)
    ref = np.asarray(ops.forest_route(*w["route"], depth=w["depth"],
                                      backend="jnp"))
    tkey = ("forest_route", "jnp", w["shape_class"]["forest_route"])
    ops.set_tuning({tkey: {"batch_ladder": "pow2_half", "ply_round": 1}})
    out = np.asarray(ops.forest_route(*w["route"], depth=w["depth"],
                                      backend="jnp"))
    np.testing.assert_array_equal(ref, out)
    # and the half-step ladder really is the smaller bucket
    assert ops._ladder_bucket(1100, 128, "pow2_half") == 1536
    assert ops._ladder_bucket(1100, 128, "pow2") == 2048


def test_ladder_bucket_properties():
    """Any n: bucket >= n, bucket >= lo, half-ladder <= pow2 ladder, and
    both ladders are monotone in n."""
    prev_p, prev_h = 0, 0
    for n in range(1, 5000, 37):
        p = ops._ladder_bucket(n, 128, "pow2")
        h = ops._ladder_bucket(n, 128, "pow2_half")
        assert p >= n and h >= n and p >= 128 and h >= 128
        assert h <= p
        assert p >= prev_p and h >= prev_h
        prev_p, prev_h = p, h


def test_depth_bucket_round_to():
    assert ops.depth_bucket(7) == 8            # historical even default
    assert ops.depth_bucket(7, 1) == 7         # exact plies
    assert ops.depth_bucket(7, 4) == 8
    assert ops.depth_bucket(8, 4) == 8
    assert ops.depth_bucket(9, 4) == 12
    assert ops.depth_bucket(0, 2) == 0


# --------------------------------------------------------------------------
# no cache fragmentation: tuned params resolve before the jit key forms
# --------------------------------------------------------------------------

def test_tuning_hit_adds_zero_extra_jits(rng):
    """Repeat dispatch with a tuning entry installed: the first call
    compiles, every later same-bucket call is a pure cache hit — same
    lru entry count, same inner-jit trace count."""
    w = ptune.make_workloads(M=64, F=4, C=8, T=4, B=700)
    tkey = ("forest_update", "jnp", w["shape_class"]["forest_update"])
    ops.set_tuning({tkey: {"tile_b": 128, "batch_ladder": "pow2_half"}})
    ops.forest_update(*w["update"], backend="jnp")
    n_lru = ops._dispatch_cached.cache_info().currsize
    handle = ops._jit_forest_update("jnp", 128, 128)
    assert handle._cache_size() == 1
    for _ in range(3):
        ops.forest_update(*w["update"], backend="jnp")
    assert ops._dispatch_cached.cache_info().currsize == n_lru, \
        "tuning-table hit minted a new cached-jit factory entry"
    assert handle._cache_size() == 1, "tuned dispatch retraced"


def test_empty_tuning_reproduces_historical_cache_keys(rng):
    """With no tuning installed the dispatch keys are exactly the
    pre-perf-layer literals — the untuned-machines-bit-identical
    contract, pinned against the historical constants."""
    a = _forest_inputs(rng)
    ops.forest_update(*a[:7], backend="jnp")
    assert ops._jit_forest_update("jnp", 256, 128)._cache_size() == 1
    ops.forest_best_splits(*a[:4], a[7], backend="jnp")
    kpad = ops.query_buckets(32)[0]
    assert ops._jit_forest_query("jnp", 128, kpad)._cache_size() == 1


def test_explicit_argument_beats_tuning_entry(rng):
    """A caller-passed tile wins over the installed entry (the explicit
    override contract of ops.tuned)."""
    w = ptune.make_workloads(M=64, F=4, C=8, T=4, B=300)
    tkey = ("forest_update", "jnp", w["shape_class"]["forest_update"])
    ops.set_tuning({tkey: {"tile_b": 512}})
    assert ops.tuned("forest_update", "jnp",
                     w["shape_class"]["forest_update"])["tile_b"] == 512
    assert ops.tuned("forest_update", "jnp",
                     w["shape_class"]["forest_update"],
                     tile_b=128)["tile_b"] == 128
    ops.forest_update(*w["update"], backend="jnp", tile_b=128)
    assert ops._jit_forest_update("jnp", 128, 128)._cache_size() == 1


def test_tuned_unknown_params_ignored():
    ops.set_tuning({("forest_merge", "jnp", "X"): {"bogus": 7, "tile_r": 64}})
    p = ops.tuned("forest_merge", "jnp", "X")
    assert p == {"tile_r": 64}


# --------------------------------------------------------------------------
# tuner: measured search + cache round-trip
# --------------------------------------------------------------------------

def test_tuner_smoke_cache_round_trip(tmp_path, rng):
    path = str(tmp_path / "cache.json")
    key, entry = ptune.tune_family("forest_merge", "jnp",
                                   shapes=ptune.SMOKE_SHAPES,
                                   space=ptune.SMOKE_SPACE, reps=1, inner=1)
    assert entry["params"] in ptune.candidates("forest_merge",
                                               ptune.SMOKE_SPACE)
    assert entry["speedup_vs_default"] > 0
    ptune.save_cache({key: entry}, path)
    reloaded = ptune.load_cache(path)
    assert reloaded == {key: json.loads(json.dumps(entry))}
    installed = ptune.install(reloaded)
    fam, bk, sc = key.split("|")[1:]
    assert installed == {(fam, bk, sc): entry["params"]}
    assert ops.get_tuning() == installed


def test_install_filters_foreign_device_kinds(tmp_path):
    """An entry measured on another accelerator never steers this host."""
    alien = "not-a-real-device|forest_merge|jnp|M8xF2xC4"
    table = ptune.install({alien: {"params": {"tile_r": 64}}})
    assert table == {} and ops.get_tuning() == {}


def test_ensure_tunes_once_then_loads(tmp_path, rng, monkeypatch):
    path = str(tmp_path / "cache.json")
    calls = []
    real = ptune.tune

    def counting_tune(families, *a, **kw):
        calls.append(tuple(families))
        return real(families, *a, **kw)

    monkeypatch.setattr(ptune, "tune", counting_tune)
    kw = dict(families=("forest_merge",), backend="jnp",
              shapes=ptune.SMOKE_SHAPES, space=ptune.SMOKE_SPACE, reps=1)
    ptune.ensure(path, **kw)
    assert calls == [("forest_merge",)]
    ops.set_tuning({})
    ptune.ensure(path, **kw)          # cache hit: no re-measure
    assert calls == [("forest_merge",)]
    assert ops.get_tuning() != {}


def test_search_space_contains_defaults():
    """The tuner can never lose to 'untuned' on the machine that tuned:
    every family's grid includes the hard-coded default point, and every
    DEFAULT_PARAMS knob appears in the family's space."""
    for family, knobs in ptune.SEARCH_SPACE.items():
        defaults = ops.DEFAULT_PARAMS[family]
        assert set(knobs) == set(defaults), family
        for k, v in defaults.items():
            assert v in knobs[k], (family, k)
        assert defaults in ptune.candidates(family)
