"""Cross-shard QO merge algebra: the §4.1 collective's contracts.

Three layers of guarantee, matching DESIGN.md §4.1:

* the kernel-backed :func:`repro.kernels.ops.forest_merge` agrees with
  the per-table :func:`repro.core.qo.merge_tables` oracle on every
  backend;
* the merge operator is commutative BITWISE (float add/mul commute) and
  associative up to float rounding (hypothesis property) — the legal
  all-reduce operator claim;
* ``test_merge_tables_is_distributed_update`` (promised by DESIGN §4.1
  since PR 1): a stream sharded D ways, learned as D independent tables
  and merge-reduced, equals the single-stream table — BITWISE on
  exact-arithmetic streams (integer-valued x with one target value per
  bin, where every float op in both paths is exact, so any summation
  order must produce identical bits), and to float tolerance on generic
  gaussian streams.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import qo, stats
from repro.kernels import ops, ref

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover
    HAVE_HYPOTHESIS = False
needs_hypothesis = pytest.mark.skipif(not HAVE_HYPOTHESIS,
                                      reason="hypothesis not installed")

BACKENDS = [
    "interpret", "jnp",
    pytest.param("pallas", marks=pytest.mark.skipif(
        jax.default_backend() != "tpu",
        reason="compiled Pallas kernels need a TPU")),
]

N, F, C = 11, 3, 40


def _rand_tables(rng, n=N):
    cnt = jnp.asarray(rng.integers(0, 5, size=(n, F, C)).astype(np.float32))
    mean = jnp.asarray(rng.normal(size=(n, F, C)).astype(np.float32)) * (cnt > 0)
    m2 = jnp.abs(jnp.asarray(
        rng.normal(size=(n, F, C)).astype(np.float32))) * (cnt > 1)
    sx = jnp.asarray(rng.normal(size=(n, F, C)).astype(np.float32)) * (cnt > 0)
    return {"n": cnt, "mean": mean, "m2": m2}, sx


def _assert_tables(got, want, **tol):
    gy, gsx = got
    wy, wsx = want
    for k in ("n", "mean", "m2"):
        np.testing.assert_allclose(np.asarray(gy[k]), np.asarray(wy[k]),
                                   err_msg=k, **tol)
    np.testing.assert_allclose(np.asarray(gsx), np.asarray(wsx), **tol)


@pytest.mark.parametrize("backend", BACKENDS)
def test_forest_merge_matches_oracle(rng, backend):
    a = _rand_tables(rng)
    b = _rand_tables(rng)
    want = ref.forest_merge_ref(*a, *b)
    got = ops.forest_merge(*a, *b, backend=backend)
    _assert_tables(got, want, rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("backend", BACKENDS)
def test_forest_merge_empty_is_identity(rng, backend):
    """Merging an all-empty delta leaves occupied-bin stats unchanged to
    float tolerance and counts/sum_x exactly (n + 0, sx + 0 are exact)."""
    a = _rand_tables(rng)
    z = (stats.init((N, F, C)), jnp.zeros((N, F, C)))
    gy, gsx = ops.forest_merge(*a, *z, backend=backend)
    np.testing.assert_array_equal(np.asarray(gy["n"]), np.asarray(a[0]["n"]))
    np.testing.assert_array_equal(np.asarray(gsx), np.asarray(a[1]))
    _assert_tables((gy, gsx), a, rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("backend", BACKENDS)
def test_forest_merge_commutative(rng, backend):
    """a ⊕ b == b ⊕ a: BITWISE for the pure sums (n, sum_x — float add
    commutes), and to 1-ulp for mean/M2 (XLA may contract the symmetric
    ``n_a·m_a + n_b·m_b`` into an FMA whose operand order differs)."""
    a = _rand_tables(rng)
    b = _rand_tables(rng)
    (ab_y, ab_sx) = ops.forest_merge(*a, *b, backend=backend)
    (ba_y, ba_sx) = ops.forest_merge(*b, *a, backend=backend)
    np.testing.assert_array_equal(np.asarray(ab_y["n"]),
                                  np.asarray(ba_y["n"]))
    np.testing.assert_array_equal(np.asarray(ab_sx), np.asarray(ba_sx))
    for k in ("mean", "m2"):
        np.testing.assert_allclose(np.asarray(ab_y[k]), np.asarray(ba_y[k]),
                                   rtol=1e-6, atol=1e-7, err_msg=k)


def test_forest_merge_traced_inlines(rng):
    """Under an enclosing jit the op inlines (same values), and concrete
    calls reuse ONE cached program per backend."""
    a = _rand_tables(rng)
    b = _rand_tables(rng)
    eager = ops.forest_merge(*a, *b, backend="jnp")
    traced = jax.jit(functools.partial(ops.forest_merge, backend="jnp"))(
        *a, *b)
    _assert_tables(traced, eager, rtol=1e-6, atol=1e-6)
    before = ops._jit_forest_merge.cache_info().currsize
    ops.forest_merge(*a, *b, backend="jnp")
    assert ops._jit_forest_merge.cache_info().currsize == before


if HAVE_HYPOTHESIS:
    @settings(max_examples=30, deadline=None)
    @given(st.integers(0, 2 ** 31 - 1))
    def test_merge_associative_commutative(seed):
        """(a ⊕ b) ⊕ c ≈ a ⊕ (b ⊕ c) and a ⊕ b == b ⊕ a over random
        tables — the algebra that legalizes any all-reduce pairing."""
        rng = np.random.default_rng(seed)
        a, b, c = (_rand_tables(rng, n=3) for _ in range(3))
        m = lambda u, v: ops.forest_merge(*u, *v, backend="jnp")
        left = m(m(a, b), c)
        right = m(a, m(b, c))
        _assert_tables(left, right, rtol=1e-4, atol=1e-5)
        _assert_tables(m(a, b), m(b, a), rtol=1e-6, atol=1e-7)


# --------------------------------------------------------------------------
# the promised §4.1 property: shard + merge == single stream
# --------------------------------------------------------------------------

def _exact_stream(rng, n_rows):
    """Integer stream on which every float op of both paths is exact:
    x ∈ {-8..8} (radius-1 bins, no edge clipping at C = 32) and y an
    integer function of the bin, so every bin mean is exactly its y
    value, every tile/merged M2 is exactly 0, and all sums are integer.
    """
    x = rng.integers(-8, 9, size=n_rows).astype(np.float32)
    y = (np.abs(x) * 3 - 7).astype(np.float32)
    return jnp.asarray(x), jnp.asarray(y)


@pytest.mark.parametrize("d", [2, 3, 8])
def test_merge_tables_is_distributed_update(rng, d):
    """D shard-learned QO tables merge-reduce to EXACTLY the
    single-stream table (bitwise on an exact-arithmetic stream, in both
    log-depth and sequential reduction order)."""
    x, y = _exact_stream(rng, 24 * d)
    full = qo.update(qo.init(32, radius=1.0), x, y)
    shards = [qo.update(qo.init(32, radius=1.0), xs, ys)
              for xs, ys in zip(jnp.split(x, d), jnp.split(y, d))]

    seq = shards[0]
    for s in shards[1:]:
        seq = qo.merge_tables(seq, s)
    while len(shards) > 1:  # log-depth pairing, the all-reduce order
        pairs = [qo.merge_tables(shards[i], shards[i + 1])
                 for i in range(0, len(shards) - 1, 2)]
        shards = pairs + ([shards[-1]] if len(shards) % 2 else [])
    for merged in (seq, shards[0]):
        jax.tree.map(lambda a, b: np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b)), merged, full)


def test_merge_tables_distributed_update_float(rng):
    """Same property on a generic gaussian stream: equal to float
    tolerance (summation order is the only difference)."""
    x = jnp.asarray(rng.normal(size=512).astype(np.float32))
    y = jnp.asarray(rng.normal(size=512).astype(np.float32))
    full = qo.update(qo.init(64, radius=0.2), x, y)
    merged = functools.reduce(
        qo.merge_tables,
        [qo.update(qo.init(64, radius=0.2), xs, ys)
         for xs, ys in zip(jnp.split(x, 4), jnp.split(y, 4))])
    for k in ("n", "mean", "m2"):
        np.testing.assert_allclose(np.asarray(merged["y"][k]),
                                   np.asarray(full["y"][k]),
                                   rtol=2e-5, atol=2e-5, err_msg=k)
    np.testing.assert_allclose(np.asarray(merged["sum_x"]),
                               np.asarray(full["sum_x"]), rtol=2e-5,
                               atol=2e-5)


@pytest.mark.parametrize("backend", BACKENDS)
def test_forest_merge_is_distributed_forest_update(rng, backend):
    """The same bitwise claim one level up: D shard-local
    ``forest_update`` deltas reduced with ``forest_merge`` equal the
    single-batch ``forest_update`` on every backend (exact stream; the
    feature column is shared so one target value rides per bin of every
    table)."""
    M_, F_, C_ = 5, 2, 32
    d, rows = 4, 96
    x, y = _exact_stream(rng, rows)
    X = jnp.stack([x, x], 1)                                  # (B, 2)
    leaf = jnp.asarray(rng.integers(0, M_, size=rows).astype(np.int32))
    radius = jnp.ones((M_, F_), jnp.float32)
    origin = jnp.zeros((M_, F_), jnp.float32)
    zero = lambda: (stats.init((M_, F_, C_)), jnp.zeros((M_, F_, C_)))

    upd = functools.partial(ops.forest_update, ao_radius=radius,
                            ao_origin=origin, backend=backend)
    full = upd(*zero(), leaf=leaf, X=X, y=y)
    parts = [upd(*zero(), leaf=ls, X=Xs, y=ys)
             for ls, Xs, ys in zip(jnp.split(leaf, d), jnp.split(X, d),
                                   jnp.split(y, d))]
    while len(parts) > 1:
        parts = [ops.forest_merge(*parts[i], *parts[i + 1], backend=backend)
                 for i in range(0, len(parts), 2)]
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), parts[0], full)
