"""E-BST / TE-BST baselines: exactness vs the batch oracle."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ebst
from tests.helpers import exact_best_split


def test_ebst_split_matches_batch_oracle(rng):
    x = rng.normal(0, 1, 1500).astype(np.float32)
    y = np.where(x <= -0.3, 2.0, 7.0).astype(np.float32) + \
        0.05 * rng.normal(0, 1, 1500).astype(np.float32)
    t = ebst.init(1500)
    t = jax.jit(ebst.update)(t, jnp.array(x), jnp.array(y))
    r = jax.jit(ebst.best_split)(t)
    merit, thr = exact_best_split(x, y)
    assert bool(r.valid)
    np.testing.assert_allclose(float(r.threshold), thr, rtol=1e-5)
    np.testing.assert_allclose(float(r.merit), merit, rtol=1e-3)


def test_tebst_truncates_and_stores_fewer(rng):
    x = rng.normal(0, 1, 2000).astype(np.float32)
    y = (3 * x).astype(np.float32)
    full = jax.jit(ebst.update)(ebst.init(2000), jnp.array(x), jnp.array(y))
    trunc = jax.jit(ebst.update)(ebst.init(2000, decimals=1), jnp.array(x),
                                 jnp.array(y))
    assert int(trunc["size"]) < int(full["size"])
    # split points still close (paper Fig. 3)
    rf = jax.jit(ebst.best_split)(full)
    rt = jax.jit(ebst.best_split)(trunc)
    assert abs(float(rf.threshold) - float(rt.threshold)) < 0.1


def test_ebst_duplicate_keys(rng):
    x = np.repeat(np.array([1.0, 2.0, 3.0], np.float32), 50)
    y = np.where(x <= 2.0, 0.0, 10.0).astype(np.float32)
    t = jax.jit(ebst.update)(ebst.init(300), jnp.array(x), jnp.array(y))
    assert int(t["size"]) == 3  # duplicates update stats, no new nodes
    r = jax.jit(ebst.best_split)(t)
    np.testing.assert_allclose(float(r.threshold), 2.0)
    assert float(t["total"]["n"]) == 150


def test_ebst_capacity_degrades_gracefully(rng):
    x = rng.normal(0, 1, 500).astype(np.float32)
    y = x.astype(np.float32)
    t = jax.jit(ebst.update)(ebst.init(100), jnp.array(x), jnp.array(y))
    assert int(t["size"]) == 100  # clamped
    assert float(t["total"]["n"]) == 500  # nothing lost from total stats
    r = jax.jit(ebst.best_split)(t)
    assert bool(r.valid) and np.isfinite(float(r.merit))
