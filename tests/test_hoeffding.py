"""Batched Hoeffding tree regressor integration tests."""
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hoeffding as ht
from repro.data import synth


def _train(cfg, X, y, bs=256):
    state = ht.init_state(cfg)
    upd = jax.jit(functools.partial(ht.update, cfg))
    for i in range(0, len(y) - bs + 1, bs):
        state = upd(state, jnp.array(X[i:i + bs]), jnp.array(y[i:i + bs]))
    return state


def test_tree_learns_piecewise_target():
    X, y = synth.piecewise_regression(12000, n_features=4, seed=3)
    cfg = ht.HTRConfig(n_features=4, max_nodes=63, n_bins=48,
                       grace_period=300, max_depth=8, r0=0.25)
    state = _train(cfg, X, y)
    assert int(state["n_nodes"]) > 1, "tree must grow"
    Xt, yt = synth.piecewise_regression(4000, n_features=4, seed=33)
    pred = jax.jit(functools.partial(ht.predict, cfg))(state, jnp.array(Xt))
    mse = float(np.mean((np.asarray(pred) - yt) ** 2))
    base = float(np.var(yt))
    assert mse < 0.2 * base, (mse, base)


def test_tree_respects_capacity_and_depth():
    X, y = synth.piecewise_regression(8000, n_features=3, seed=5)
    cfg = ht.HTRConfig(n_features=3, max_nodes=15, n_bins=32,
                       grace_period=100, max_depth=3, r0=0.3)
    state = _train(cfg, X, y)
    assert int(state["n_nodes"]) <= 15
    assert int(jnp.max(state["depth"])) <= 3
    # structural sanity: children of internal nodes point inside capacity
    n = int(state["n_nodes"])
    internal = ~np.asarray(state["is_leaf"])[:n]
    kids = np.asarray(state["child"])[:n][internal]
    assert (kids >= 0).all() and (kids < n).all()


def test_tree_stationary_prediction_without_splits():
    """Below grace period the tree is a single leaf predicting the mean."""
    rng = np.random.default_rng(0)
    X = rng.normal(0, 1, (150, 2)).astype(np.float32)
    y = np.full(150, 7.5, np.float32)
    cfg = ht.HTRConfig(n_features=2, max_nodes=7, grace_period=1000)
    state = ht.init_state(cfg)
    state = ht.update(cfg, state, jnp.array(X), jnp.array(y))
    assert int(ht.n_leaves(state)) == 1
    pred = ht.predict(cfg, state, jnp.array(X[:5]))
    np.testing.assert_allclose(np.asarray(pred), 7.5, rtol=1e-4)


def test_update_stream_learns_ragged_tail():
    """N not divisible by batch_size: the tail rides in a masked final
    batch and must match the unpadded per-batch loop exactly."""
    N, bs = 1000, 256                      # 3 full batches + 232 tail rows
    X, y = synth.piecewise_regression(N, n_features=3, seed=21)
    cfg = ht.HTRConfig(n_features=3, max_nodes=15, n_bins=32,
                       grace_period=150, max_depth=4, r0=0.3)
    s_loop = ht.init_state(cfg)
    upd = jax.jit(functools.partial(ht.update, cfg))
    for i in range(0, N, bs):              # final call sees the bare tail
        s_loop = upd(s_loop, jnp.array(X[i:i + bs]), jnp.array(y[i:i + bs]))
    s_scan = ht.update_stream(cfg, ht.init_state(cfg), jnp.array(X),
                              jnp.array(y), batch_size=bs)
    assert int(s_loop["n_nodes"]) == int(s_scan["n_nodes"])
    np.testing.assert_array_equal(np.asarray(s_loop["ystats"]["n"]),
                                  np.asarray(s_scan["ystats"]["n"]))
    np.testing.assert_allclose(np.asarray(s_loop["ystats"]["mean"]),
                               np.asarray(s_scan["ystats"]["mean"]),
                               rtol=1e-5, atol=1e-5)
    # and the tail genuinely changed the tree vs the old truncating driver
    s_trunc = ht.update_stream(cfg, ht.init_state(cfg),
                               jnp.array(X[:(N // bs) * bs]),
                               jnp.array(y[:(N // bs) * bs]), batch_size=bs)
    assert not np.array_equal(np.asarray(s_scan["ystats"]["n"]),
                              np.asarray(s_trunc["ystats"]["n"]))


def test_forest_vmap():
    """A forest is just vmap over tree states."""
    X, y = synth.piecewise_regression(4000, n_features=3, seed=7)
    cfg = ht.HTRConfig(n_features=3, max_nodes=31, n_bins=32,
                       grace_period=200, max_depth=6, r0=0.3)
    n_trees = 4
    states = jax.vmap(lambda _: ht.init_state(cfg))(jnp.arange(n_trees))
    upd = jax.jit(jax.vmap(functools.partial(ht.update, cfg),
                           in_axes=(0, 0, 0)))
    bs = 250
    rng = np.random.default_rng(0)
    for i in range(0, 4000 - bs + 1, bs):
        xb = np.stack([X[i:i + bs]] * n_trees)
        yb = np.stack([y[i:i + bs]] * n_trees)
        # poor-man's bagging: per-tree shuffled order
        for t in range(n_trees):
            p = rng.permutation(bs)
            xb[t], yb[t] = xb[t][p], yb[t][p]
        states = upd(states, jnp.array(xb), jnp.array(yb))
    Xt, yt = synth.piecewise_regression(1000, n_features=3, seed=77)
    preds = jax.vmap(lambda s: ht.predict(cfg, s, jnp.array(Xt)))(states)
    ens = np.asarray(preds).mean(0)
    mse = float(np.mean((ens - yt) ** 2))
    assert mse < 0.3 * float(np.var(yt))
