"""Per-architecture smoke tests: REDUCED config of the same family, one
forward/train step on CPU, asserting output shapes + no NaNs (assignment
requirement).  Full configs are exercised only via the dry-run."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.configs import reduced
from repro.models import model as M

ARCHS = sorted(configs.ARCHS)
KEY = jax.random.PRNGKey(0)
B, SQ = 2, 32


def _batch(r):
    b = {"tokens": jax.random.randint(KEY, (B, SQ), 0, r.vocab),
         "labels": jax.random.randint(KEY, (B, SQ), 0, r.vocab)}
    if r.family == "encdec":
        b["enc_in"] = jax.random.normal(KEY, (B, r.enc_seq, r.d_model))
    if r.family == "vlm":
        b["loss_mask"] = jnp.ones((B, SQ), jnp.float32)
    return b


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_shapes_and_finite(arch):
    r = reduced(configs.get_arch(arch))
    params = M.init_params(KEY, r)
    loss, metrics = jax.jit(functools.partial(
        M.lm_loss, cfg=r, kv_chunk=16, loss_chunk=16))(params, batch=_batch(r))
    assert loss.shape == ()
    assert np.isfinite(float(loss))
    # one grad step decreases loss on the same batch
    g = jax.grad(lambda p: M.lm_loss(p, r, _batch(r), kv_chunk=16,
                                     loss_chunk=16)[0])(params)
    p2 = jax.tree.map(lambda p_, g_: p_ - 0.3 * g_, params, g)
    loss2, _ = M.lm_loss(p2, r, _batch(r), kv_chunk=16, loss_chunk=16)
    assert float(loss2) < float(loss)


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_roundtrip(arch):
    r = reduced(configs.get_arch(arch))
    params = M.init_params(KEY, r)
    cache = M.init_cache(r, B, 64)
    cache, logits = jax.jit(functools.partial(M.prefill, cfg=r, kv_chunk=16))(
        params, batch=_batch(r), cache=cache)
    assert logits.shape == (B, r.vocab)
    assert np.isfinite(np.asarray(logits)).all()
    tok = jnp.argmax(logits, -1)
    step = jax.jit(functools.partial(M.decode_step, cfg=r))
    for i in range(3):
        logits, cache = step(params, token=tok, cache=cache,
                             pos=jnp.int32(SQ + i))
        assert logits.shape == (B, r.vocab)
        assert np.isfinite(np.asarray(logits)).all()
        tok = jnp.argmax(logits, -1)


def test_decode_consistent_with_teacher_forcing():
    """Decode with cache must reproduce the no-cache forward logits."""
    r = reduced(configs.get_arch("phi3-mini-3.8b"))
    params = M.init_params(KEY, r)
    toks = jax.random.randint(KEY, (B, 8), 0, r.vocab)
    # full forward logits at the last position
    from repro.models import transformer as T
    from repro.models.layers import cast
    x = params["embed"][toks]
    h, _, _ = T.forward(params, r, x, jnp.arange(8), kv_chunk=8)
    h = T.rms_norm(h, params["final_norm"], r.norm_eps)
    full_logits = jnp.einsum("bsd,dv->bsv", h, params["lm_head"])
    # prefill 7 tokens then decode token 8
    cache = M.init_cache(r, B, 16)
    cache, _ = M.prefill(params, r, {"tokens": toks[:, :7]}, cache, kv_chunk=8)
    logits, _ = M.decode_step(params, r, toks[:, 7], cache, jnp.int32(7),
                              kv_chunk=8)
    np.testing.assert_allclose(np.asarray(logits),
                               np.asarray(full_logits[:, 7]),
                               rtol=2e-3, atol=2e-3)


def test_swa_ring_cache_decode_matches_window_semantics():
    """h2o-danube ring cache: decoding far past the window must only attend
    to the last `window` tokens."""
    r = reduced(configs.get_arch("h2o-danube-3-4b"), swa_window=16)
    params = M.init_params(KEY, r)
    # max_seq > window so the ring cache activates
    cache = M.init_cache(r, B, 64)
    assert "pos" in cache["attn"], "ring cache expected"
    toks = jax.random.randint(KEY, (B, 32), 0, r.vocab)
    cache, logits = M.prefill(params, r, {"tokens": toks}, cache, kv_chunk=16)
    step = jax.jit(functools.partial(M.decode_step, cfg=r))
    tok = jnp.argmax(logits, -1)
    for i in range(4):
        logits, cache = step(params, token=tok, cache=cache,
                             pos=jnp.int32(32 + i))
        assert np.isfinite(np.asarray(logits)).all()
        tok = jnp.argmax(logits, -1)


def test_moe_capacity_drop_rate():
    """With capacity_factor >= 1 and balanced tokens, drop rate is small."""
    from repro.models import layers as L
    r = reduced(configs.get_arch("moonshot-v1-16b-a3b"))
    p = L.moe_params(KEY, r)
    x = jax.random.normal(KEY, (2, 64, r.d_model))
    out, aux = L.moe(p, x, r, group_size=128)
    assert out.shape == x.shape
    assert np.isfinite(np.asarray(out)).all()
    assert float(aux) > 0.5  # aux loss near 1 when roughly balanced
