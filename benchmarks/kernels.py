"""Kernel micro-benchmarks.

On CPU the Pallas kernels run under interpret=True (a Python interpreter —
its wall time is meaningless), so we time the jnp reference path (what the
kernel computes) and report the kernel/oracle agreement + the analytic
VMEM/MXU utilization of the kernel's tiling for the TPU target."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import qo
from repro.kernels import ops
from repro.kernels.qo_update import TABLE_ROWS


def _time(f, *args, iters=20):
    r = f(*args)
    jax.block_until_ready(r)
    t0 = time.perf_counter()
    for _ in range(iters):
        r = f(*args)
    jax.block_until_ready(r)
    return (time.perf_counter() - t0) / iters


def run(out=None):
    rng = np.random.default_rng(0)
    report = {}
    for cap, n in ((128, 100_000), (256, 1_000_000)):
        x = jnp.array(rng.normal(0, 1, n).astype(np.float32))
        y = jnp.array(rng.normal(0, 1, n).astype(np.float32))
        t0 = qo.init(cap, radius=0.05)
        upd = jax.jit(qo.update)
        dt = _time(upd, t0, x, y)
        q = jax.jit(qo.best_split)
        table = upd(t0, x, y)
        qt = _time(q, table)
        # kernel agreement (interpret mode, correctness only)
        tk = ops.qo_update(t0, x[:4096], y[:4096], interpret=True)
        tr = qo.update(t0, x[:4096], y[:4096])
        agree = float(jnp.max(jnp.abs(tk["y"]["n"] - tr["y"]["n"])))
        # analytic kernel occupancy for TPU target (tile=1024, f32)
        tile = 1024
        vmem_bytes = (3 * tile + tile * cap + TABLE_ROWS * cap * 2) * 4
        report[f"qo_update_cap{cap}_n{n}"] = {
            "observe_ns_per_elem": dt / n * 1e9,
            "query_us": qt * 1e6,
            "kernel_vs_ref_max_abs_n_diff": agree,
            "kernel_tile_vmem_bytes": vmem_bytes,
            "kernel_vmem_fits_16MB": vmem_bytes < 16 * 2 ** 20,
        }
    return report
