"""Kernel micro-benchmarks.

On CPU the Pallas kernels run under interpret=True (a Python interpreter —
its wall time is meaningless), so we time the dispatchable backends (the
jnp lowering the tree actually runs off-TPU, and the seed reference it
replaces), report kernel/oracle agreement from a small interpret-mode
probe, and the analytic VMEM footprint of the kernels' tiling for the TPU
target."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import qo, stats
from repro.kernels import ops, ref
from repro.kernels.qo_update import TABLE_ROWS
from repro.kernels.qo_update_leaves import FOREST_ROWS, round_up


def _time(f, *args, iters=20):
    r = f(*args)
    jax.block_until_ready(r)
    t0 = time.perf_counter()
    for _ in range(iters):
        r = f(*args)
    jax.block_until_ready(r)
    return (time.perf_counter() - t0) / iters


def _single_table(report, rng):
    for cap, n in ((128, 100_000), (256, 1_000_000)):
        x = jnp.array(rng.normal(0, 1, n).astype(np.float32))
        y = jnp.array(rng.normal(0, 1, n).astype(np.float32))
        t0 = qo.init(cap, radius=0.05)
        upd = jax.jit(qo.update)
        dt = _time(upd, t0, x, y)
        q = jax.jit(qo.best_split)
        table = upd(t0, x, y)
        qt = _time(q, table)
        # kernel agreement (interpret mode, correctness only)
        tk = ops.qo_update(t0, x[:4096], y[:4096], interpret=True)
        tr = qo.update(t0, x[:4096], y[:4096])
        agree = float(jnp.max(jnp.abs(tk["y"]["n"] - tr["y"]["n"])))
        # analytic kernel occupancy for TPU target (tile=1024, f32)
        tile = 1024
        vmem_bytes = (3 * tile + tile * cap + TABLE_ROWS * cap * 2) * 4
        report[f"qo_update_cap{cap}_n{n}"] = {
            "observe_ns_per_elem": dt / n * 1e9,
            "query_us": qt * 1e6,
            "kernel_vs_ref_max_abs_n_diff": agree,
            "kernel_tile_vmem_bytes": vmem_bytes,
            "kernel_vmem_fits_16MB": vmem_bytes < 16 * 2 ** 20,
        }


def _forest(report, rng):
    """Forest-scale ops: every (leaf, feature) table of a tree at once."""
    for M, F, C, B in ((63, 4, 48, 256), (255, 8, 64, 1024)):
        ao_y = stats.init((M, F, C))
        ao_sum_x = jnp.zeros((M, F, C))
        ao_radius = jnp.full((M, F), 0.1, jnp.float32)
        ao_origin = jnp.zeros((M, F), jnp.float32)
        leaf = jnp.array(rng.integers(0, M, B), jnp.int32)
        X = jnp.array(rng.normal(0, 1, (B, F)).astype(np.float32))
        y = jnp.array(rng.normal(0, 1, B).astype(np.float32))
        attempt = jnp.ones((M,), bool)

        upd = jax.jit(lambda *a: ops.forest_update(*a, backend="jnp"))
        dt = _time(upd, ao_y, ao_sum_x, ao_radius, ao_origin, leaf, X, y)
        ao_y2, ao_sum_x2 = upd(ao_y, ao_sum_x, ao_radius, ao_origin,
                               leaf, X, y)
        qry = jax.jit(lambda *a: ops.forest_best_splits(*a, backend="jnp"))
        qt = _time(qry, ao_y2, ao_sum_x2, ao_radius, ao_origin, attempt)
        # the seed reference engine it replaces (vmap of per-table scans)
        qry_ref = jax.jit(ref.forest_query_ref)
        qt_ref = _time(qry_ref, ao_y2, ao_sum_x2, attempt)

        # interpret-mode agreement probe (small slice: interpreter is slow;
        # cross-checks the two THIS-repo backends against each other — the
        # per-table core.qo oracle comparison lives in tests/test_qo_batched)
        ky, _ = ops.forest_update(ao_y, ao_sum_x, ao_radius, ao_origin,
                                  leaf[:64], X[:64], y[:64],
                                  backend="interpret")
        ry, _ = ops.forest_update(ao_y, ao_sum_x, ao_radius, ao_origin,
                                  leaf[:64], X[:64], y[:64], backend="jnp")
        agree = float(jnp.max(jnp.abs(ky["n"] - ry["n"])))

        # analytic VMEM per grid step of qo_update_leaves (tile_m x Cp slabs)
        tile_m, tile_b = min(128, round_up(M, 8)), min(256, B)
        Cp = round_up(C, 128)
        vmem = (4 * tile_b                        # leaf/x/y/w tiles
                + 2 * FOREST_ROWS * tile_m * Cp   # in + out table slabs
                + tile_b * tile_m + 2 * tile_b * Cp) * 4  # one-hots
        report[f"forest_M{M}_F{F}_C{C}_B{B}"] = {
            "observe_ns_per_elem": dt / B * 1e9,
            "update_us": dt * 1e6,
            "query_us": qt * 1e6,
            "query_ref_us": qt_ref * 1e6,
            "query_speedup_vs_ref": qt_ref / qt,
            "interpret_vs_jnp_max_abs_n_diff": agree,
            "kernel_tile_vmem_bytes": vmem,
            "kernel_vmem_fits_16MB": vmem < 16 * 2 ** 20,
        }


def _tuned_dispatch(report, rng):
    """Autotuned vs hard-coded dispatch, same-run interleaved race.

    Runs the real tuner (full SEARCH_SPACE grid, measured best-of) for
    the forest_update and forest_route families on a ragged B=1300
    workload — the regime where the dispatch-shaping knobs (batch
    ladder, ply rounding) matter — then races winner vs defaults
    interleaved.  Bit-identity of every candidate is asserted inside
    ``tune_family`` itself, so a recorded speedup can never come from a
    schedule that changed results.
    """
    from repro.perf import tune as ptune

    shapes = dict(M=256, F=8, C=16, T=8, B=1300)
    w = ptune.make_workloads(**shapes)
    for family in ("forest_update", "forest_route"):
        key, entry = ptune.tune_family(family, "jnp", shapes=shapes, reps=4)
        tuned = dict(entry["params"])
        tkey = (family, "jnp", w["shape_class"][family])
        run_op = ptune._runner(family, w, "jnp")
        best = {"tuned": float("inf"), "default": float("inf")}
        for params, label in ((tuned, "tuned"), ({}, "default")):
            with ptune._only_tuning({tkey: params} if params else {}):
                jax.block_until_ready(run_op())           # warm both
        for _ in range(9):                                # interleaved race
            for params, label in ((tuned, "tuned"), ({}, "default")):
                with ptune._only_tuning({tkey: params} if params else {}):
                    t0 = time.perf_counter()
                    jax.block_until_ready(run_op())
                    best[label] = min(best[label],
                                      (time.perf_counter() - t0) * 1e6)
        report[f"tuned_dispatch_{family}"] = {
            "tuned_us": best["tuned"],
            "default_us": best["default"],
            "speedup_tuned_vs_default": best["default"] / best["tuned"],
            "params": tuned,
            "cache_key": key,
            "bit_identical": True,        # enforced by tune_family
        }
    ops.clear_jit_caches()


def run(out=None):
    rng = np.random.default_rng(0)
    report = {}
    _single_table(report, rng)
    _forest(report, rng)
    _tuned_dispatch(report, rng)
    return report


def to_rows(report):
    """BENCH_kernels.json rows (name, us_per_call, derived) — shared by
    benchmarks.run and benchmarks.check_regression so the regression gate
    diffs exactly the rows the trajectory artifact commits."""
    rows = []
    for name, k in report.items():
        if name.startswith("tuned_dispatch_"):
            rows.append((f"kernel_{name}", k["tuned_us"],
                         f"speedup_tuned_vs_default="
                         f"{k['speedup_tuned_vs_default']:.3f}"
                         f" default_us={k['default_us']:.1f}"
                         f" params={k['params']}"))
        else:
            rows.append((f"kernel_{name}", k["observe_ns_per_elem"] / 1e3,
                         f"query_us={k['query_us']:.1f}"))
    return rows
