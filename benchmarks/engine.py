"""Serving-engine benchmark: sustained open-loop throughput + tail
latency, engine overhead vs the bare read path, staleness vs cadence.

Three sections (DESIGN.md §5.6):

* **engine race** — one packed ``serve_once`` dispatch (submit + queue
  pop + concatenate + ``predict_snapshot`` + per-ticket split) vs the
  same-run bare ``serve.predict_snapshot`` on the SAME snapshot at the
  SAME pow-2 bucket.  Machine-independent structural floor (gated in
  check_regression): engine throughput >= ``0.8x`` bare — the admission
  and accounting layers must stay off the hot path.
* **open loop** — the threaded engine driven by
  :func:`repro.core.faults.bursty_arrivals` (base-rate arrivals with
  8x burst spikes, arrivals never wait for service) while the trainer
  absorbs its stream concurrently: sustained rows/s, p50/p99 request
  latency, and how many rows the bounded queue shed.
* **staleness sweep** — stepped (deterministic) train loops at
  ``sync_every`` in {2, 8}: publishes made, mean/max snapshot age in
  trainer steps, plus the measured cost of one freeze+validate+publish
  boundary.  Accuracy-only rows (us=0) carry the sweep; the publish
  cost is a timed row.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.serve import plateau_stream
from repro.core import engine as eng
from repro.core import faults as fl
from repro.core import forest as fr
from repro.core import hoeffding as ht
from repro.core import serve as sv


def _time(f, iters=20):
    t0 = time.perf_counter()
    for _ in range(iters):
        r = f()
    np.asarray(r)
    return (time.perf_counter() - t0) / iters


def _best(f, iters=20, trials=3):
    f()                                       # warm (compile, caches)
    return float(min(_time(f, iters) for _ in range(trials)))


def _race(fa, fb, rounds=150):
    """Tightly alternating single-call race: one call of each side per
    round, per-side minimum over all rounds.  Load epochs on the shared
    box outlast any fixed-size timing block, so block-interleaving (the
    serve._race discipline) still lets an epoch land on one side only;
    alternating call-by-call guarantees both sides sample every epoch
    and the min finds each side's quiet-floor — the ratio the
    structural gate needs is between those floors."""
    fa(), fb()                                # warm both
    ta = tb = float("inf")
    for _ in range(rounds):
        t0 = time.perf_counter()
        fa()
        t1 = time.perf_counter()
        fb()
        t2 = time.perf_counter()
        ta = min(ta, t1 - t0)
        tb = min(tb, t2 - t1)
    return ta, tb


def _trained(n, n_features, n_trees):
    tcfg = ht.HTRConfig(n_features=n_features, max_nodes=63, n_bins=48,
                        grace_period=300, max_depth=12, r0=0.25)
    cfg = fr.ForestConfig(tree=tcfg, n_trees=n_trees, subspace=1.0)
    X, y = plateau_stream(n, n_features=n_features, seed=11)
    state = fr.init_forest(cfg, jax.random.PRNGKey(0))
    state, _ = fr.update_stream(cfg, state, np.asarray(X), np.asarray(y))
    jax.block_until_ready(state["trees"]["n_nodes"])
    return cfg, state, X, y


def run(n=8192, n_features=8, n_trees=8, B=2048, trials=3,
        open_loop_requests=96):
    cfg, state, X, y = _trained(n, n_features, n_trees)
    Xq = np.ascontiguousarray(X[:B], np.float32)

    # --- race: engine serve_once vs bare predict_snapshot, same bucket ---
    # the no-op stream keeps the trainer out of the race: this measures
    # pure read-path overhead (admission, packing, accounting)
    e = eng.ServingEngine(cfg, state, lambda step: None,
                          cfg=eng.EngineConfig(max_queue_rows=4 * B,
                                               max_batch_rows=B))
    snap = e.snapshot_for_version(e.published_version)

    def eng_once():
        t = e.submit(Xq)
        e.serve_once()
        return t.result

    def bare_once():
        return np.asarray(sv.predict_snapshot(snap, Xq))

    np.testing.assert_array_equal(eng_once(), bare_once())  # equality gate
    t_eng, t_bare = _race(eng_once, bare_once)

    # --- open loop: bursty arrivals racing a live trainer ------------------
    steps, rows = 12, 256
    stream = (lambda s: (X[(s * rows) % n:(s * rows) % n + rows],
                         y[(s * rows) % n:(s * rows) % n + rows])
              if s < steps else None)
    inj = fl.FaultInjector()
    eo = eng.ServingEngine(cfg, state, stream,
                           cfg=eng.EngineConfig(sync_every=4, ckpt_every=0,
                                                max_queue_rows=4096,
                                                max_batch_rows=2048),
                           injector=inj)
    sched = fl.bursty_arrivals(open_loop_requests, base_rows=256,
                               burst_factor=8, burst_every=10, burst_len=2,
                               base_gap_s=0.02, seed=3)
    pool = np.ascontiguousarray(X[:4096], np.float32)
    # compile both dispatches off-clock: one stepped trainer batch and one
    # max-bucket serve — the open loop measures steady state, not warmup
    eo.train_once()
    eo.submit(pool[:2048])
    eo.serve_once()
    m0 = eo.metrics()
    eo.start()
    t0 = time.perf_counter()
    tickets = []
    for gap, r in sched:
        if gap:
            time.sleep(gap)
        tickets.append(eo.submit(pool[:min(r, len(pool))]))
    for t in tickets:
        t.wait(timeout=60)
    wall = time.perf_counter() - t0
    eo.stop(drain=True)
    m = eo.metrics()
    for k in ("served_rows", "serve_batches", "shed_requests", "shed_rows"):
        m[k] -= m0[k]                       # the warmup is off the books
    lat = np.array([t.latency_s for t in tickets if t.status == "done"])

    # --- staleness sweep: cadence vs snapshot age (stepped, exact) --------
    sweep = {}
    for se in (2, 8):
        es = eng.ServingEngine(
            cfg, state, stream,
            cfg=eng.EngineConfig(sync_every=se, ckpt_every=0))
        ages = []
        while es.train_once():
            ages.append(es.staleness()["age_steps"])
        sweep[se] = {"publishes": es.metrics()["publishes"],
                     "mean_age_steps": float(np.mean(ages)),
                     "max_age_steps": int(np.max(ages))}
    t_pub = _best(e.publish_from_state, iters=5, trials=trials)

    return {
        "B": B, "n_trees": n_trees, "trials": trials,
        "race": {
            "engine_us": t_eng * 1e6, "bare_us": t_bare * 1e6,
            "rows_per_s": B / t_eng,
            "throughput_frac_of_bare": t_bare / t_eng},
        "open_loop": {
            "requests": len(tickets), "wall_s": wall,
            "served_rows": m["served_rows"],
            "sustained_rows_per_s": m["served_rows"] / wall,
            "serve_batches": m["serve_batches"],
            "shed_requests": m["shed_requests"],
            "shed_rows": m["shed_rows"],
            "p50_ms": float(np.percentile(lat, 50) * 1e3),
            "p99_ms": float(np.percentile(lat, 99) * 1e3),
            "publishes": m["publishes"]},
        "publish_us": t_pub * 1e6,
        "staleness": sweep,
    }


def to_rows(report):
    """BENCH_engine.json rows (name, us_per_call, derived)."""
    r, o, s = report["race"], report["open_loop"], report["staleness"]
    B = report["B"]
    rows = [
        ("engine_serve_once", r["engine_us"],
         f"B={B} T={report['n_trees']} rows_per_s={r['rows_per_s']:.0f}"
         f" frac_of_bare={r['throughput_frac_of_bare']:.2f}"),
        ("engine_bare_snapshot", r["bare_us"],
         f"B={B} same-run bare predict_snapshot, same bucket"),
        ("engine_open_loop_request", 1e6 * o["wall_s"] / o["requests"],
         f"sustained_rows_per_s={o['sustained_rows_per_s']:.0f}"
         f" p50_ms={o['p50_ms']:.2f} p99_ms={o['p99_ms']:.2f}"
         f" batches={o['serve_batches']} shed={o['shed_requests']}"
         f"/{o['shed_rows']}rows publishes={o['publishes']}"),
        ("engine_publish", report["publish_us"],
         "freeze + validate + atomic swap (no checkpoint)"),
    ]
    for se, rec in sorted(s.items()):
        rows.append((f"engine_staleness_sync{se}", 0.0,
                     f"publishes={rec['publishes']}"
                     f" mean_age={rec['mean_age_steps']:.2f}"
                     f" max_age={rec['max_age_steps']}"))
    return rows
