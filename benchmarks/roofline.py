"""Analytic roofline for the forest kernels: achieved vs attainable.

For each dispatch family (``forest_update``, ``forest_best_splits``,
``forest_route``, ``forest_merge``) this computes the *algorithmically
necessary* flops and bytes from the workload shapes (M, F, C, T, B,
plies) — counting only work any implementation of the op must do, so the
model cannot flatter a wasteful schedule — and divides by device peaks
**measured in the same run** (an f32 matmul probe for flops, a
read+write streaming probe for bandwidth).  The bound

    attainable_us = max(flops / peak_flops, bytes / peak_bw)

is the classic roofline: an op can finish no faster than its slower
wall.  ``achieved_frac = attainable_us / measured_us`` is then a
**machine-independent** health signal: host load slows the probes and
the kernels together, so the fraction holds still while absolute wall
times swing 2-3x (docs/benchmarks.md) — which is why
``check_regression`` gates on it instead of a wall-time band.

Ops are measured through their PUBLIC concrete-dispatch wrappers (pad +
cached jit + slice), so the fraction charges the whole path a real
caller pays, and probes/ops interleave round-robin per rep.  Writes
``BENCH_roofline.json`` via ``benchmarks.run``; the regression gate
writes ``BENCH_roofline.fresh.json`` only.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.perf.tune import make_workloads

#: Necessary-work models, per family.  f32 everywhere (4 bytes/elem).
#: flops count the arithmetic any lowering must perform; bytes count one
#: read of every input and one write of every output — compulsory
#: traffic, no temporaries — so achieved_frac <= 1 up to model error and
#: real schedules land well below it.


def _model_update(M, F, C, B):
    flops = 12 * B * F + 18 * M * F * C   # bin + payload math; Chan merge
    bytes_ = 4 * (B * (F + 3)             # X, y, w, leaf in
                  + 2 * 4 * M * F * C)    # 4 table planes in + out
    return flops, bytes_


def _model_query(M, F, C):
    flops = 25 * M * F * C                # prefix stats + variance ratio
    bytes_ = 4 * (4 * M * F * C + 2 * M * F)      # planes in, merit/thr out
    return flops, bytes_


def _model_route(T, M, F, B, plies):
    flops = 3 * T * B * plies             # compare + child-id arithmetic
    bytes_ = 4 * (3 * T * B * plies       # fc/thr/x gathers per ply
                  + 3 * T * M + B * F + T * B)    # tables, X in, leaf out
    return flops, bytes_


def _model_merge(N, F, C):
    flops = 12 * N * F * C                # Chan combine per bin
    bytes_ = 4 * 3 * 4 * N * F * C        # 2 operands in + 1 out, 4 planes
    return flops, bytes_


def _best_us(fn, best):
    t0 = time.perf_counter()
    jax.block_until_ready(fn())
    return min(best, (time.perf_counter() - t0) * 1e6)


def _probes():
    """Same-run device peak estimators: measured, not datasheet."""
    n = 512
    a = jnp.ones((n, n), jnp.float32)
    mm = jax.jit(lambda a, b: a @ b)
    stream = jnp.ones((8 * 2 ** 20,), jnp.float32)        # 32 MB
    add = jax.jit(lambda x: x + 1.0)
    return {
        "peak_flops": (lambda: mm(a, a), 2.0 * n ** 3),
        "peak_bw": (lambda: add(stream), 2.0 * stream.nbytes),
    }


def run(reps: int = 3, shapes: dict | None = None) -> dict:
    shapes = dict(dict(M=256, F=8, C=16, T=8, B=1300), **(shapes or {}))
    M, F, C, T, B = (shapes[k] for k in "MFCTB")
    w = make_workloads(**shapes)
    plies = ops.depth_bucket(w["depth"])
    backend = ops.resolve_backend(None)
    fams = {
        "forest_update": (
            lambda: ops.forest_update(*w["update"], backend=backend),
            _model_update(M, F, C, B)),
        "forest_best_splits": (
            lambda: ops.forest_best_splits(*w["query"], backend=backend),
            _model_query(M, F, C)),
        "forest_route": (
            lambda: ops.forest_route(*w["route"], depth=w["depth"],
                                     backend=backend),
            _model_route(T, M, F, B, plies)),
        "forest_merge": (
            lambda: ops.forest_merge(*w["merge"], backend=backend),
            _model_merge(M, F, C)),
    }
    probes = _probes()
    for fn, _ in list(probes.values()) + list(fams.values()):
        jax.block_until_ready(fn())                       # compile/warm
    best = {name: float("inf") for name in list(fams) + list(probes)}
    for _ in range(reps):                                 # interleaved
        for name, (fn, _) in probes.items():
            best[name] = _best_us(fn, best[name])
        for name, (fn, _) in fams.items():
            best[name] = _best_us(fn, best[name])

    peak_flops = probes["peak_flops"][1] / (best["peak_flops"] / 1e6)
    peak_bw = probes["peak_bw"][1] / (best["peak_bw"] / 1e6)
    report = {
        "backend": backend,
        "shapes": dict(shapes, plies=plies),
        "device": {
            "kind": jax.devices()[0].device_kind,
            "peak_gflops": peak_flops / 1e9,
            "peak_gbps": peak_bw / 1e9,
        },
        "ops": {},
    }
    for name, (_, (flops, bytes_)) in fams.items():
        attainable_us = max(flops / peak_flops, bytes_ / peak_bw) * 1e6
        measured = best[name]
        report["ops"][name] = {
            "flops": flops,
            "bytes": bytes_,
            "intensity_flops_per_byte": flops / bytes_,
            "bound": ("compute" if flops / peak_flops > bytes_ / peak_bw
                      else "memory"),
            "measured_us": measured,
            "attainable_us": attainable_us,
            "achieved_frac": attainable_us / measured,
            "achieved_gflops": flops / measured / 1e3,
            "achieved_gbps": bytes_ / measured / 1e3,
        }
    return report


def to_rows(report):
    """BENCH_roofline.json rows — the peaks row is accuracy-only
    (us_per_call 0.0) so machine-to-machine probe drift can never trip
    the absolute wall-time band; each op row's timing is banded like any
    other bench row and its achieved_frac rides in ``derived``."""
    d = report["device"]
    rows = [("roofline_device_peaks", 0.0,
             f"kind={d['kind']} peak_gflops={d['peak_gflops']:.2f}"
             f" peak_gbps={d['peak_gbps']:.2f}")]
    for name, o in report["ops"].items():
        rows.append((f"roofline_{name}", o["measured_us"],
                     f"achieved_frac={o['achieved_frac']:.4f}"
                     f" bound={o['bound']}"
                     f" attainable_us={o['attainable_us']:.1f}"
                     f" flops={o['flops']:.0f} bytes={o['bytes']:.0f}"))
    return rows


if __name__ == "__main__":
    rep = run()
    for name, us, derived in to_rows(rep):
        print(f"{name},{us:.3f},{derived}")
