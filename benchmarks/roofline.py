"""Roofline report generator: reads dryrun_results.json into the
EXPERIMENTS.md tables (one row per (arch x shape x mesh))."""
from __future__ import annotations

import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "..", "dryrun_results.json")


def load(path=RESULTS):
    with open(path) as f:
        return json.load(f)


def table(rows=None, mesh="16x16"):
    rows = rows or load()
    out = []
    for r in rows:
        if r.get("mesh") != mesh:
            continue
        if r["status"] == "skipped":
            out.append({"arch": r["arch"], "shape": r["shape"],
                        "status": "skipped", "reason": r["reason"]})
            continue
        if r["status"] != "ok":
            out.append({"arch": r["arch"], "shape": r["shape"],
                        "status": "FAILED"})
            continue
        out.append({
            "arch": r["arch"], "shape": r["shape"], "status": "ok",
            "t_compute_s": r["t_compute_s"],
            "t_memory_s": r["t_memory_s"],
            "t_collective_s": r["t_collective_s"],
            "bottleneck": r["bottleneck"],
            "useful_flops_ratio": r["useful_flops_ratio"],
            "roofline_fraction": r["roofline_fraction"],
        })
    return out


def markdown(rows=None, mesh="16x16"):
    t = table(rows, mesh)
    lines = [
        f"| arch | shape | compute s | memory s | collective s | bottleneck "
        f"| useful-flops | roofline frac |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in t:
        if r["status"] != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                         f"{r['status']} | — | — |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.3g} | "
            f"{r['t_memory_s']:.3g} | {r['t_collective_s']:.3g} | "
            f"{r['bottleneck']} | {r['useful_flops_ratio']:.2f} | "
            f"{r['roofline_fraction']:.4f} |")
    return "\n".join(lines)


def summary(rows=None):
    rows = rows or load()
    ok = [r for r in rows if r["status"] == "ok"]
    by_bneck = {}
    for r in ok:
        by_bneck.setdefault(r["bottleneck"], []).append(
            (r["arch"], r["shape"], r["mesh"]))
    worst = sorted(ok, key=lambda r: r["roofline_fraction"])[:5]
    most_coll = sorted(ok, key=lambda r: -r["t_collective_s"])[:5]
    return {
        "cells_ok": len(ok),
        "cells_skipped": sum(1 for r in rows if r["status"] == "skipped"),
        "cells_failed": sum(1 for r in rows if r["status"] == "FAILED"),
        "bottleneck_counts": {k: len(v) for k, v in by_bneck.items()},
        "worst_roofline": [(r["arch"], r["shape"], r["mesh"],
                            round(r["roofline_fraction"], 5)) for r in worst],
        "most_collective_bound": [(r["arch"], r["shape"], r["mesh"],
                                   round(r["t_collective_s"], 2))
                                  for r in most_coll],
    }
