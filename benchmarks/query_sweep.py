"""Attempt-fraction sweep of the split query (DESIGN.md §2.5).

The acceptance benchmark for compacted attempt scheduling: for each
forest size, sweep the attempting fraction K/M over {1/64, 1/8, 1/2, 1}
and race the K-compacted query against the full M-table scan IN THE SAME
RUN (same tables, same jit discipline, interleaved timing loops), so the
reported speedup is immune to machine-load drift between runs.  Both
paths go through ``ops.forest_best_splits`` jitted with the attempt mask
as an argument — i.e. the traced ``lax.switch`` bucket selection the
streaming tree actually executes — and are pinned equal on the finite
entries before timing.

The acceptance bar (ISSUE 3): at K/M = 1/8, M = 255, compacted must be
>= 3x the full scan, and learned trees bit-identical (pinned by
tests/test_attempt_compaction.py).
"""
from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import stats
from repro.kernels import ops

FRACTIONS = ((1, 64), (1, 8), (1, 2), (1, 1))


def _time(f, *args, iters=20):
    r = f(*args)
    jax.block_until_ready(r)
    t0 = time.perf_counter()
    for _ in range(iters):
        r = f(*args)
    jax.block_until_ready(r)
    return (time.perf_counter() - t0) / iters


def _populated_forest(rng, M, F, C, B):
    ao_y = stats.init((M, F, C))
    ao_sum_x = jnp.zeros((M, F, C))
    ao_radius = jnp.full((M, F), 0.1, jnp.float32)
    ao_origin = jnp.zeros((M, F), jnp.float32)
    leaf = jnp.array(rng.integers(0, M, B), jnp.int32)
    X = jnp.array(rng.normal(0, 1, (B, F)).astype(np.float32))
    y = jnp.array(rng.normal(0, 1, B).astype(np.float32))
    return ops.forest_update(ao_y, ao_sum_x, ao_radius, ao_origin,
                             leaf, X, y, backend="jnp") + (ao_radius,
                                                           ao_origin)


def run(backend: str = "jnp"):
    """Returns {size_key: {frac, K, compact_us, full_us, speedup, ...}}."""
    rng = np.random.default_rng(0)
    report = {}
    for M, F, C, B in ((63, 4, 48, 1024), (255, 8, 64, 4096)):
        tabs = _populated_forest(rng, M, F, C, B)
        # tables ride as jit ARGUMENTS (like the streaming tree's trace):
        # baking them in as constants lets XLA constant-fold table math
        # with compile-time rounding, breaking the bitwise equality gate
        j_comp = jax.jit(functools.partial(ops.forest_best_splits,
                                           backend=backend, compact=True))
        j_full = jax.jit(functools.partial(ops.forest_best_splits,
                                           backend=backend, compact=False))
        for num, den in FRACTIONS:
            K = max(1, (M * num) // den)
            att = np.zeros(M, bool)
            att[rng.choice(M, K, replace=False)] = True
            att = jnp.array(att)
            # equality gate before timing: compacted == full on finite rows
            mc, tc = j_comp(*tabs, att)
            mf, tf = j_full(*tabs, att)
            fin = np.isfinite(np.asarray(mf))
            assert (np.isfinite(np.asarray(mc)) == fin).all()
            np.testing.assert_array_equal(np.asarray(mc)[fin],
                                          np.asarray(mf)[fin])
            t_c = _time(j_comp, *tabs, att)
            t_f = _time(j_full, *tabs, att)
            report[f"M{M}_F{F}_C{C}_K{K}"] = {
                "frac": f"{num}/{den}", "K": K, "M": M,
                "compact_us": t_c * 1e6, "full_us": t_f * 1e6,
                "speedup_vs_full_scan": t_f / t_c,
                "buckets": list(ops.query_buckets(M)),
            }
    return report


def to_rows(report):
    """BENCH_query.json rows: (name, us_per_call, derived) — us_per_call
    is the compacted query, the path the streaming tree dispatches."""
    rows = []
    for name, r in report.items():
        rows.append((
            f"query_{name}", r["compact_us"],
            f"frac={r['frac']} full_us={r['full_us']:.1f}"
            f" speedup_vs_full_scan={r['speedup_vs_full_scan']:.2f}"))
    return rows
