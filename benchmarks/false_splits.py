"""Statistical validation of the split-decision backends (DESIGN.md §2.7).

Three measurements, all deterministic given the seeds — the first two are
machine-independent statistical gates, not wall-times:

* **false-split rate** — trees trained on pure-noise streams (y
  independent of X) under the ``eager`` schedule, where every mature
  leaf re-tests every batch.  ANY split is a false split.  The anytime
  e-process backend must keep the empirical rate ≤ its configured α;
  the Hoeffding ratio test exceeds it (its fixed-n bound is voided by
  the peeking, and its ``eps < tau`` tie-break fires unconditionally
  once ``n > ln(1/delta)/(2 tau^2)``) — the motivating defect, kept
  measured so the gap never silently closes.
* **drift prequential MSE** — test-then-train MSE on the shared
  concept-drift suite (:func:`benchmarks.forest.drift_stream`) under
  ``eager``, anytime vs Hoeffding.  The e-process must not give back
  the statistical win as accuracy: the gate is ratio ≤ 1.05 (in
  practice it is *better* — fewer noise splits means less capacity
  wasted before the drift and cleaner leaves after it).
* **decision-stage µs/attempt** — wall time of one jitted
  :func:`repro.core.decide.decide` call on an (M, F) merit table, per
  backend (the stage is a few fused elementwise ops + a top-k; it must
  stay negligible next to the query that feeds it).

``python -m benchmarks.run`` writes the rows to BENCH_splits.json;
``check_regression`` re-runs this module and enforces the two
statistical gates as structural (machine-independent) checks.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import decide as dc
from repro.core import hoeffding as ht
from benchmarks.forest import drift_stream

ALPHA = 0.1          # alpha == delta so both backends claim the same risk
N_SEEDS = 12
MAX_MSE_RATIO = 1.05  # drift-suite acceptance bar: anytime vs hoeffding


def _noise_cfg(backend: str) -> ht.HTRConfig:
    return ht.HTRConfig(n_features=4, max_nodes=31, n_bins=32,
                        grace_period=100, delta=ALPHA, tau=0.05,
                        max_depth=6, r0=0.3, split_backend="jnp",
                        attempt_schedule="eager",
                        decision_backend=backend, alpha=ALPHA)


def false_split_rates(n_seeds: int = N_SEEDS, n: int = 4000, seed0: int = 100):
    out = {}
    for backend in dc.DECISION_BACKENDS:
        cfg = _noise_cfg(backend)
        hits = 0
        for i in range(n_seeds):
            rng = np.random.default_rng(seed0 + i)
            X = jnp.array(rng.normal(size=(n, 4)), jnp.float32)
            y = jnp.array(rng.normal(size=n), jnp.float32)
            s = ht.update_stream(cfg, ht.init_state(cfg), X, y,
                                 batch_size=64)
            hits += int(s["n_nodes"]) > 1
        out[backend] = {"false_splits": hits, "seeds": n_seeds,
                        "rate": hits / n_seeds, "alpha": ALPHA}
    return out


def _drift_cfg(backend: str) -> ht.HTRConfig:
    return ht.HTRConfig(n_features=4, max_nodes=63, n_bins=48,
                        grace_period=300, max_depth=8, r0=0.25,
                        split_backend="jnp", attempt_schedule="eager",
                        decision_backend=backend, alpha=0.05)


def drift_prequential(n: int = 12288, bs: int = 256):
    X, y = drift_stream(n, 4, seed=11)
    X, y = jnp.array(X), jnp.array(y)
    out = {}
    for backend in dc.DECISION_BACKENDS:
        cfg = _drift_cfg(backend)
        Xc, yc, wc = ht.pad_stream(X, y, None, bs)

        def body(s, xyw, cfg=cfg):
            xb, yb, wb = xyw
            yhat = ht.predict(cfg, s, xb)
            mse = jnp.sum(wb * (yhat - yb) ** 2) / jnp.maximum(wb.sum(), 1.0)
            return ht.update(cfg, s, xb, yb, wb), mse

        s, mses = jax.jit(lambda st: jax.lax.scan(body, st, (Xc, yc, wc)))(
            ht.init_state(cfg))
        out[backend] = {"preq_mse": float(jnp.mean(mses)),
                        "n_nodes": int(s["n_nodes"])}
    out["mse_ratio"] = (out["anytime"]["preq_mse"]
                        / out["hoeffding"]["preq_mse"])
    return out


def decide_latency(M: int = 63, F: int = 4, trials: int = 200):
    """µs per jitted decision-stage call, per backend (M leaves looked
    at once — the per-attempt cost is this over K)."""
    rng = np.random.default_rng(0)
    n = jnp.array(rng.uniform(100, 5000, M).astype(np.float32))
    state = {"ystats": {"n": n, "mean": jnp.zeros((M,)), "m2": n * 2.0},
             "dec_logE": jnp.array(rng.uniform(0, 2, (M, F)),
                                   dtype=jnp.float32),
             "dec_n_last": n * 0.5}
    merit = jnp.array(rng.uniform(0, 1.5, (M, F)).astype(np.float32))
    attempt = jnp.array(rng.random(M) < 0.5)
    out = {}
    for backend in dc.DECISION_BACKENDS:
        cfg = _noise_cfg(backend)
        fn = jax.jit(lambda st, m, a, cfg=cfg: dc.decide(cfg, st, m, a))
        jax.block_until_ready(fn(state, merit, attempt))
        t0 = time.perf_counter()
        for _ in range(trials):
            r = fn(state, merit, attempt)
        jax.block_until_ready(r)
        out[backend] = (time.perf_counter() - t0) / trials * 1e6
    return out


def run():
    return {"false_splits": false_split_rates(),
            "drift": drift_prequential(),
            "decide_us": decide_latency()}


def to_rows(report):
    fs, dr = report["false_splits"], report["drift"]
    rows = []
    for b in dc.DECISION_BACKENDS:
        r = fs[b]
        # statistical rows: us_per_call = 0 (accuracy-only, never timed)
        rows.append((f"false_split_rate_{b}", 0.0,
                     f"rate={r['rate']:.3f} ({r['false_splits']}/"
                     f"{r['seeds']}) alpha={r['alpha']} schedule=eager"))
    rows.append(("drift_preq_mse_anytime_vs_hoeffding", 0.0,
                 f"mse_ratio={dr['mse_ratio']:.3f}"
                 f" anytime={dr['anytime']['preq_mse']:.3f}"
                 f" hoeffding={dr['hoeffding']['preq_mse']:.3f}"
                 f" nodes={dr['anytime']['n_nodes']}/"
                 f"{dr['hoeffding']['n_nodes']}"))
    for b in dc.DECISION_BACKENDS:
        rows.append((f"decide_stage_{b}", report["decide_us"][b],
                     "jitted decide() on (63,4) merit, µs/call"))
    return rows
