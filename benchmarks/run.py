"""Benchmark entry point: ``PYTHONPATH=src python -m benchmarks.run``.

One section per paper table/figure + the system-level benches.
Prints ``name,us_per_call,derived`` CSV rows (harness contract) and dumps
the full JSON report to benchmarks/report.json.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

import jax.numpy as jnp

from repro.models import layers as L

L.set_compute_dtype(jnp.float32)  # CPU container cannot execute bf16 dots

from benchmarks import (aos, dp, engine, false_splits, forest,  # noqa: E402
                        kernels, query_sweep, roofline, serve, tree)
from benchmarks.bench_io import write_bench as _write_bench  # noqa: E402


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="full paper grid (sizes to 50k, 10 seeds)")
    ap.add_argument("--skip-aos", action="store_true")
    args = ap.parse_args()

    report = {}
    csv = []

    # --- paper Figs. 1-6: AO comparison grid -----------------------------
    if not args.skip_aos:
        rep = aos.run(full=args.full)
        report["aos"] = {k: v for k, v in rep.items() if k != "rows"}
        report["aos_rows"] = rep["rows"]
        # emit averaged CSV per AO
        by_ao = {}
        for r in rep["rows"]:
            by_ao.setdefault(r["ao"], []).append(r)
        for ao_name, rows in sorted(by_ao.items()):
            obs = sum(r["observe_s"] for r in rows) / len(rows)
            qry = sum(r["query_s"] for r in rows) / len(rows)
            merit = sum(r["merit"] for r in rows) / len(rows)
            elems = sum(r["elements"] for r in rows) / len(rows)
            csv.append((f"ao_observe_{ao_name}", obs * 1e6,
                        f"elements={elems:.0f}"))
            csv.append((f"ao_query_{ao_name}", qry * 1e6,
                        f"merit={merit:.4f}"))

    # --- tree-level e2e (paper §7 future work, implemented) --------------
    trep = tree.run()
    report["tree"] = trep
    tree_rows = [
        ("hoeffding_tree_update", 1e6 / trep["kernel"]["instances_per_s"],
         f"mse_ratio={trep['kernel']['mse_ratio']:.4f}"
         f" speedup_vs_oracle={trep['kernel_speedup_vs_oracle']:.3f}"
         f" mse_rel_diff={trep['mse_rel_diff_vs_oracle']:.5f}"),
        ("hoeffding_tree_update_oracle",
         1e6 / trep["oracle"]["instances_per_s"],
         f"mse_ratio={trep['oracle']['mse_ratio']:.4f}"),
    ]
    csv.extend(tree_rows)
    _write_bench("BENCH_tree.json", tree_rows)

    # --- forest-level e2e: vmapped tree axis vs loop-over-trees ----------
    frep = forest.run()
    report["forest"] = frep
    preq = frep["prequential"]
    forest_rows = [
        ("forest_update_vmapped",
         1e6 / frep["vmapped"]["instances_per_s"],
         f"T={frep['n_trees']}"
         f" speedup_vs_loop={frep['speedup_vs_loop']:.3f}"),
        ("forest_update_loop", 1e6 / frep["loop"]["instances_per_s"],
         f"T={frep['n_trees']} per-tree python loop baseline"),
        # accuracy-only row: us_per_call deliberately 0 so the timing is
        # not double-counted with the forest_update_vmapped row above
        ("forest_prequential_drift", 0.0,
         f"forest_mse={preq['forest_mse']:.3f}"
         f" best_member_mse={preq['best_member_mse']:.3f}"
         f" beats_best_member={preq['forest_beats_best_member']}"
         f" drift_resets={preq['drift_resets']}"),
    ]
    csv.extend(forest_rows)
    _write_bench("BENCH_forest.json", forest_rows)

    # --- serving: fused routing + frozen snapshots (read path) ------------
    srep = serve.run()
    report["serve"] = srep
    serve_rows = serve.to_rows(srep)
    csv.extend(serve_rows)
    _write_bench("BENCH_serve.json", serve_rows)

    # --- continuous-serving engine: admission overhead + open-loop load ---
    erep = engine.run()
    report["engine"] = erep
    engine_rows = engine.to_rows(erep)
    csv.extend(engine_rows)
    _write_bench("BENCH_engine.json", engine_rows)

    # --- data-parallel stream scale-out (§4.1; own subprocess for the
    # forced-host-device XLA flags) ----------------------------------------
    drep = dp.run()
    report["dp"] = drep
    dp_rows = dp.to_rows(drep)
    csv.extend(dp_rows)
    _write_bench("BENCH_dp.json", dp_rows)

    # --- split-decision validity: false-split rates + drift MSE (§2.7) ----
    fsrep = false_splits.run()
    report["false_splits"] = fsrep
    fs_rows = false_splits.to_rows(fsrep)
    csv.extend(fs_rows)
    _write_bench("BENCH_splits.json", fs_rows)

    # --- kernel micro-benches ---------------------------------------------
    krep = kernels.run()
    report["kernels"] = krep
    kernel_rows = kernels.to_rows(krep)
    csv.extend(kernel_rows)
    _write_bench("BENCH_kernels.json", kernel_rows)

    # --- attempt-fraction query sweep: compacted vs full scan (§2.5) ------
    qrep = query_sweep.run()
    report["query_sweep"] = qrep
    query_rows = query_sweep.to_rows(qrep)
    csv.extend(query_rows)
    _write_bench("BENCH_query.json", query_rows)

    # --- roofline summary from the dry-run ---------------------------------
    try:
        report["roofline_summary"] = roofline.summary()
        s = report["roofline_summary"]
        csv.append(("dryrun_cells_ok", s["cells_ok"],
                    f"failed={s['cells_failed']}"))
    except FileNotFoundError:
        print("warning: dryrun_results.json missing; run repro.launch.dryrun",
              file=sys.stderr)

    out_path = os.path.join(os.path.dirname(__file__), "report.json")
    with open(out_path, "w") as f:
        json.dump(report, f, indent=1, default=float)

    print("name,us_per_call,derived")
    for name, us, derived in csv:
        print(f"{name},{us:.3f},{derived}")


if __name__ == "__main__":
    main()
