"""Benchmark entry point: ``PYTHONPATH=src python -m benchmarks.run``.

One section per paper table/figure + the system-level benches.
Prints ``name,us_per_call,derived`` CSV rows (harness contract) and dumps
the full JSON report to benchmarks/report.json (a run artifact,
gitignored — the committed trajectory lives in the BENCH_*.json files).

``--only SECTION [SECTION...]`` runs a subset (see ``SECTIONS``);
``--profile`` captures a bounded ``jax.profiler`` trace (one dispatch
per kernel family, written to ``profile_trace/`` at the repo root) and
harvests per-op compiled flops/bytes into ``BENCH_profile.fresh.json``
— both gitignored CI artifacts, see docs/benchmarks.md §How to profile.
"""
from __future__ import annotations

import argparse
import json
import os

import jax.numpy as jnp

from repro.models import layers as L

L.set_compute_dtype(jnp.float32)  # CPU container cannot execute bf16 dots

from benchmarks import (aos, dp, engine, false_splits, forest,  # noqa: E402
                        kernels, query_sweep, roofline, serve, tree)
from benchmarks import sketch as sketch_bench  # noqa: E402
from benchmarks.bench_io import REPO_ROOT, write_bench  # noqa: E402


def _sec_aos(report, csv, args):
    rep = aos.run(full=args.full)
    report["aos"] = {k: v for k, v in rep.items() if k != "rows"}
    report["aos_rows"] = rep["rows"]
    by_ao = {}
    for r in rep["rows"]:
        by_ao.setdefault(r["ao"], []).append(r)
    for ao_name, rows in sorted(by_ao.items()):
        obs = sum(r["observe_s"] for r in rows) / len(rows)
        qry = sum(r["query_s"] for r in rows) / len(rows)
        merit = sum(r["merit"] for r in rows) / len(rows)
        elems = sum(r["elements"] for r in rows) / len(rows)
        csv.append((f"ao_observe_{ao_name}", obs * 1e6,
                    f"elements={elems:.0f}"))
        csv.append((f"ao_query_{ao_name}", qry * 1e6,
                    f"merit={merit:.4f}"))


def _sec_tree(report, csv, args):
    trep = tree.run()
    report["tree"] = trep
    rows = [
        ("hoeffding_tree_update", 1e6 / trep["kernel"]["instances_per_s"],
         f"mse_ratio={trep['kernel']['mse_ratio']:.4f}"
         f" speedup_vs_oracle={trep['kernel_speedup_vs_oracle']:.3f}"
         f" mse_rel_diff={trep['mse_rel_diff_vs_oracle']:.5f}"),
        ("hoeffding_tree_update_oracle",
         1e6 / trep["oracle"]["instances_per_s"],
         f"mse_ratio={trep['oracle']['mse_ratio']:.4f}"),
    ]
    csv.extend(rows)
    write_bench("BENCH_tree.json", rows)


def _sec_forest(report, csv, args):
    frep = forest.run()
    report["forest"] = frep
    preq = frep["prequential"]
    rows = [
        ("forest_update_vmapped",
         1e6 / frep["vmapped"]["instances_per_s"],
         f"T={frep['n_trees']}"
         f" speedup_vs_loop={frep['speedup_vs_loop']:.3f}"),
        ("forest_update_loop", 1e6 / frep["loop"]["instances_per_s"],
         f"T={frep['n_trees']} per-tree python loop baseline"),
        # accuracy-only row: us_per_call deliberately 0 so the timing is
        # not double-counted with the forest_update_vmapped row above
        ("forest_prequential_drift", 0.0,
         f"forest_mse={preq['forest_mse']:.3f}"
         f" best_member_mse={preq['best_member_mse']:.3f}"
         f" beats_best_member={preq['forest_beats_best_member']}"
         f" drift_resets={preq['drift_resets']}"),
    ]
    csv.extend(rows)
    write_bench("BENCH_forest.json", rows)


def _sec_serve(report, csv, args):
    srep = serve.run()
    report["serve"] = srep
    rows = serve.to_rows(srep)
    csv.extend(rows)
    write_bench("BENCH_serve.json", rows)


def _sec_engine(report, csv, args):
    erep = engine.run()
    report["engine"] = erep
    rows = engine.to_rows(erep)
    csv.extend(rows)
    write_bench("BENCH_engine.json", rows)


def _sec_dp(report, csv, args):
    # own subprocess for the forced-host-device XLA flags (§4.1)
    drep = dp.run()
    report["dp"] = drep
    rows = dp.to_rows(drep)
    csv.extend(rows)
    write_bench("BENCH_dp.json", rows)


def _sec_splits(report, csv, args):
    fsrep = false_splits.run()
    report["false_splits"] = fsrep
    rows = false_splits.to_rows(fsrep)
    csv.extend(rows)
    write_bench("BENCH_splits.json", rows)


def _sec_sketch(report, csv, args):
    skrep = sketch_bench.run()
    report["sketch"] = skrep
    rows = sketch_bench.to_rows(skrep)
    csv.extend(rows)
    write_bench("BENCH_sketch.json", rows)


def _profiled_kernels(report):
    """Per-op compiled-cost harvest + a BOUNDED profiler trace (one
    dispatch per family): the ``--profile`` artifacts (gitignored).
    The trace deliberately does NOT wrap the bench run itself — the
    profiler buffers every event in host memory, and minutes of
    tuner-race dispatches are an OOM, not a trace."""
    from repro.kernels import ops as kops
    from repro.perf import profile as pprof
    from repro.perf.tune import make_workloads

    w = make_workloads()
    backend = kops.resolve_backend(None)
    named = {
        "forest_update": (
            lambda *a: kops.forest_update(*a, backend=backend), w["update"]),
        "forest_best_splits": (
            lambda *a: kops.forest_best_splits(*a, backend=backend),
            w["query"]),
        "forest_route": (
            lambda *a: kops.forest_route(*a, depth=w["depth"],
                                         backend=backend), w["route"]),
        "forest_merge": (
            lambda *a: kops.forest_merge(*a, backend=backend), w["merge"]),
    }
    costs = pprof.profile_ops(
        named, logdir=os.path.join(REPO_ROOT, "profile_trace"))
    report["profile"] = costs
    pprof.write_report(costs, os.path.join(REPO_ROOT,
                                           "BENCH_profile.fresh.json"))
    return kernels.run()


def _sec_kernels(report, csv, args):
    krep = _profiled_kernels(report) if args.profile else kernels.run()
    report["kernels"] = krep
    rows = kernels.to_rows(krep)
    csv.extend(rows)
    write_bench("BENCH_kernels.json", rows)


def _sec_query(report, csv, args):
    qrep = query_sweep.run()
    report["query_sweep"] = qrep
    rows = query_sweep.to_rows(qrep)
    csv.extend(rows)
    write_bench("BENCH_query.json", rows)


def _sec_roofline(report, csv, args):
    rrep = roofline.run()
    report["roofline"] = rrep
    rows = roofline.to_rows(rrep)
    csv.extend(rows)
    write_bench("BENCH_roofline.json", rows)


SECTIONS = {
    "aos": _sec_aos,
    "tree": _sec_tree,
    "forest": _sec_forest,
    "serve": _sec_serve,
    "engine": _sec_engine,
    "dp": _sec_dp,
    "splits": _sec_splits,
    "sketch": _sec_sketch,
    "kernels": _sec_kernels,
    "query": _sec_query,
    "roofline": _sec_roofline,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="full paper grid (sizes to 50k, 10 seeds)")
    ap.add_argument("--skip-aos", action="store_true")
    ap.add_argument("--only", nargs="+", choices=sorted(SECTIONS),
                    default=None, help="run only these sections")
    ap.add_argument("--profile", action="store_true",
                    help="bounded profiler trace (one dispatch per kernel "
                         "family) + per-op compiled costs")
    args = ap.parse_args()

    names = args.only or list(SECTIONS)
    if args.skip_aos and "aos" in names:
        names.remove("aos")

    report = {}
    csv = []
    for name in names:
        SECTIONS[name](report, csv, args)

    out_path = os.path.join(os.path.dirname(__file__), "report.json")
    with open(out_path, "w") as f:
        json.dump(report, f, indent=1, default=float)

    print("name,us_per_call,derived")
    for name, us, derived in csv:
        print(f"{name},{us:.3f},{derived}")


if __name__ == "__main__":
    main()
