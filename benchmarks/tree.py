"""Tree-level benchmark: Hoeffding tree with QO observers vs baselines.

The paper (§7) leaves "QO inside Hoeffding trees" as future work — we
implement it: an online HT regressor with vectorized QO observers, compared
against the mean predictor and a batch-oracle piecewise fit on the paper's
synthetic protocol + a multivariate piecewise task."""
from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hoeffding as ht
from repro.data import synth


def run(n=20000, n_features=4, bs=256, out=None):
    X, y = synth.piecewise_regression(n, n_features=n_features, seed=11)
    Xt, yt = synth.piecewise_regression(4000, n_features=n_features, seed=101)
    cfg = ht.HTRConfig(n_features=n_features, max_nodes=63, n_bins=48,
                       grace_period=300, max_depth=8, r0=0.25)
    state = ht.init_state(cfg)
    upd = jax.jit(functools.partial(ht.update, cfg))
    state = upd(state, jnp.array(X[:bs]), jnp.array(y[:bs]))  # compile
    jax.block_until_ready(state["n_nodes"])
    state = ht.init_state(cfg)
    t0 = time.perf_counter()
    for i in range(0, n - bs + 1, bs):
        state = upd(state, jnp.array(X[i:i + bs]), jnp.array(y[i:i + bs]))
    jax.block_until_ready(state["n_nodes"])
    train_t = time.perf_counter() - t0

    pred = jax.jit(functools.partial(ht.predict, cfg))
    yhat = np.asarray(pred(state, jnp.array(Xt)))
    mse_tree = float(np.mean((yhat - yt) ** 2))
    mse_mean = float(np.var(yt))
    report = {
        "instances": n,
        "train_s": train_t,
        "instances_per_s": n / train_t,
        "n_nodes": int(state["n_nodes"]),
        "n_leaves": int(ht.n_leaves(state)),
        "mse_tree": mse_tree,
        "mse_mean_predictor": mse_mean,
        "mse_ratio": mse_tree / mse_mean,
    }
    return report
