"""Tree-level benchmark: the batched-QO kernel pipeline vs the jnp oracle.

The paper (§7) leaves "QO inside Hoeffding trees" as future work — we
implement it and race the two engines head to head on the paper's
synthetic protocol:

* ``kernel`` — ``split_backend="auto"``: the forest-scale QO pipeline
  (compiled Pallas kernels on TPU, the fused-jnp lowering elsewhere);
* ``oracle`` — ``split_backend="oracle"``: the seed's per-stat
  segment-scatter absorb + per-table scan query (the correctness
  reference).

Both paths run the identical driver (same batches, same trial protocol,
median wall time of ``trials`` runs) so the reported speedup isolates the
absorb/attempt engines."""
from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hoeffding as ht
from repro.data import synth


def _train_once(upd, cfg, batches):
    state = ht.init_state(cfg)
    t0 = time.perf_counter()
    for xb, yb in batches:
        state = upd(state, xb, yb)
    jax.block_until_ready(state["n_nodes"])
    return state, time.perf_counter() - t0


def run(n=20000, n_features=4, bs=256, trials=5, out=None):
    X, y = synth.piecewise_regression(n, n_features=n_features, seed=11)
    Xt, yt = synth.piecewise_regression(4000, n_features=n_features, seed=101)
    batches = [(jnp.array(X[i:i + bs]), jnp.array(y[i:i + bs]))
               for i in range(0, n - bs + 1, bs)]
    n_seen = len(batches) * bs
    base_mse = float(np.var(yt))

    engines = {}
    for name, backend in (("kernel", "auto"), ("oracle", "oracle")):
        cfg = ht.HTRConfig(n_features=n_features, max_nodes=63, n_bins=48,
                           grace_period=300, max_depth=8, r0=0.25,
                           split_backend=backend)
        upd = jax.jit(functools.partial(ht.update, cfg))
        s = upd(ht.init_state(cfg), *batches[0])               # compile
        jax.block_until_ready(s["n_nodes"])
        engines[name] = (cfg, upd, [])

    # interleave trials so machine-load drift hits both engines equally
    states = {}
    for _ in range(trials):
        for name, (cfg, upd, times) in engines.items():
            states[name], dt = _train_once(upd, cfg, batches)
            times.append(dt)

    report = {"instances": n_seen, "batch_size": bs, "trials": trials}
    for name, (cfg, upd, times) in engines.items():
        state = states[name]
        train_t = float(np.median(times))
        pred = jax.jit(functools.partial(ht.predict, cfg))
        yhat = np.asarray(pred(state, jnp.array(Xt)))
        mse = float(np.mean((yhat - yt) ** 2))
        report[name] = {
            "train_s": train_t,
            "train_s_best": float(np.min(times)),
            "instances_per_s": n_seen / train_t,
            "us_per_batch": train_t / len(batches) * 1e6,
            "n_nodes": int(state["n_nodes"]),
            "n_leaves": int(ht.n_leaves(state)),
            "mse_tree": mse,
            "mse_mean_predictor": base_mse,
            "mse_ratio": mse / base_mse,
        }

    k, o = report["kernel"], report["oracle"]
    report["kernel_speedup_vs_oracle"] = o["train_s"] / k["train_s"]
    report["mse_rel_diff_vs_oracle"] = \
        abs(k["mse_tree"] - o["mse_tree"]) / max(o["mse_tree"], 1e-12)
    # backwards-compatible top-level fields (the kernel path is the product)
    report.update({kk: k[kk] for kk in
                   ("train_s", "instances_per_s", "n_nodes", "n_leaves",
                    "mse_tree", "mse_mean_predictor", "mse_ratio")})
    return report
