"""Data-parallel stream scale-out benchmark (DESIGN.md §4.1) -> BENCH_dp.json.

Races the SAME global stream through ``build_data_parallel_forest`` on a
1-device and a 4-device mesh — same config, same batches, same sync
cadence, measured INTERLEAVED in the same run with a per-side best-of
(the repo's standard load-noise armor) — and reports amortized
per-instance throughput of whole sync windows (``update_window``: S
local batches in one dispatch + the merge collective).

D devices are forced host-platform devices, so the run must own its
``XLA_FLAGS`` before JAX initializes: :func:`run` spawns a worker
subprocess (the test_sharding.py idiom).

**Devices own their cores.**  Real accelerator devices do not share
each other's compute, but forced host devices all draw on one XLA CPU
thread pool — unpinned, the D = 1 baseline silently spreads across
every host core and the race measures the shared pool, not the
protocol.  The worker therefore pins CPU affinity per round (every
``/proc/self/task`` tid): the D = 1 baseline takes its best round over
EACH core separately (shared hosts steal cores asymmetrically; racing
it on a fixed core would let a noisy neighbor inflate the ratio), the
D-shard meshes run on ``min(D, cpu_count)`` cores.

**Read the ratio against the same-run host ceiling.**  The nominal
``speedup_vs_D1`` ceiling is ``min(D, cpu_count)``, but shared-host
MEMORY bandwidth caps it first: on this container two fully independent
single-core copies of the same program aggregate only ~1.2-1.35x one
copy, so no data-parallel execution of this workload can beat that
here, whatever the protocol costs.  The worker therefore also races a
D = 2 mesh — two shards, two cores, no oversubscription — as the
measured same-run ceiling proxy, and reports D4's ``ceiling_frac =
speedup_D4 / speedup_D2``: how much of the host's attainable scaling
the 4-shard protocol captures (observed ~0.8-1.0; the remaining gap is
4-on-2 oversubscription plus the per-shard table-sized delta work —
the wall ratio itself is hardware-bound).  On >= 4 real cores or
devices with commensurate bandwidth the same program has the full 4x
of headroom.  A microbench of the sync's merge op (``ops.forest_merge``
over the forest's folded T·M table axis) rides along.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time

D = 4
T, M, F, C = 4, 63, 8, 64
BATCH = 16384        # global rows per local step (BATCH/D per shard)
SYNC_EVERY = 8       # local steps per sync window
ROUNDS, REPS = 5, 1  # interleaved best-of: ROUNDS x (REPS windows/side)


def _pin_all_threads(cpus) -> None:
    """Set CPU affinity of EVERY thread in this process (XLA's pool
    threads already exist by measurement time, so pinning only the
    caller would leave them roaming).  No-op off Linux (no /proc, no
    sched_setaffinity): the race still runs, it just measures the
    shared-pool behavior the docstring warns about."""
    if not hasattr(os, "sched_setaffinity") or not os.path.isdir(
            "/proc/self/task"):
        return
    for tid in os.listdir("/proc/self/task"):
        try:
            os.sched_setaffinity(int(tid), cpus)
        except OSError:  # thread exited between listdir and the call
            pass


def _worker() -> None:
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import forest as fr
    from repro.core import hoeffding as ht
    from repro.data import synth
    from repro.launch.mesh import make_mesh_auto
    from repro.train import sharding as sh

    tree = ht.HTRConfig(n_features=F, max_nodes=M, n_bins=C,
                        grace_period=200, max_depth=8, r0=0.25)
    cfg = fr.ForestConfig(tree=tree, n_trees=T)
    X, y = synth.piecewise_regression(SYNC_EVERY * BATCH, n_features=F,
                                      seed=17)
    Xw = jnp.asarray(X).reshape(SYNC_EVERY, BATCH, F)
    yw = jnp.asarray(y).reshape(SYNC_EVERY, BATCH)

    meshes = (1, 2, D)
    dp, st = {}, {}
    for d in meshes:
        mesh = make_mesh_auto((d,), ("data",))
        dp[d] = sh.build_data_parallel_forest(cfg, mesh, "data",
                                              sync_every=SYNC_EVERY)
        s = dp[d].init(jax.random.PRNGKey(0))
        s, _ = dp[d].update_window(s, Xw, yw)        # warmup (compiles)
        jax.block_until_ready(s["forest"]["trees"]["ystats"]["n"])
        st[d] = s

    def window(d):
        s = st[d]
        t0 = time.perf_counter()
        for _ in range(REPS):
            s, _ = dp[d].update_window(s, Xw, yw)
        jax.block_until_ready(s["forest"]["trees"]["ystats"]["n"])
        st[d] = s
        return (time.perf_counter() - t0) / REPS

    # devices own their cores: the D=1 baseline races on EACH core
    # (best-of — asymmetric neighbor steal must not pick its core for
    # it), sharded meshes on min(D, nproc) cores
    n_cores = os.cpu_count() or 1
    wide = set(range(min(D, n_cores)))
    best = {d: float("inf") for d in meshes}
    try:
        for _ in range(ROUNDS):                      # interleaved race
            for core in sorted(wide):
                _pin_all_threads({core})
                best[1] = min(best[1], window(1))
            for d in meshes[1:]:
                _pin_all_threads(wide)
                best[d] = min(best[d], window(d))
    finally:
        _pin_all_threads(set(range(n_cores)))

    rows = SYNC_EVERY * BATCH
    rep = {
        str(d): {"us_per_instance": best[d] / rows * 1e6,
                 "instances_per_s": rows / best[d],
                 "n_nodes": int(np.asarray(
                     st[d]["forest"]["trees"]["n_nodes"]).max())}
        for d in meshes
    }
    print(json.dumps({
        "D1": rep["1"], "D2": rep["2"], "D4": rep[str(D)],
        "speedup_vs_D1": best[1] / best[D],
        "ceiling_speedup_D2": best[1] / best[2],
        "ceiling_frac": best[2] / best[D],
        "n_cores": n_cores,
        "config": {"T": T, "M": M, "F": F, "C": C, "batch": BATCH,
                   "sync_every": SYNC_EVERY, "shards": D},
    }))


def _merge_microbench():
    """us/call of the §4.1 merge op over the folded T·M table axis."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.kernels import ops

    rng = np.random.default_rng(0)
    mk = lambda: ({"n": jnp.asarray(rng.integers(0, 9, (T * M, F, C))
                                    .astype(np.float32)),
                   "mean": jnp.asarray(rng.normal(size=(T * M, F, C))
                                       .astype(np.float32)),
                   "m2": jnp.abs(jnp.asarray(rng.normal(size=(T * M, F, C))
                                             .astype(np.float32)))},
                  jnp.asarray(rng.normal(size=(T * M, F, C))
                              .astype(np.float32)))
    a, b = mk(), mk()
    out = ops.forest_merge(*a, *b)                    # warm the cached jit
    jax.block_until_ready(out[1])
    reps = 50
    t0 = time.perf_counter()
    for _ in range(reps):
        out = ops.forest_merge(*a, *b)
    jax.block_until_ready(out[1])
    return (time.perf_counter() - t0) / reps * 1e6


def run() -> dict:
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={D}")
    out = subprocess.run(
        [sys.executable, "-m", "benchmarks.dp", "--worker"],
        capture_output=True, text=True, env=env, timeout=3000)
    if out.returncode != 0:
        raise RuntimeError(f"dp bench worker failed:\n{out.stderr[-3000:]}")
    rep = json.loads(out.stdout.strip().splitlines()[-1])
    rep["merge_us_per_call"] = _merge_microbench()
    return rep


def to_rows(rep: dict):
    c = rep["config"]
    tag = f"T={c['T']} B={c['batch']} sync_every={c['sync_every']}"
    cores = rep.get("n_cores")
    return [
        ("dp_update_D1", rep["D1"]["us_per_instance"],
         f"{tag} single-device baseline (same run, best single core)"),
        ("dp_update_D2", rep["D2"]["us_per_instance"],
         f"{tag} speedup_vs_D1={rep['ceiling_speedup_D2']:.3f} — the "
         f"same-run host-parallelism ceiling proxy (2 shards, 2 cores)"),
        (f"dp_update_D{c['shards']}", rep["D4"]["us_per_instance"],
         f"{tag} speedup_vs_D1={rep['speedup_vs_D1']:.3f} "
         f"ceiling_frac={rep['ceiling_frac']:.3f} (devices-own-cores "
         f"race on {cores} cores; see docs/benchmarks.md)"),
        ("dp_forest_merge", rep["merge_us_per_call"],
         f"N={c['T'] * c['M']} tables F={c['F']} C={c['C']} "
         f"(the sync's folded-axis Chan merge, ops.forest_merge)"),
    ]


if __name__ == "__main__":
    if "--worker" in sys.argv:
        _worker()
    else:
        print(json.dumps(run(), indent=1))
