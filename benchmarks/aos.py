"""Paper experiment reproduction: AO comparison (Figs. 1-6, Table 1 grid).

Compared observers (paper §5.2):
  E-BST, TE-BST(3 decimals),
  QO_0.01 (fixed radius), QO_{sigma/2}, QO_{sigma/3}.

Metrics (paper §5.3): split merit (VR), #stored elements, observation
time, query time.  Plus Fig. 3's split-point deviation vs E-BST and a
Friedman significance test over (size x distribution x task) blocks.

CPU-container scaling: sizes are capped (default <= 25k; paper goes to
1e6) and repetitions reduced; pass --full for the complete grid.
"""
from __future__ import annotations

import itertools
import json
import time
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ebst, qo
from repro.data import synth

QO_VARIANTS = ("qo_0.01", "qo_s2", "qo_s3")
AOS = ("ebst", "tebst") + QO_VARIANTS


def _make_qo(variant, x, cap=2048):
    sigma = float(np.std(x)) or 1.0
    mu = float(np.mean(x))
    if variant == "qo_0.01":
        # paper's fixed cold-start radius.  The paper's hash grows
        # unboundedly; our dense table must COVER the data span, so size
        # the capacity to the sample range (memory is still measured as
        # OCCUPIED slots, keeping the comparison fair).
        span = float(np.max(x) - np.min(x)) + 1e-6
        need = int(span / 0.01) + 2
        cap = max(cap, 1 << (need - 1).bit_length())
        return qo.init(cap, radius=0.01, origin=mu)
    k = 2.0 if variant == "qo_s2" else 3.0
    return qo.init(cap, radius=sigma / k, origin=mu)


def run_ao(name, x, y):
    """Returns dict(metrics) for one AO on one sample."""
    n = len(x)
    xj, yj = jnp.array(x), jnp.array(y)
    if name in ("ebst", "tebst"):
        t = ebst.init(n, decimals=3 if name == "tebst" else -1)
        upd = jax.jit(ebst.update)
        t = upd(t, xj, yj)  # warm compile
        jax.block_until_ready(t["size"])
        t = ebst.init(n, decimals=3 if name == "tebst" else -1)
        t0 = time.perf_counter()
        t = upd(t, xj, yj)
        jax.block_until_ready(t["size"])
        obs_t = time.perf_counter() - t0
        q = jax.jit(ebst.best_split)
        r = q(t); jax.block_until_ready(r.merit)
        t0 = time.perf_counter()
        r = q(t); jax.block_until_ready(r.merit)
        query_t = time.perf_counter() - t0
        elements = int(t["size"])
    else:
        t = _make_qo(name, x)
        upd = jax.jit(qo.update)
        t2 = upd(t, xj, yj); jax.block_until_ready(t2["sum_x"])
        t0 = time.perf_counter()
        t2 = upd(t, xj, yj); jax.block_until_ready(t2["sum_x"])
        obs_t = time.perf_counter() - t0
        q = jax.jit(qo.best_split)
        r = q(t2); jax.block_until_ready(r.merit)
        t0 = time.perf_counter()
        r = q(t2); jax.block_until_ready(r.merit)
        query_t = time.perf_counter() - t0
        elements = int(qo.n_slots(t2))
        t = t2
    return {
        "merit": float(r.merit), "threshold": float(r.threshold),
        "elements": elements, "observe_s": obs_t, "query_s": query_t,
    }


def grid(sizes, seeds, dists=("normal", "uniform", "bimodal"),
         variants=(0, 1, 2), tasks=("lin", "cub"), noises=(0.0, 0.1)):
    rows = []
    for size, dist, var, task, noise, seed in itertools.product(
            sizes, dists, variants, tasks, noises, seeds):
        cfg = synth.SynthConfig(dist=dist, variant=var, task=task,
                                noise_frac=noise, n=size, seed=seed)
        x, y = synth.generate(cfg)
        row_key = dict(size=size, dist=dist, variant=var, task=task,
                       noise=noise, seed=seed)
        for ao in AOS:
            m = run_ao(ao, x, y)
            rows.append({**row_key, "ao": ao, **m})
    return rows


def friedman_ranks(rows, metric, lower_better=True):
    """Friedman test over blocks = (size, dist, variant, task, noise, seed)."""
    from scipy import stats as sps
    blocks = {}
    for r in rows:
        k = (r["size"], r["dist"], r["variant"], r["task"], r["noise"], r["seed"])
        blocks.setdefault(k, {})[r["ao"]] = r[metric]
    per_ao = {ao: [] for ao in AOS}
    mat = []
    for k, vals in blocks.items():
        if len(vals) != len(AOS):
            continue
        mat.append([vals[ao] for ao in AOS])
    mat = np.array(mat)
    if not lower_better:
        mat = -mat
    ranks = np.apply_along_axis(sps.rankdata, 1, mat)
    stat, p = sps.friedmanchisquare(*[mat[:, i] for i in range(len(AOS))])
    return {ao: float(ranks[:, i].mean()) for i, ao in enumerate(AOS)}, \
        float(stat), float(p)


def split_deviation_vs_ebst(rows):
    """Fig. 3: |threshold_AO - threshold_EBST| averaged per AO."""
    blocks = {}
    for r in rows:
        k = (r["size"], r["dist"], r["variant"], r["task"], r["noise"], r["seed"])
        blocks.setdefault(k, {})[r["ao"]] = r["threshold"]
    dev = {ao: [] for ao in AOS if ao != "ebst"}
    for vals in blocks.values():
        if "ebst" not in vals:
            continue
        for ao in dev:
            if ao in vals:
                dev[ao].append(abs(vals[ao] - vals["ebst"]))
    return {ao: float(np.mean(v)) for ao, v in dev.items() if v}


def run(full=False, out=None):
    sizes = ([50, 200, 1000, 5000] if not full
             else synth.SAMPLE_SIZES[:14])
    seeds = range(2) if not full else range(10)
    rows = grid(sizes, seeds,
                dists=("normal", "bimodal") if not full
                else ("normal", "uniform", "bimodal"),
                variants=(0, 2) if not full else (0, 1, 2),
                tasks=("lin", "cub"),
                noises=(0.0, 0.1) if full else (0.0,))
    report = {"rows": rows}
    for metric, lower in (("merit", False), ("elements", True),
                          ("observe_s", True), ("query_s", True)):
        ranks, stat, p = friedman_ranks(rows, metric, lower_better=lower)
        report[f"friedman_{metric}"] = {
            "mean_ranks": ranks, "chi2": stat, "p": p}
    report["split_deviation_vs_ebst"] = split_deviation_vs_ebst(rows)
    if out:
        with open(out, "w") as f:
            json.dump(report, f, indent=1)
    return report
