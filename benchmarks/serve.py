"""Serving benchmark: the fused inference engine vs the seed read path.

Three same-run races on trained models (equality-gated — every fused
path must reproduce the scalar oracle's predictions BIT-identically
before any clock starts), interleaved best-of-``trials`` so machine-load
drift hits both sides of each race equally:

* **tree route** — the §2.6 batched routing dispatch
  (``hoeffding._route``: cached jits, realized-depth ply bucket, one
  packed-row gather per ply) vs the seed's jitted vmap-of-scalar
  ``fori_loop`` walk over ``cfg.max_depth`` (``kernels.ref.route_ref``
  — the seed cannot trim: its ply count is baked into the jit);
* **forest predict** — the fused live read path (``forest.predict``:
  ONE folded-axis route for all T members + carried vote weights) vs
  the per-tree baseline the seed served (vmapped scalar member routes +
  vote weights re-derived per call);
* **snapshot predict** — ``serve.predict_snapshot`` on the frozen
  breadth-first snapshot vs the fused live-state predict it was frozen
  from (what the §5.5 trim + pre-gather buy on top of fused routing).

Acceptance (ISSUE 4): fused forest predict >= 3x the per-tree baseline
at T = 16; fused tree routing >= 2x the scalar walk.
"""
from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import forest as fr
from repro.core import hoeffding as ht
from repro.core import serve as sv
from repro.kernels import ops as kops
from repro.kernels import ref as kref


def _time(f, *args, iters=20):
    jax.block_until_ready(f(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        r = f(*args)
    jax.block_until_ready(r)
    return (time.perf_counter() - t0) / iters


def _race(fast, slow, trials):
    """Interleaved best-of-``trials`` of two thunks -> (t_fast, t_slow)."""
    tf, ts = [], []
    for _ in range(trials):
        tf.append(_time(*fast))
        ts.append(_time(*slow))
    return float(np.min(tf)), float(np.min(ts))


def plateau_stream(n: int, n_features: int = 8, levels: int = 5,
                   seed: int = 11, noise: float = 0.1):
    """Balanced plateau concept: y is set by the sign pattern of the
    first ``levels`` features — the generating tree is COMPLETE at depth
    ``levels`` (2^levels plateaus), so a capacity-63 Hoeffding tree
    realizes a shallow, balanced shape far below ``cfg.max_depth``.
    That gap is exactly what the serving engine exploits (realized-depth
    ply trim) and what the seed's scalar walk, jitted with
    ``max_depth + 1`` plies baked in, cannot."""
    rng = np.random.default_rng(seed)
    X = rng.normal(0, 1, (n, n_features)).astype(np.float32)
    bits = (X[:, :levels] > 0) @ (2.0 ** np.arange(levels))
    y = (bits + noise * rng.normal(0, 1, n)).astype(np.float32)
    return X, y


def run(n=12288, n_features=8, n_trees=16, B=8192, trials=5):
    tcfg = ht.HTRConfig(n_features=n_features, max_nodes=63, n_bins=48,
                        grace_period=300, max_depth=12, r0=0.25)
    X, y = plateau_stream(n, n_features=n_features, seed=11)
    Xq = jnp.array(np.random.default_rng(5).normal(
        0, 1, (B, n_features)).astype(np.float32))   # the request batch

    # --- train once: a single tree and a T-member forest ------------------
    tstate = ht.update_stream(tcfg, ht.init_state(tcfg),
                              jnp.array(X), jnp.array(y))
    # full subspaces: the members' realized depth reflects the concept,
    # not random feature masking (subspace diversity is a learning knob,
    # orthogonal to the read path this benchmark measures)
    fcfg = fr.ForestConfig(tree=tcfg, n_trees=n_trees, subspace=1.0)
    fstate = fr.init_forest(fcfg, jax.random.PRNGKey(0))
    fstate, _ = fr.update_stream(fcfg, fstate, jnp.array(X), jnp.array(y))
    jax.block_until_ready(fstate["trees"]["n_nodes"])
    realized = int(fstate["trees"]["depth"].max())

    # --- race 1: fused tree route vs the seed's scalar walk ---------------
    # the engine's serving contract: realized depth is probed once per
    # model refresh (it is static metadata, baked into snapshots) and the
    # per-request dispatch is one cached-jit call; the seed's walk is
    # jitted once with max_depth + 1 plies baked in (it cannot trim)
    tree_depth = min(tcfg.max_depth, int(tstate["depth"].max()))
    fused_route = functools.partial(
        kops.route, tstate["feature"], tstate["threshold"],
        tstate["child"], tstate["is_leaf"], depth=tree_depth)
    scalar_route = jax.jit(functools.partial(
        kref.route_ref, tstate["feature"], tstate["threshold"],
        tstate["child"], tstate["is_leaf"], max_depth=tcfg.max_depth))
    np.testing.assert_array_equal(np.asarray(fused_route(Xq)),
                                  np.asarray(scalar_route(Xq)))
    t_route, t_scalar = _race((fused_route, Xq), (scalar_route, Xq), trials)

    # --- race 2: fused forest predict vs the per-tree vmap baseline -------
    ocfg = fr.ForestConfig(
        tree=ht.HTRConfig(n_features=n_features, max_nodes=63, n_bins=48,
                          grace_period=300, max_depth=12, r0=0.25,
                          split_backend="oracle"), n_trees=n_trees)

    def _pertree_predict(state, Xb):
        # the pre-engine read path: T vmapped scalar walks + vote weights
        # re-derived from the error windows on every call
        yhat = jax.vmap(functools.partial(ht.predict, ocfg.tree),
                        in_axes=(0, None))(state["trees"], Xb)
        return fr._vote_combine(yhat, fr.vote_weights(ocfg, state), None)

    pertree = jax.jit(_pertree_predict)
    fused = functools.partial(fr.predict, fcfg, fstate)
    np.testing.assert_array_equal(np.asarray(fused(Xq)),
                                  np.asarray(pertree(fstate, Xq)))
    t_fused, t_pertree = _race((fused, Xq), (pertree, fstate, Xq), trials)

    # --- race 3: frozen snapshot vs the fused live state ------------------
    snap = sv.freeze(fstate)
    snap_pred = functools.partial(sv.predict_snapshot, snap)
    np.testing.assert_array_equal(np.asarray(snap_pred(Xq)),
                                  np.asarray(fused(Xq)))
    t_snap, t_live = _race((snap_pred, Xq), (fused, Xq), trials)

    return {
        "B": B, "n_trees": n_trees, "trials": trials,
        "max_depth": tcfg.max_depth, "realized_depth": realized,
        "snapshot_nodes": int(snap.feature.shape[1]),
        "snapshot_depth": snap.depth,
        "tree_route": {
            "fused_us": t_route * 1e6, "scalar_us": t_scalar * 1e6,
            "rows_per_s": B / t_route,
            "speedup_vs_scalar": t_scalar / t_route},
        "forest_predict": {
            "fused_us": t_fused * 1e6, "pertree_us": t_pertree * 1e6,
            "rows_per_s": B / t_fused,
            "speedup_vs_pertree": t_pertree / t_fused},
        "snapshot_predict": {
            "snapshot_us": t_snap * 1e6, "live_us": t_live * 1e6,
            "rows_per_s": B / t_snap,
            "speedup_vs_live": t_live / t_snap},
    }


def to_rows(report):
    """BENCH_serve.json rows (name, us_per_call, derived)."""
    tr, fp, sp = (report["tree_route"], report["forest_predict"],
                  report["snapshot_predict"])
    B = report["B"]
    return [
        ("serve_tree_route_fused", tr["fused_us"],
         f"B={B} rows_per_s={tr['rows_per_s']:.0f}"
         f" speedup_vs_scalar={tr['speedup_vs_scalar']:.2f}"),
        ("serve_tree_route_scalar", tr["scalar_us"],
         f"B={B} seed vmap-of-fori walk, max_depth={report['max_depth']}"),
        ("serve_forest_predict_fused", fp["fused_us"],
         f"B={B} T={report['n_trees']} rows_per_s={fp['rows_per_s']:.0f}"
         f" speedup_vs_pertree={fp['speedup_vs_pertree']:.2f}"),
        ("serve_forest_predict_pertree", fp["pertree_us"],
         f"B={B} T={report['n_trees']} per-tree vmap baseline"),
        ("serve_snapshot_predict", sp["snapshot_us"],
         f"B={B} nodes={report['snapshot_nodes']}"
         f" depth={report['snapshot_depth']}"
         f" speedup_vs_live={sp['speedup_vs_live']:.2f}"),
    ]
