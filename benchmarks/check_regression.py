"""Bench-regression gate: ``PYTHONPATH=src python -m benchmarks.check_regression``.

Reruns the kernel micro-benches, the attempt-fraction query sweep, the
serving races and the serving-engine bench (best-of-2) and applies two
kinds of check:

* **absolute band** — each row's ``us_per_call`` must stay within
  ``TOLERANCE`` (3x) of the committed ``BENCH_kernels.json`` /
  ``BENCH_query.json`` / ``BENCH_serve.json`` baselines.  Deliberately
  wide: shared CI runners and the dev sandbox swing 2-3x with load (and
  differ from the machine that committed the baselines), so this only
  catches order-of-magnitude breakage.  Rows without a committed
  baseline and accuracy-only rows (``us_per_call == 0``) are reported
  but never fail.
* **statistical gates** — the split-decision validity suite
  (:mod:`benchmarks.false_splits`, fixed seeds, so these are exact
  reproductions, not noisy timings): the anytime backend's false-split
  rate on no-signal streams must stay ≤ its configured α while the
  Hoeffding backend's must still exceed it (the §2.7 premise), and the
  anytime drift-suite prequential MSE must stay within
  ``false_splits.MAX_MSE_RATIO`` of the Hoeffding backend's; and the
  sketch-observer suite (:mod:`benchmarks.sketch`, fixed seeds): every
  gated stream's first split within the §2.8 ε-rank/merit bounds and
  the ≥10x equivalent-capacity floor.

* **roofline floors** — the analytic achieved-vs-attainable fraction
  from :mod:`benchmarks.roofline` must stay above a per-family floor for
  ``forest_update`` and ``forest_route``.  Both the attainable bound
  (device peaks) and the measured time come from the SAME run, so the
  fraction is machine- and load-independent where a wall-time band is
  not: a loaded runner slows the peak probes and the kernels together.
  The floors sit ~5x under the healthy fractions measured at commit
  time — they trip on order-of-magnitude dispatch breakage (eager
  fallback, per-call retraces), not on host variance.

* **structural ratios** — machine-independent, measured inside ONE run:

  - at small attempt fractions (K/M <= 1/8) on forests of
    M >= ``MIN_GATED_M`` tables, the compacted query must beat the
    same-run full scan by ``MIN_SPEEDUP`` (1.5x) — catches compaction
    silently degrading to the full scan;
  - the fused forest predict must at least MATCH the same-run per-tree
    vmap baseline (``MIN_SERVE_SPEEDUP``, 1.0x) — catches the serving
    engine silently degrading below the path it replaced (the committed
    BENCH_serve.json acceptance bar is 3x; the CI floor is intentionally
    looser so runner load cannot flake the gate, while a true fallback
    to per-tree routing — ratio ~= 1 with noise both sides — still
    trips it);
  - the serving engine's full admission path must keep
    ``MIN_ENGINE_FRAC`` (0.8x) of the same-run bare
    ``serve.predict_snapshot`` throughput at the same batch bucket —
    catches the queue/accounting layer creeping onto the hot path.

  Small-M query cells are reported but ungated: their fixed O(M*F)
  gather/scatter overheads sit too close to the query itself for a
  load-stable ratio.

The fresh sweeps are written to ``BENCH_query.fresh.json`` /
``BENCH_serve.fresh.json`` / ``BENCH_engine.fresh.json`` (the CI
artifacts), NEVER to the committed
baselines — only ``benchmarks.run`` rewrites baselines, so running the
gate locally can never silently shift what future runs are compared
against.  Exit code 1 on any failure.
"""
from __future__ import annotations

import json
import os
import sys

from benchmarks import engine as engine_bench
from benchmarks import (false_splits, kernels, query_sweep, roofline,
                        serve)
from benchmarks import sketch as sketch_bench
from benchmarks.bench_io import REPO_ROOT, write_bench

BASELINES = ("BENCH_kernels.json", "BENCH_query.json", "BENCH_serve.json",
             "BENCH_engine.json", "BENCH_splits.json",
             "BENCH_sketch.json", "BENCH_roofline.json")
FRESH_ARTIFACT = "BENCH_query.fresh.json"
SERVE_FRESH_ARTIFACT = "BENCH_serve.fresh.json"
ENGINE_FRESH_ARTIFACT = "BENCH_engine.fresh.json"
SPLITS_FRESH_ARTIFACT = "BENCH_splits.fresh.json"
SKETCH_FRESH_ARTIFACT = "BENCH_sketch.fresh.json"
ROOFLINE_FRESH_ARTIFACT = "BENCH_roofline.fresh.json"
TOLERANCE = 3.0
MIN_SPEEDUP = 1.5          # compacted vs full scan, same run, K/M <= 1/8
MIN_SERVE_SPEEDUP = 1.0    # fused forest predict vs same-run per-tree vmap
MIN_ENGINE_FRAC = 0.8      # engine throughput vs same-run bare snapshot
SMALL_FRACTIONS = ("1/64", "1/8")
MIN_GATED_M = 128          # the acceptance-criterion scale (M = 255)
# achieved-vs-roofline floors (machine-independent: both sides of the
# fraction are measured in the same run).  Healthy commit-time values on
# the dev container: forest_update ~0.05, forest_route ~0.25 — the
# floors sit ~5x below, so they catch dispatch breakage, never load.
MIN_ROOFLINE_FRAC = {"forest_update": 0.01, "forest_route": 0.05}


def _committed():
    """{row name: committed us_per_call} from the repo-root artifacts."""
    rows = {}
    for fname in BASELINES:
        path = os.path.join(REPO_ROOT, fname)
        if not os.path.exists(path):
            continue
        with open(path) as f:
            for row in json.load(f):
                rows[row["name"]] = float(row["us_per_call"])
    return rows


def _best_of(run_report, to_rows, reps=2):
    """Per-row minimum over ``reps`` bench runs — wall times on shared
    runners swing with load and only in one direction (up), so the min is
    the least-noise estimator and can never mask a real regression.
    Returns (rows, reports)."""
    best = {}
    order = []
    reports = []
    for _ in range(reps):
        report = run_report()
        reports.append(report)
        for name, us, derived in to_rows(report):
            if name not in best:
                order.append(name)
                best[name] = (us, derived)
            elif us < best[name][0]:
                best[name] = (us, derived)
    return [(name,) + best[name] for name in order], reports


def main(argv=None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    committed = _committed()

    if "--profile" in argv:
        # harvest per-op compiled costs + a BOUNDED trace (one dispatch
        # per family) — the CI profile artifacts (profile_trace/,
        # BENCH_profile.fresh.json).  Never trace the bench runs
        # themselves: the profiler buffers every event in host memory
        # and minutes of tuner-race dispatches are an OOM, not a trace.
        from repro.kernels import ops as kops
        from repro.perf import profile as pprof
        from repro.perf.tune import make_workloads
        w = make_workloads()
        backend = kops.resolve_backend(None)
        costs = pprof.profile_ops({
            "forest_update": (
                lambda *a: kops.forest_update(*a, backend=backend),
                w["update"]),
            "forest_route": (
                lambda *a: kops.forest_route(*a, depth=w["depth"],
                                             backend=backend), w["route"]),
        }, logdir=os.path.join(REPO_ROOT, "profile_trace"))
        pprof.write_report(costs, os.path.join(REPO_ROOT,
                                               "BENCH_profile.fresh.json"))
    fresh, _ = _best_of(kernels.run, kernels.to_rows)
    qrows, qreports = _best_of(query_sweep.run, query_sweep.to_rows)
    fresh.extend(qrows)
    write_bench(FRESH_ARTIFACT, qrows)       # the uploaded artifact
    srows, sreports = _best_of(serve.run, serve.to_rows)
    fresh.extend(srows)
    write_bench(SERVE_FRESH_ARTIFACT, srows)
    erows, ereports = _best_of(engine_bench.run, engine_bench.to_rows)
    fresh.extend(erows)
    write_bench(ENGINE_FRESH_ARTIFACT, erows)
    rrows, rreports = _best_of(roofline.run, roofline.to_rows)
    fresh.extend(rrows)
    write_bench(ROOFLINE_FRESH_ARTIFACT, rrows)
    # fixed-seed statistical suite: deterministic, one rep is exact
    fsreport = false_splits.run()
    fsrows = false_splits.to_rows(fsreport)
    fresh.extend(fsrows)
    write_bench(SPLITS_FRESH_ARTIFACT, fsrows)
    # sketch-observer merit/capacity suite (fixed seeds, same contract)
    skreport = sketch_bench.run()
    skrows = sketch_bench.to_rows(skreport)
    fresh.extend(skrows)
    write_bench(SKETCH_FRESH_ARTIFACT, skrows)

    failures = []
    print(f"{'row':<42} {'committed':>10} {'fresh':>10} {'ratio':>7}  verdict")
    for name, us, _ in fresh:
        base = committed.get(name)
        if base is None:
            print(f"{name:<42} {'-':>10} {us:>10.2f} {'-':>7}  new row")
            continue
        if base <= 0.0 or us <= 0.0:
            print(f"{name:<42} {base:>10.2f} {us:>10.2f} {'-':>7}  "
                  f"accuracy-only")
            continue
        ratio = us / base
        ok = ratio <= TOLERANCE
        print(f"{name:<42} {base:>10.2f} {us:>10.2f} {ratio:>6.2f}x  "
              f"{'ok' if ok else 'REGRESSION'}")
        if not ok:
            failures.append(f"{name}: {base:.2f} -> {us:.2f} us/call "
                            f"(past the {TOLERANCE:.0f}x band)")

    # structural check, no cross-machine comparison: at sparse attempt
    # fractions the compacted path must beat the same-run full scan
    print(f"\n{'sweep cell':<42} {'speedup vs full scan':>22}  verdict")
    for name in sorted({n for rep in qreports for n in rep}):
        sp = max(rep[name]["speedup_vs_full_scan"]
                 for rep in qreports if name in rep)
        frac = qreports[0][name]["frac"]
        gated = frac in SMALL_FRACTIONS and qreports[0][name]["M"] >= MIN_GATED_M
        ok = (not gated) or sp >= MIN_SPEEDUP
        print(f"query_{name:<36} {sp:>21.2f}x  "
              f"{'ok' if ok else 'REGRESSION'}{'' if gated else ' (ungated)'}")
        if not ok:
            failures.append(
                f"query_{name}: compacted only {sp:.2f}x the full scan at "
                f"K/M = {frac} (structural floor {MIN_SPEEDUP}x)")

    # serving structural check: the fused forest predict must not fall
    # below the same-run per-tree vmap baseline it replaced
    sp = max(rep["forest_predict"]["speedup_vs_pertree"] for rep in sreports)
    ok = sp >= MIN_SERVE_SPEEDUP
    print(f"\n{'serve race':<42} {'speedup vs per-tree':>22}  verdict")
    print(f"{'serve_forest_predict_fused':<42} {sp:>21.2f}x  "
          f"{'ok' if ok else 'REGRESSION'}")
    if not ok:
        failures.append(
            f"serve_forest_predict_fused: only {sp:.2f}x the same-run "
            f"per-tree baseline (structural floor {MIN_SERVE_SPEEDUP}x)")

    # engine structural check: the full admission path (submit -> pack ->
    # dispatch -> split) must keep >= MIN_ENGINE_FRAC of the same-run bare
    # predict_snapshot throughput at the same bucket — catches the queue
    # layer creeping onto the hot path
    frac = max(rep["race"]["throughput_frac_of_bare"] for rep in ereports)
    ok = frac >= MIN_ENGINE_FRAC
    print(f"\n{'engine race':<42} {'frac of bare snapshot':>22}  verdict")
    print(f"{'engine_serve_once':<42} {frac:>21.2f}x  "
          f"{'ok' if ok else 'REGRESSION'}")
    if not ok:
        failures.append(
            f"engine_serve_once: only {frac:.2f}x the same-run bare "
            f"predict_snapshot throughput (structural floor "
            f"{MIN_ENGINE_FRAC}x)")

    # roofline floors: achieved-vs-attainable fraction, both sides from
    # the same run — load-independent, unlike the wall-time band above
    print(f"\n{'roofline gate':<42} {'achieved frac':>22}  verdict")
    for fam, floor in MIN_ROOFLINE_FRAC.items():
        frac = max(rep["ops"][fam]["achieved_frac"] for rep in rreports)
        ok = frac >= floor
        print(f"{'roofline_' + fam:<42} {frac:>21.4f}x  "
              f"{'ok' if ok else 'REGRESSION'} (floor {floor})")
        if not ok:
            failures.append(
                f"roofline_{fam}: achieved only {frac:.4f} of the "
                f"same-run attainable bound (floor {floor})")

    # split-decision statistical gates (fixed seeds — exact, not timing):
    # anytime ≤ α on noise, hoeffding > α (the §2.7 premise), drift MSE
    # ratio within the acceptance bar
    fs, dr = fsreport["false_splits"], fsreport["drift"]
    checks = [
        ("anytime_false_split_rate", fs["anytime"]["rate"],
         f"<= {fs['anytime']['alpha']}",
         fs["anytime"]["rate"] <= fs["anytime"]["alpha"]),
        ("hoeffding_false_split_rate", fs["hoeffding"]["rate"],
         f">  {fs['hoeffding']['alpha']} (motivating defect)",
         fs["hoeffding"]["rate"] > fs["hoeffding"]["alpha"]),
        ("drift_preq_mse_ratio", dr["mse_ratio"],
         f"<= {false_splits.MAX_MSE_RATIO}",
         dr["mse_ratio"] <= false_splits.MAX_MSE_RATIO),
    ]
    # sketch-observer gates: per-stream ε-rank / merit bounds plus the
    # ≥10x equivalent-capacity floor (§2.8 error model, fixed seeds)
    checks.extend(sketch_bench.gates(skreport))
    print(f"\n{'statistical gate':<42} {'value':>10} {'bound':>28}  verdict")
    for name, val, bound, ok in checks:
        print(f"{name:<42} {val:>10.3f} {bound:>28}  "
              f"{'ok' if ok else 'REGRESSION'}")
        if not ok:
            failures.append(f"{name}: {val:.3f} violates {bound}")

    if failures:
        print(f"\n{len(failures)} check(s) failed:", file=sys.stderr)
        for f in failures:
            print(f"  {f}", file=sys.stderr)
        return 1
    print("\nall rows within the absolute band and structural floor")
    return 0


if __name__ == "__main__":
    sys.exit(main())
