"""Forest-level benchmark: ONE vmapped update for T trees vs a python
loop over per-tree updates (DESIGN.md §5).

Two questions, both on the paper's synthetic protocol with an abrupt
concept drift planted mid-stream:

* **throughput** — the ensemble hot path as :func:`repro.core.forest.update`
  executes it (one dispatch: member predictions, Poisson(λ) bagging
  weights, T vmapped tree updates, drift windows) raced against the
  classical engine loop (the SAME per-member math — predict, Poisson
  draw, weighted update — jitted once and dispatched per tree per batch).
  ``speedup_vs_loop`` isolates what batching the tree axis buys; the
  sharded path (train/sharding.build_sharded_forest) runs this same
  vmapped program per device shard.
* **accuracy** — prequential (test-then-train) MSE of the vote-weighted
  forest vs every single member across the drift, and the drift-reset
  count.  The forest must track its best member or beat it.
"""
from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import forest as fr
from repro.core import hoeffding as ht
from repro.data.synth import piecewise_target


def drift_stream(n: int, n_features: int = 4, seed: int = 0,
                 noise: float = 0.1):
    """Piecewise-constant target whose split point jumps at n//2
    (the shared :func:`repro.data.synth.piecewise_target` concept)."""
    rng = np.random.default_rng(seed)
    X = rng.normal(0, 1, (n, n_features)).astype(np.float32)
    shift = np.where(np.arange(n) < n // 2, 0.0, 0.8).astype(np.float32)
    y = piecewise_target(X, shift)
    return X, (y + noise * rng.normal(0, 1, n)).astype(np.float32)


def _member_step(tcfg, lam, state, key, X, y, mask):
    """One member's share of forest.update: predict + Poisson + update
    (the identical per-member math, including the inverse-CDF sampler,
    so the race isolates the engines)."""
    yhat = ht.predict(tcfg, state, X)
    mse = jnp.mean((yhat - y) ** 2)
    key, wkey = jax.random.split(key)
    cdf = jnp.asarray(fr._poisson_cdf(lam), jnp.float32)
    w = fr._poisson_weights(wkey, cdf, y.shape)
    return ht.update(tcfg, state, X, y, w, mask), key, mse


def run(n=20480, n_features=4, bs=256, n_trees=16, trials=5):
    tcfg = ht.HTRConfig(n_features=n_features, max_nodes=63, n_bins=48,
                        grace_period=300, max_depth=8, r0=0.25)
    cfg = fr.ForestConfig(tree=tcfg, n_trees=n_trees)
    X, y = drift_stream(n, n_features, seed=11)
    batches = [(jnp.array(X[i:i + bs]), jnp.array(y[i:i + bs]))
               for i in range(0, n - bs + 1, bs)]
    n_seen = len(batches) * bs

    # --- engines ----------------------------------------------------------
    upd_vmap = jax.jit(functools.partial(fr.update, cfg))
    upd_loop = jax.jit(functools.partial(_member_step, tcfg, cfg.lam))

    def train_vmap():
        state = fr.init_forest(cfg, jax.random.PRNGKey(0))
        t0 = time.perf_counter()
        for xb, yb in batches:
            state, _ = upd_vmap(state, xb, yb)
        jax.block_until_ready(state["trees"]["n_nodes"])
        return state, time.perf_counter() - t0

    def train_loop():
        f0 = fr.init_forest(cfg, jax.random.PRNGKey(0))
        trees = [jax.tree.map(lambda a, t=t: a[t], f0["trees"])
                 for t in range(n_trees)]
        keys = [f0["keys"][t] for t in range(n_trees)]
        masks = [f0["feat_mask"][t] for t in range(n_trees)]
        t0 = time.perf_counter()
        for xb, yb in batches:
            for t in range(n_trees):
                trees[t], keys[t], _ = upd_loop(trees[t], keys[t], xb, yb,
                                                masks[t])
        jax.block_until_ready(trees[-1]["n_nodes"])
        return trees, time.perf_counter() - t0

    # compile both engines outside the timed region
    s = upd_vmap(fr.init_forest(cfg, jax.random.PRNGKey(0)), *batches[0])
    jax.block_until_ready(s[0]["trees"]["n_nodes"])
    f0 = fr.init_forest(cfg, jax.random.PRNGKey(0))
    r = upd_loop(jax.tree.map(lambda a: a[0], f0["trees"]), f0["keys"][0],
                 *batches[0], f0["feat_mask"][0])
    jax.block_until_ready(r[0]["n_nodes"])

    # interleave trials so machine-load drift hits both engines equally;
    # the speedup uses best-of-trials — the least-noise estimator on a
    # contended box (sandbox wall times swing 2-3x with load)
    times = {"vmapped": [], "loop": []}
    for _ in range(trials):
        _, dt = train_vmap()
        times["vmapped"].append(dt)
        _, dt = train_loop()
        times["loop"].append(dt)
    t_vmap = float(np.min(times["vmapped"]))
    t_loop = float(np.min(times["loop"]))

    # --- prequential accuracy across the drift (one-dispatch scan) --------
    state = fr.init_forest(cfg, jax.random.PRNGKey(0))
    state, trace = fr.update_stream(cfg, state, jnp.array(X), jnp.array(y),
                                    batch_size=bs)
    fmse = float(np.mean(np.asarray(trace["forest_mse"])))
    member_mse = np.asarray(trace["member_mse"]).mean(axis=0)      # (T,)
    resets = np.asarray(state["resets"])

    return {
        "n_trees": n_trees, "instances": n_seen, "batch_size": bs,
        "trials": trials,
        "vmapped": {"train_s": t_vmap,
                    "train_s_median": float(np.median(times["vmapped"])),
                    "instances_per_s": n_seen / t_vmap,
                    "us_per_batch": t_vmap / len(batches) * 1e6},
        "loop": {"train_s": t_loop,
                 "train_s_median": float(np.median(times["loop"])),
                 "instances_per_s": n_seen / t_loop,
                 "us_per_batch": t_loop / len(batches) * 1e6},
        "speedup_vs_loop": t_loop / t_vmap,
        "prequential": {
            "forest_mse": fmse,
            "member_mse": [float(m) for m in member_mse],
            "best_member_mse": float(member_mse.min()),
            "forest_beats_best_member": bool(fmse <= float(member_mse.min())),
            "drift_resets": int(resets.sum()),
            "leaves_per_tree": [int(v) for v in
                                np.asarray(fr.n_leaves_per_tree(state))],
        },
    }
