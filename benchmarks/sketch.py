"""Sketch-observer capacity and merit-error suite (DESIGN.md §2.8).

Two measurements, both deterministic given the seeds (machine-independent
statistical gates, not wall-times) plus one timing row:

* **merit-error gate** — trees trained with ``observer_backend="sketch"``
  on fixed-seed heavy-tail step streams; the first split's threshold
  must land within ``RANK_EPS`` (rank units) of the exhaustive
  ``tests``-oracle cut on the exact prefix the observer saw, and the
  exact merit AT the sketch threshold must retain ``MERIT_FRAC`` of the
  oracle optimum.  This is the documented ε bound of the §2.8 error
  model, enforced per stream.
* **equivalent-capacity gate** — what a static uniform C-bin grid over
  the observed range would need to localize the same cut at the
  sketch's achieved rank error.  On heavy-tail marginals the answer is
  ``C_eff >> K``: the K-slot sketch concentrates its boundaries where
  the mass (and the cut) lives, a uniform grid spends bins on empty
  tail range.  The gate is ``F * C_eff >= CAPACITY_RATIO * F * K`` —
  the sketch observer resolves a candidate layout ≥ 10x larger than
  dense state of equal memory.  For scale, the report also prints the
  per-leaf observer bytes both ways (4 f32 planes per slot) and trains
  a dense ``n_bins = K`` tree at the SAME budget for an (ungated,
  informational) merit comparison.
* **update throughput** — µs/call of one jitted
  :func:`repro.kernels.ops.sketch_update` absorb at serving shape.

``python -m benchmarks.run --only sketch`` writes BENCH_sketch.json;
``check_regression`` re-runs this module and enforces the per-stream
merit gates and the capacity ratio as structural checks.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hoeffding as ht
from repro.core import sketch as sk
from repro.kernels import ops

GRACE = 512          # rows seen before the first attempt (both schedules)
SKETCH_K = 16
N_FEATURES = 8
RANK_EPS = 0.15      # documented ε: 2 merge levels + boundary pick @ K=16
MERIT_FRAC = 0.8     # exact merit retained at the sketch's cut
CAPACITY_RATIO = 10  # F*C_eff vs F*K floor (the ISSUE acceptance bar)
C_EFF_CAP = 1 << 20  # stop the equivalent-grid search here
PLANES = 4           # n, mean, m2, sum_x — f32 each, per slot


def _step(rng, x, n):
    """Step target on the (skewed) signal marginal, at its median."""
    return (np.where(x > np.median(x), 2.0, 0.0)
            + 0.05 * rng.normal(size=n)).astype(np.float32)


def _stream_lognormal(seed, n=3072, F=N_FEATURES):
    rng = np.random.default_rng(seed)
    X = rng.lognormal(0.0, 1.5, size=(n, F)).astype(np.float32)
    return X, _step(rng, X[:, 0], n)


def _stream_pareto(seed, n=3072, F=N_FEATURES):
    rng = np.random.default_rng(seed)
    X = (rng.pareto(1.5, size=(n, F)) + 1.0).astype(np.float32)
    return X, _step(rng, X[:, 0], n)


def _stream_outliers(seed, n=3072, F=N_FEATURES):
    # Gaussian bulk with 2% far outliers: the cut lives in the dense
    # bulk, the outliers stretch the RANGE a uniform grid must cover —
    # the contamination case rank bucketing is immune to by construction
    rng = np.random.default_rng(seed)
    X = rng.normal(0.0, 1.0, size=(n, F))
    mask = rng.random(size=(n, F)) < 0.02
    X = np.where(mask, rng.uniform(1e3, 5e3, size=(n, F)),
                 X).astype(np.float32)
    return X, _step(rng, X[:, 0], n)


STREAMS = {
    "lognormal": (_stream_lognormal, 210),
    "pareto": (_stream_pareto, 211),
    "outliers": (_stream_outliers, 212),
}


def _exact_best_split(x, y):
    # inlined tests/helpers.py oracle (benchmarks must not import tests)
    order = np.argsort(x, kind="stable")
    xs = np.asarray(x, np.float64)[order]
    ys = np.asarray(y, np.float64)[order]
    n = len(ys)
    csum, csq = np.cumsum(ys), np.cumsum(ys ** 2)
    tot, totsq = csum[-1], csq[-1]
    s2d = np.var(ys, ddof=1)
    best = (-np.inf, None)
    for i in range(n - 1):
        if xs[i] == xs[i + 1]:
            continue
        nl, nr = i + 1, n - i - 1
        vl = (csq[i] - csum[i] ** 2 / nl) / (nl - 1) if nl > 1 else 0.0
        vr = ((totsq - csq[i]) - (tot - csum[i]) ** 2 / nr) / (nr - 1) \
            if nr > 1 else 0.0
        m = s2d - nl / n * vl - nr / n * vr
        if m > best[0]:
            best = (m, xs[i])
    return best


def _merit_at(x, y, thr):
    x = np.asarray(x, np.float64)
    y = np.asarray(y, np.float64)
    left = x <= float(thr)
    nl, nr = int(left.sum()), int((~left).sum())
    if nl == 0 or nr == 0:
        return -np.inf
    n = len(y)
    vl = np.var(y[left], ddof=1) if nl > 1 else 0.0
    vr = np.var(y[~left], ddof=1) if nr > 1 else 0.0
    return np.var(y, ddof=1) - nl / n * vl - nr / n * vr


def _rank(xs, v):
    return float(np.mean(np.asarray(xs, np.float64) <= float(v)))


def _cfg(observer: str, **kw):
    base = dict(n_features=N_FEATURES, max_nodes=3, n_bins=SKETCH_K,
                grace_period=GRACE, max_depth=3, r0=0.3,
                split_backend="jnp")
    if observer == "sketch":
        base.update(observer_backend="sketch", sketch_k=SKETCH_K)
    base.update(kw)
    return ht.HTRConfig(**base)


def _first_split(cfg, X, y):
    """Train to the first (and only — max_nodes=3) split; returns
    (feature, threshold) or None if the stream never split."""
    state = ht.update_stream(cfg, ht.init_state(cfg), jnp.asarray(X),
                             jnp.asarray(y), batch_size=256)
    if int(state["n_nodes"]) < 3:
        return None
    return int(state["feature"][0]), float(state["threshold"][0])


def _equivalent_grid_bins(x, t_star, eps):
    """Smallest uniform C-bin grid over [min(x), max(x)] with a boundary
    within ``eps`` rank units of the oracle cut — the dense capacity the
    sketch's achieved resolution is worth on this marginal."""
    x = np.asarray(x, np.float64)
    lo, hi = float(x.min()), float(x.max())
    r_star = _rank(x, t_star)
    c = SKETCH_K
    while c < C_EFF_CAP:
        bounds = np.linspace(lo, hi, c + 1)[1:-1]
        ranks = np.searchsorted(np.sort(x), bounds, side="right") / len(x)
        if np.abs(ranks - r_star).min() <= eps:
            return c
        c *= 2
    return C_EFF_CAP


def _time_update(reps: int = 50):
    """µs/call of one jitted sketch absorb at serving shape."""
    M, F, K, B = 255, N_FEATURES, SKETCH_K, 1024
    rng = np.random.default_rng(7)
    leaf = jnp.asarray(rng.integers(0, M, size=B), jnp.int32)
    X = jnp.asarray(rng.lognormal(0, 1.5, size=(B, F)), jnp.float32)
    y = jnp.asarray(rng.normal(size=B), jnp.float32)
    n, mean, m2, sum_x = sk.from_batch_planes(leaf, X, y,
                                              jnp.ones(B, jnp.float32),
                                              M, K)
    ao_y = {"n": n, "mean": mean, "m2": m2}
    args = (ao_y, sum_x, leaf, X, y)
    jax.block_until_ready(ops.sketch_update(*args, backend="jnp"))
    t0 = time.perf_counter()
    for _ in range(reps):
        out = ops.sketch_update(*args, backend="jnp")
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def run():
    report = {"streams": {}, "k": SKETCH_K, "n_features": N_FEATURES,
              "rank_eps": RANK_EPS, "merit_frac": MERIT_FRAC,
              "capacity_ratio_floor": CAPACITY_RATIO}
    for name, (gen, seed) in STREAMS.items():
        X, y = gen(seed)
        split = _first_split(_cfg("sketch"), X, y)
        assert split is not None, f"{name}: step stream must split"
        feat, thr = split
        xp, yp = X[:GRACE, feat], y[:GRACE]
        m_star, t_star = _exact_best_split(xp, yp)
        rank_err = abs(_rank(xp, thr) - _rank(xp, t_star))
        merit_ratio = _merit_at(xp, yp, thr) / m_star
        # the capacity a uniform grid needs to match the achieved rank
        # error (floored at one prefix row so a perfect cut stays finite)
        c_eff = _equivalent_grid_bins(xp, t_star,
                                      max(rank_err, 1.0 / GRACE))
        # informational: dense observer at the SAME memory (C = K slots)
        dense = _first_split(_cfg("qo"), X, y)
        dense_ratio = (_merit_at(X[:GRACE, dense[0]], yp, dense[1])
                       / m_star) if dense else 0.0
        report["streams"][name] = {
            "signal_feature": feat, "threshold": thr,
            "oracle_threshold": float(t_star),
            "oracle_merit": float(m_star),
            "rank_err": float(rank_err),
            "merit_ratio": float(merit_ratio),
            "c_eff": int(c_eff),
            "fc_sketch": N_FEATURES * SKETCH_K,
            "fc_eff": N_FEATURES * int(c_eff),
            "capacity_ratio": c_eff / SKETCH_K,
            "bytes_per_leaf_sketch": N_FEATURES * SKETCH_K * PLANES * 4,
            "bytes_per_leaf_dense_eff": N_FEATURES * int(c_eff) * PLANES
            * 4,
            "dense_same_budget_merit_ratio": float(dense_ratio),
        }
    report["update_us"] = _time_update()
    return report


def gates(report):
    """[(name, value, bound string, ok)] — the structural checks
    check_regression enforces (fixed seeds: exact, not timing)."""
    out = []
    for name, s in report["streams"].items():
        out.append((f"sketch_rank_err_{name}", s["rank_err"],
                    f"<= {RANK_EPS}", s["rank_err"] <= RANK_EPS))
        out.append((f"sketch_merit_ratio_{name}", s["merit_ratio"],
                    f">= {MERIT_FRAC}", s["merit_ratio"] >= MERIT_FRAC))
        out.append((f"sketch_capacity_ratio_{name}", s["capacity_ratio"],
                    f">= {CAPACITY_RATIO}",
                    s["capacity_ratio"] >= CAPACITY_RATIO))
    return out


def to_rows(report):
    rows = []
    for name, s in report["streams"].items():
        rows.append((f"sketch_merit_{name}", 0.0,
                     f"rank_err={s['rank_err']:.4f} "
                     f"merit_ratio={s['merit_ratio']:.3f} "
                     f"dense_same_budget={s['dense_same_budget_merit_ratio']:.3f} "
                     f"K={report['k']}"))
        rows.append((f"sketch_capacity_{name}", 0.0,
                     f"FxC_eff={s['fc_eff']} vs FxK={s['fc_sketch']} "
                     f"({s['capacity_ratio']:.0f}x; "
                     f"{s['bytes_per_leaf_dense_eff']}B dense-equiv vs "
                     f"{s['bytes_per_leaf_sketch']}B sketch per leaf)"))
    rows.append(("sketch_update", report["update_us"],
                 f"jitted absorb M=255 F={report['n_features']} "
                 f"K={report['k']} B=1024, µs/call"))
    return rows


if __name__ == "__main__":
    rep = run()
    for name, us, derived in to_rows(rep):
        print(f"{name:<36} {us:>10.1f}  {derived}")
    for name, val, bound, ok in gates(rep):
        print(f"{name:<36} {val:>10.3f} {bound:>10}  "
              f"{'ok' if ok else 'FAIL'}")
