"""Shared writer for the repo-root BENCH_*.json perf-trajectory artifacts.

One schema, one serializer: ``[{name, us_per_call, derived}, ...]`` rows
with ``us_per_call`` rounded to 3 decimals.  Used by both
:mod:`benchmarks.run` (which commits the baselines) and
:mod:`benchmarks.check_regression` (which diffs fresh runs against them),
so the two can never drift apart in format.  Lives in its own module
because ``benchmarks.run`` has import-time side effects (compute-dtype
setup) that the regression gate must not inherit.
"""
from __future__ import annotations

import json
import os

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def write_bench(filename: str, rows) -> None:
    """Write fixed-seed benchmark rows ``[(name, us_per_call, derived)]``
    to the repo root so successive PRs can diff throughput."""
    payload = [{"name": n, "us_per_call": round(float(us), 3), "derived": d}
               for n, us, d in rows]
    with open(os.path.join(REPO_ROOT, filename), "w") as f:
        json.dump(payload, f, indent=1)
